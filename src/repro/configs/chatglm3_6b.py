"""ChatGLM3-6B: 2d-RoPE (rotary on half the head dims), extreme GQA (kv=2)
[arXiv:2406.12793]."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128,
    layer_pattern="G", rope_style="partial",
    mlp_act="silu", rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="chatglm3-6b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        max_seq=256)
