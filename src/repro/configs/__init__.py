"""Config registry: ``get(name)`` / ``get_reduced(name)`` for every
assigned architecture (plus the paper's own FPGA benchmark suite lives in
``repro.fpga.benchmarks``)."""
from __future__ import annotations

import importlib

from .base import ArchConfig

ARCHS = [
    "arctic-480b", "granite-moe-3b-a800m", "llama-3.2-vision-11b",
    "granite-8b", "gemma2-27b", "chatglm3-6b", "gemma3-12b", "zamba2-7b",
    "whisper-tiny", "rwkv6-1.6b",
]

_MODULES = {
    "arctic-480b": "arctic_480b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "granite-8b": "granite_8b",
    "gemma2-27b": "gemma2_27b",
    "chatglm3-6b": "chatglm3_6b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-1.6b": "rwkv6_1p6b",
}


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.reduced()
