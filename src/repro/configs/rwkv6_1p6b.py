"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay WKV
recurrence [arXiv:2404.05892]."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab=65536, head_dim=64,
    layer_pattern="R", ssm_head_dim=64,
    gated_mlp=False, rope_style="none",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        ssm_head_dim=16, max_seq=256)
