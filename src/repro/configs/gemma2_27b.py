"""Gemma 2 27B: alternating local(4096)/global attention, attn softcap 50,
final softcap 30, post-norms, query scale 1/sqrt(d_model/n_heads)
[arXiv:2408.00118]."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000, head_dim=128,
    layer_pattern="LG", sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,   # gemma2 scales by d_model/n_heads
    mlp_act="gelu", post_norms=True,
    rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="gemma2-27b-reduced", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        sliding_window=32, query_scale=(64 / 4) ** -0.5, max_seq=256)
