"""Architecture configuration system.

One ``ArchConfig`` describes everything the model builder, the dry-run and
the TAPA task-graph extractor need.  Every assigned architecture provides a
module with ``CONFIG`` (full-size, exact public numbers) and ``reduced()``
(a tiny same-family config for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "hybrid", "audio", "ssm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads

    # ---- attention flavour -------------------------------------------------
    rope_theta: float = 10_000.0
    #: "neox" full-dim rotary; "partial" = rotary on half the head dim
    #: (chatglm's 2d-RoPE applies rotary to half the dims);
    #: "learned" = learned positions (whisper); "none" = attention-free
    rope_style: str = "neox"
    #: sliding-window size for local layers (None = all global)
    sliding_window: int | None = None
    #: layer pattern string over a repeating group, e.g. "LG" (gemma2
    #: alternating), "LLLLLG" (gemma3 5:1), "G"*n (all global),
    #: "M"*5 + "H" (zamba2: mamba with every-6th hybrid), "X" = cross-attn
    #: inserted (vlm).
    layer_pattern: str = "G"
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    #: query scaling ("head_dim" default, gemma2 uses d_model/n_heads)
    query_scale: float | None = None

    # ---- MLP ----------------------------------------------------------------
    mlp_act: str = "silu"                # silu | gelu
    gated_mlp: bool = True

    # ---- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int | None = None          # default d_ff
    #: arctic: dense FFN residual in parallel with the MoE FFN
    dense_residual: bool = False

    # ---- SSM (mamba2 / rwkv6) -----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # ---- enc-dec / multimodal ------------------------------------------------
    n_enc_layers: int = 0                # whisper encoder depth
    cross_attn_period: int = 0           # vlm: cross-attn every k layers
    frontend_tokens: int = 0             # stub modality tokens (audio/vision)
    frontend_dim: int = 0

    # ---- norms / misc ---------------------------------------------------------
    norm: str = "rmsnorm"
    post_norms: bool = False             # gemma2-style post-attn/post-mlp norm
    tie_embeddings: bool = True
    max_seq: int = 524_288

    # ---- training memory plan --------------------------------------------------
    #: optimizer selected per memory budget (see DESIGN.md §6)
    optimizer: str = "adamw"             # adamw | adafactor

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ---- derived sizes ---------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a shard-friendly multiple of 256
        (logits for padded rows are masked to -inf in lm_head)."""
        return -(-self.vocab // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-flops)."""
        c = self
        emb = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
        per_layer = 0
        att = c.d_model * (c.q_dim + 2 * c.kv_dim) + c.q_dim * c.d_model
        mlp_in = 2 * c.d_model * c.d_ff if c.gated_mlp else c.d_model * c.d_ff
        mlp = mlp_in + c.d_ff * c.d_model
        pat = c.layer_pattern
        for i in range(c.n_layers):
            kind = pat[i % len(pat)]
            if kind in ("G", "L", "X"):
                per_layer += att + mlp
                if kind == "X":
                    per_layer += att  # cross-attention
            elif kind == "M":
                d_in = c.ssm_expand * c.d_model
                per_layer += (c.d_model * (2 * d_in + 2 * c.ssm_state)
                              + d_in * c.d_model + d_in * 3)
            elif kind == "H":
                d_in = c.ssm_expand * c.d_model
                per_layer += (c.d_model * (2 * d_in + 2 * c.ssm_state)
                              + d_in * c.d_model + d_in * 3)
                per_layer += att + mlp  # shared block (counted once is fine)
            elif kind == "R":
                per_layer += 4 * c.d_model * c.d_model + 2 * c.d_model * c.d_ff
        if c.n_experts:
            moe_in = 2 * c.d_model * c.moe_d_ff if c.gated_mlp else \
                c.d_model * c.moe_d_ff
            moe = (moe_in + c.moe_d_ff * c.d_model) * c.n_experts \
                + c.d_model * c.n_experts
            delta = moe - mlp if not c.dense_residual else moe
            per_layer += delta * c.n_layers
        return int(emb + per_layer)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts)."""
        c = self
        if not c.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_in = 2 * c.d_model * c.moe_d_ff if c.gated_mlp else \
            c.d_model * c.moe_d_ff
        expert = moe_in + c.moe_d_ff * c.d_model
        inactive = (c.n_experts - c.top_k) * expert * c.n_layers
        return int(full - inactive)
