"""IBM Granite 3.0 MoE (3B total / 800M active): 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64,
    layer_pattern="G",
    n_experts=40, top_k=8, moe_d_ff=512,
    rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-3b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, moe_d_ff=64, vocab=256,
        head_dim=16, n_experts=8, top_k=4, max_seq=256)
