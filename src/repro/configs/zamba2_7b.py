"""Zamba2 7B: Mamba2 backbone with two alternating *shared* attention
blocks invoked every 6th layer over concat(hidden, embeddings)
[arXiv:2411.15242].  81 layers = 3 groups x 27 (pattern below).  The
shared block is the broadcast-topology task the floorplanner must either
co-locate or balance (DESIGN.md §4)."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112,
    layer_pattern="MMMMMH" * 4 + "MMM",      # len 27; 81 = 3 groups
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-7b-reduced", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        layer_pattern="MMMMMH", ssm_state=16, ssm_head_dim=16, max_seq=256)
