"""Llama 3.2 Vision 11B backbone: 40 decoder layers with gated
cross-attention image layers every 5th layer [hf:meta-llama/
Llama-3.2-11B-Vision].  The vision tower is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings (B, 1601, 1280)."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128,
    layer_pattern="GGGXG",            # X = cross-attention layer (8 total)
    cross_attn_period=5, frontend_tokens=1601, frontend_dim=1280,
    rope_theta=5e5, tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="llama-vision-reduced", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        frontend_tokens=16, frontend_dim=32, max_seq=256)
