"""Snowflake Arctic (480B): dense-MoE hybrid — 128 experts top-2 with a
parallel dense FFN residual [hf:Snowflake/snowflake-arctic-base].

Memory plan: fp32 Adam for 480B params (6.7 TB) cannot fit a 256-chip v5e
pod; config selects Adafactor (factored 2nd moment) per DESIGN.md §6.
"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128,
    layer_pattern="G",
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    rope_theta=1e6, optimizer="adafactor",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="arctic-480b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, moe_d_ff=96, vocab=256,
        head_dim=16, n_experts=8, top_k=2, max_seq=256)
