"""Gemma 3 12B: 5:1 local:global attention (window 1024), 128k context,
global layers at rope theta 1M [hf:google/gemma-3-1b-pt family].
long_500k is served with the ring-buffered local caches; only the 8 global
layers hold full-length KV."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=256,
    layer_pattern="LLLLLG", sliding_window=1024,
    mlp_act="gelu", post_norms=True,
    rope_theta=2e4,          # x50 on global layers (see build_specs)
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="gemma3-12b-reduced", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        sliding_window=32, max_seq=256)
