"""Whisper-tiny backbone: 4-layer encoder + 4-layer causal decoder with
cross-attention [arXiv:2212.04356].  The conv audio frontend is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
(B, T_frames, 384).  decode_32k / prefill_32k are shape-valid synthetic
cells far beyond the model's trained 448-token context (noted in
DESIGN.md)."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, head_dim=64,
    layer_pattern="X",                 # decoder layers cross-attend
    n_enc_layers=4, frontend_tokens=1500, frontend_dim=384,
    mlp_act="gelu", gated_mlp=False, tie_embeddings=True,
    rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-tiny-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, head_dim=16,
        n_enc_layers=2, frontend_tokens=32, frontend_dim=64, max_seq=256)
