"""Static deadlock pass: firing bounds over the capacity graph.

The simulator's model (``repro.core.simulate``) is a *unit-rate marked
graph*: every FIFO starts empty, a firing consumes/produces one token per
stream, and capacity ``cap(s) = depth(s) + extra_capacity(s)``.  Latency
and initiation intervals delay firings but can never deadlock them, and
``control`` streams are excluded from the token model entirely — this pass
analyzes exactly the structure the event engine executes.

Two-step analysis, both in near-linear time:

1. **Dead tasks.**  Build the *zero-token graph*: a forward arc
   ``producer -> consumer`` for every data stream (0 initial tokens ahead
   of the consumer) and a backward arc ``consumer -> producer`` for every
   stream with effective capacity <= 0 (0 initial credits ahead of the
   producer).  Any task on a cycle of this graph can never fire: each arc
   of the cycle says "u fires only after v", with no initial token to
   break the wait.  This covers both classic data cycles (all FIFOs empty)
   and zero-capacity FIFOs (producer blocked forever).

2. **Firing bounds.**  Token conservation gives, for every data stream
   ``s``:  ``fired(consumer) <= fired(producer)`` and
   ``fired(producer) <= fired(consumer) + cap(s)``.  Seeding ``0`` at the
   dead tasks and relaxing these inequalities is a shortest-path problem
   (arc weights 0 forward, ``cap`` backward): ``ub[t]`` = the minimum
   token sum over any path from a dead task to ``t``.  Tasks unreachable
   from every dead task have no finite bound — they are live.

A graph is *doomed* at wave size ``firings`` iff some non-detached task
has ``ub < firings``; the bound is exact enough in both directions that
the property tests in ``tests/test_analysis.py`` hold it against the event
engine on randomized graphs: no "safe" graph may deadlock, and every
"doomed" graph must.
"""
from __future__ import annotations

import heapq
from typing import Mapping

from repro.core.graph import TaskGraph

from .report import ERROR, WARN, Report

_INF = float("inf")


def _dead_sccs(nodes: list[str],
               edges: list[tuple[str, str]]) -> list[list[str]]:
    """Strongly connected components with >= 2 nodes (no self-arcs exist in
    the zero-token graph, so singletons are never dead).  Iterative
    Kosaraju — analysis must not recurse out of stack on deep chains."""
    fwd: dict[str, list[str]] = {n: [] for n in nodes}
    rev: dict[str, list[str]] = {n: [] for n in nodes}
    for u, v in edges:
        fwd[u].append(v)
        rev[v].append(u)

    order: list[str] = []
    seen: set[str] = set()
    for root in nodes:
        if root in seen:
            continue
        stack: list[tuple[str, int]] = [(root, 0)]
        seen.add(root)
        while stack:
            n, i = stack.pop()
            if i < len(fwd[n]):
                stack.append((n, i + 1))
                m = fwd[n][i]
                if m not in seen:
                    seen.add(m)
                    stack.append((m, 0))
            else:
                order.append(n)

    comp: dict[str, int] = {}
    sccs: list[list[str]] = []
    for root in reversed(order):
        if root in comp:
            continue
        cid = len(sccs)
        members = [root]
        comp[root] = cid
        work = [root]
        while work:
            n = work.pop()
            for m in rev[n]:
                if m not in comp:
                    comp[m] = cid
                    members.append(m)
                    work.append(m)
        sccs.append(sorted(members))
    return [s for s in sccs if len(s) >= 2]


def firing_bounds(graph: TaskGraph, *,
                  extra_capacity: Mapping[str, int] | None = None
                  ) -> tuple[dict[str, int | None], list[list[str]]]:
    """``(bounds, dead_cycles)``: the static per-task firing upper bound
    (``None`` = unbounded) and the dead zero-token SCCs that seed it."""
    extra_capacity = extra_capacity or {}
    tasks = list(graph.tasks)
    data = [s for s in graph.streams if not s.control
            and s.src in graph.tasks and s.dst in graph.tasks
            and s.src != s.dst]
    cap = {s.name: int(s.depth) + int(extra_capacity.get(s.name, 0))
           for s in data}

    zero_edges = [(s.src, s.dst) for s in data]
    zero_edges += [(s.dst, s.src) for s in data if cap[s.name] <= 0]
    dead_cycles = _dead_sccs(tasks, zero_edges)
    dead = {n for scc in dead_cycles for n in scc}

    # weighted relaxation graph: token slack along each conservation arc
    arcs: dict[str, list[tuple[str, int]]] = {n: [] for n in tasks}
    for s in data:
        arcs[s.src].append((s.dst, 0))
        arcs[s.dst].append((s.src, max(cap[s.name], 0)))

    dist = {n: (0 if n in dead else _INF) for n in tasks}
    heap = [(0, n) for n in sorted(dead)]
    heapq.heapify(heap)
    while heap:
        d, n = heapq.heappop(heap)
        if d > dist[n]:
            continue
        for m, w in arcs[n]:
            nd = d + w
            if nd < dist[m]:
                dist[m] = nd
                heapq.heappush(heap, (nd, m))

    bounds = {n: (None if dist[n] == _INF else int(dist[n]))
              for n in tasks}
    return bounds, dead_cycles


def lint_deadlock(graph: TaskGraph, report: Report, *,
                  extra_capacity: Mapping[str, int] | None = None,
                  firings: int | None = None) -> None:
    """Append the deadlock (``D``-code) diagnostics to ``report`` and fill
    ``report.max_firings`` (non-detached tasks) / ``report.deadlock``."""
    bounds, dead_cycles = firing_bounds(graph,
                                        extra_capacity=extra_capacity)
    detached = {n: t.detached for n, t in graph.tasks.items()}
    report.max_firings = {n: b for n, b in bounds.items() if not detached[n]}

    for scc in dead_cycles:
        report.add("D001-dead-cycle", ERROR,
                   f"tasks {', '.join(scc)} form a tokenless dependency "
                   "cycle (empty FIFOs / zero capacity) and can never fire",
                   subjects=tuple(scc),
                   hint="give the loop initial credit by closing it with a "
                   "control stream, or break the cycle")

    dead = {n for scc in dead_cycles for n in scc}
    for n, b in sorted(report.max_firings.items()):
        if b is None or n in dead:
            continue
        if firings is None:
            report.add("D002-starved-task", WARN,
                       f"task {n!r} can fire at most {b} times (starved by "
                       "a dead upstream/downstream task)",
                       subjects=(n,),
                       hint="any firing wave larger than the bound "
                       "deadlocks")
        elif b < firings:
            report.add("D002-starved-task", ERROR,
                       f"task {n!r} can fire at most {b} < {firings} times "
                       "— the requested wave is a guaranteed deadlock",
                       subjects=(n,),
                       hint="shrink the wave or fix the dead cycle feeding "
                       "the bound")
    if firings is not None:
        report.deadlock = report.doomed(firings)
