"""Structural lint pass: graph-shape findings that need no firing model.

Codes (stable; the table lives in ``docs/analysis-guide.md``):

==========================  ========  =========================================
code                        severity  finding
==========================  ========  =========================================
``A001-dangling-stream``    error     stream endpoint is not a task of the graph
``A002-self-loop-stream``   error     stream with ``src == dst``
``A003-nonpositive-width``  error     stream with ``width <= 0``
``A004-negative-depth``     error     stream with ``depth < 0``
``A005-zero-capacity``      error     data stream whose effective capacity
                                      (``depth + extra_capacity``) is ``<= 0``
                                      — its producer can never write
``A006-width-change``       info      single-in/single-out task whose input and
                                      output widths differ
``A007-unreachable-task``   warn      non-detached task no data path from a
                                      source reaches (lives in/behind a cycle)
``A008-sinkless-task``      warn      non-detached task with no data path to a
                                      sink — its results are never drained
``A009-pin-outside-grid``   error     ``Task.pinned`` slot outside the grid
``A010-pin-shared-slot``    warn      several tasks pinned to one slot
``A011-pin-overflow``       error     pinned tasks overflow their slot's
                                      capacity even at ``max_util = 1.0``
``A012-stale-index``        error     ``TaskGraph`` adjacency index out of sync
                                      with the stream list
==========================  ========  =========================================

Pin lints (A009-A011) run only when a ``SlotGrid`` is supplied.
"""
from __future__ import annotations

from collections import deque
from typing import Mapping

from repro.core.graph import TaskGraph

from .report import ERROR, INFO, WARN, Report


def _data_streams(graph: TaskGraph):
    return [s for s in graph.streams if not s.control]


def _reachable(adj: Mapping[str, list[str]], roots) -> set[str]:
    seen = set(roots)
    work = deque(seen)
    while work:
        n = work.popleft()
        for m in adj.get(n, ()):
            if m not in seen:
                seen.add(m)
                work.append(m)
    return seen


def lint_structure(graph: TaskGraph, report: Report, *,
                   grid=None,
                   extra_capacity: Mapping[str, int] | None = None) -> None:
    """Append the structural (``A``-code) diagnostics to ``report``."""
    extra_capacity = extra_capacity or {}
    tasks = graph.tasks

    # -- stream-level lints ------------------------------------------------
    for s in graph.streams:
        missing = [e for e in (s.src, s.dst) if e not in tasks]
        if missing:
            report.add("A001-dangling-stream", ERROR,
                       f"stream {s.name!r} references unknown task(s) "
                       f"{', '.join(repr(m) for m in missing)}",
                       subjects=(s.name,),
                       hint="add the task or remove the stream")
            continue
        if s.src == s.dst:
            report.add("A002-self-loop-stream", ERROR,
                       f"stream {s.name!r} loops {s.src!r} onto itself — the "
                       "task model forbids a task streaming to itself",
                       subjects=(s.name, s.src),
                       hint="split the task or drop the stream")
        if s.width <= 0:
            report.add("A003-nonpositive-width", ERROR,
                       f"stream {s.name!r} has width {s.width!r}",
                       subjects=(s.name,),
                       hint="declare a positive channel width")
        if s.depth < 0:
            report.add("A004-negative-depth", ERROR,
                       f"stream {s.name!r} has depth {s.depth!r}",
                       subjects=(s.name,),
                       hint="declare a non-negative FIFO depth")
        if not s.control:
            cap = int(s.depth) + int(extra_capacity.get(s.name, 0))
            if cap <= 0:
                report.add("A005-zero-capacity", ERROR,
                           f"data stream {s.name!r} has effective capacity "
                           f"{cap} — its producer can never write",
                           subjects=(s.name,),
                           hint="give the FIFO depth >= 1 (or pipeline "
                           "headroom)")

    # -- adjacency-index consistency ---------------------------------------
    want_out: dict[str, list[int]] = {}
    want_in: dict[str, list[int]] = {}
    for i, s in enumerate(graph.streams):
        want_out.setdefault(s.src, []).append(i)
        want_in.setdefault(s.dst, []).append(i)
    have_out = {n: sorted(v) for n, v in graph._out.items() if v}
    have_in = {n: sorted(v) for n, v in graph._in.items() if v}
    if (have_out != {n: sorted(v) for n, v in want_out.items()}
            or have_in != {n: sorted(v) for n, v in want_in.items()}):
        report.add("A012-stale-index", ERROR,
                   "task->stream adjacency index disagrees with the stream "
                   "list (a stream was added without add_stream)",
                   hint="always add streams via TaskGraph.add_stream")

    # Remaining lints walk producer/consumer relations; dangling endpoints
    # would KeyError, so restrict to well-formed data streams.
    data = [s for s in _data_streams(graph)
            if s.src in tasks and s.dst in tasks]

    # -- per-task port lints -----------------------------------------------
    din: dict[str, list] = {n: [] for n in tasks}
    dout: dict[str, list] = {n: [] for n in tasks}
    for s in data:
        dout[s.src].append(s)
        din[s.dst].append(s)
    for n in tasks:
        if len(din[n]) == 1 and len(dout[n]) == 1:
            w_in, w_out = din[n][0].width, dout[n][0].width
            if w_in != w_out:
                report.add("A006-width-change", INFO,
                           f"task {n!r} narrows/widens its stream "
                           f"({w_in:g} -> {w_out:g} bits)",
                           subjects=(n, din[n][0].name, dout[n][0].name),
                           hint="intended for (de)serializers; otherwise a "
                           "width typo")

    # -- reachability ------------------------------------------------------
    fwd: dict[str, list[str]] = {n: [] for n in tasks}
    bwd: dict[str, list[str]] = {n: [] for n in tasks}
    for s in data:
        fwd[s.src].append(s.dst)
        bwd[s.dst].append(s.src)
    sources = [n for n in tasks if not din[n]]
    sinks = [n for n in tasks if not dout[n]]
    from_sources = _reachable(fwd, sources)
    to_sinks = _reachable(bwd, sinks)
    unreachable = tuple(sorted(n for n in tasks
                               if n not in from_sources
                               and not tasks[n].detached))
    if unreachable:
        report.add("A007-unreachable-task", WARN,
                   "no data path from any source reaches "
                   f"{', '.join(unreachable)} (cycle-fed only)",
                   subjects=unreachable,
                   hint="feed the task from a source or mark the loop "
                   "closure as a control stream")
    sinkless = tuple(sorted(n for n in tasks
                            if n not in to_sinks and not tasks[n].detached))
    if sinkless:
        report.add("A008-sinkless-task", WARN,
                   f"no data path from {', '.join(sinkless)} reaches a sink",
                   subjects=sinkless,
                   hint="drain the task's output or detach it")

    # -- pin lints (need a grid) -------------------------------------------
    if grid is None:
        return
    by_slot: dict[tuple[int, int], list[str]] = {}
    for n, t in tasks.items():
        if t.pinned is None:
            continue
        r, c = t.pinned
        if not (0 <= r < grid.rows and 0 <= c < grid.cols):
            report.add("A009-pin-outside-grid", ERROR,
                       f"task {n!r} pinned to slot ({r}, {c}) outside the "
                       f"{grid.rows}x{grid.cols} grid {grid.name!r}",
                       subjects=(n,),
                       hint="fix the pin or pick a larger device")
            continue
        by_slot.setdefault((r, c), []).append(n)
    for slot, names in sorted(by_slot.items()):
        if len(names) > 1:
            report.add("A010-pin-shared-slot", WARN,
                       f"tasks {', '.join(sorted(names))} all pinned to "
                       f"slot {slot}",
                       subjects=tuple(sorted(names)),
                       hint="legal (they co-locate), but check it is "
                       "intentional")
        cap = grid.capacity(*slot, max_util=1.0)
        need: dict[str, float] = {}
        for n in names:
            for k, v in tasks[n].area.items():
                need[k] = need.get(k, 0.0) + v
        over = sorted(k for k, v in need.items()
                      if k in cap and v > cap[k])
        if over:
            report.add("A011-pin-overflow", ERROR,
                       f"tasks pinned to slot {slot} need more "
                       f"{', '.join(over)} than the slot has even at "
                       "max_util=1.0 — every floorplan is infeasible",
                       subjects=tuple(sorted(names)),
                       hint="unpin a task or spread the pins")
