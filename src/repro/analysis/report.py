"""Diagnostic/report types and the static-skip accounting counters.

A ``Diagnostic`` is one finding of the static verifier: a stable code
(``A...`` structural, ``D...`` deadlock, ``R...`` rate), a severity
(``error`` / ``warn`` / ``info``), the graph objects it is about and a fix
hint.  ``Report`` aggregates the diagnostics of one ``analyze()`` run plus
the deadlock pass's firing bounds and the rate pass's repetition vector /
static cycle lower bound.

The module-global counters mirror ``simulate.engine_counts()`` /
``autobridge.floorplan_counts()``: benchmark drivers snapshot them into the
BENCH JSON ``sim.analysis`` block and the CI regression gate reads them to
prove the pre-flight gate actually ran (``analyzed > 0``) and that static
skipping never changed a frontier (``skipped > 0`` implies frontier
unchanged vs baseline).
"""
from __future__ import annotations

import dataclasses

from ..obs import metrics as _metrics

ERROR = "error"
WARN = "warn"
INFO = "info"

_SEVERITIES = (ERROR, WARN, INFO)

# analyze() runs / doomed verdicts / gate-skipped candidates / structural
# static-infeasibility verdicts recorded by ``autobridge(check=True)`` —
# global like the engine/floorplan counters, reset per benchmark run.
_ANALYSIS_COUNTS = _metrics.group(
    "analysis",
    {"analyzed": 0, "doomed": 0, "skipped": 0, "infeasible": 0})


def reset_analysis_counts() -> None:
    """Zero the global static-analysis counters."""
    _ANALYSIS_COUNTS.reset()


def analysis_counts() -> dict[str, int]:
    """Snapshot of analyzer runs, doomed verdicts, gate-skipped candidates
    and static-infeasibility verdicts since the last reset."""
    return dict(_ANALYSIS_COUNTS)


class StaticAnalysisError(ValueError):
    """Raised by ``simulate(check="raise")`` / ``analyze`` consumers when a
    graph fails static verification; carries the full ``Report``."""

    def __init__(self, message: str, report: "Report"):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One static-verifier finding."""
    #: stable machine-readable code, e.g. ``A001-dangling-stream``
    code: str
    #: ``error`` (graph is broken / guaranteed to fail), ``warn`` (almost
    #: certainly a bug, but the flow can proceed), ``info`` (notable)
    severity: str
    #: human-readable one-line statement of the finding
    message: str
    #: the task/stream names the finding is about
    subjects: tuple[str, ...] = ()
    #: how to fix it
    hint: str = ""

    def __post_init__(self):
        if self.severity not in _SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def __str__(self) -> str:
        subj = f" [{', '.join(self.subjects)}]" if self.subjects else ""
        return f"{self.severity.upper()} {self.code}{subj}: {self.message}"


@dataclasses.dataclass
class Report:
    """Structured result of one ``analyze()`` run."""
    graph_name: str
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    #: static upper bound on firings per *non-detached* task (None =
    #: unbounded/live); filled by the deadlock pass — detached tasks are
    #: excluded because the engine's termination rule ignores them
    max_firings: dict[str, int | None] = dataclasses.field(
        default_factory=dict)
    #: True when the deadlock pass proved the graph cannot complete the
    #: requested firing wave (only set when ``firings`` was given)
    deadlock: bool = False
    #: SDF repetition vector (task -> relative firing rate), or None when
    #: the rate pass found the balance equations inconsistent
    repetition: dict[str, int] | None = None
    #: static lower bound on completion cycles for the requested firing
    #: wave (None when ``firings`` was not given or the graph is doomed)
    min_cycles: int | None = None

    def add(self, code: str, severity: str, message: str, *,
            subjects: tuple[str, ...] = (), hint: str = "") -> Diagnostic:
        d = Diagnostic(code=code, severity=severity, message=message,
                       subjects=tuple(subjects), hint=hint)
        self.diagnostics.append(d)
        return d

    def by_severity(self, severity: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == severity]

    @property
    def errors(self) -> list[Diagnostic]:
        return self.by_severity(ERROR)

    @property
    def warnings(self) -> list[Diagnostic]:
        return self.by_severity(WARN)

    @property
    def ok(self) -> bool:
        """No error-severity diagnostics (warnings/infos allowed)."""
        return not self.errors

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def firing_bound(self, task: str) -> int | None:
        """Static upper bound on ``task``'s firings (None = unbounded)."""
        return self.max_firings.get(task)

    def doomed(self, firings: int) -> bool:
        """True when some non-detached task provably cannot reach
        ``firings`` firings — the simulator is guaranteed to deadlock."""
        if firings <= 0:
            return False
        return any(b is not None and b < firings
                   for b in self.max_firings.values())

    def summary(self) -> str:
        """One line: ``ok``/``FAIL`` plus the diagnostic tally."""
        n = {s: len(self.by_severity(s)) for s in _SEVERITIES}
        verdict = "ok" if self.ok else "FAIL"
        return (f"{self.graph_name}: {verdict} "
                f"({n[ERROR]} error, {n[WARN]} warn, {n[INFO]} info)")

    def error_summary(self) -> str:
        """Deterministic one-line reason string for error diagnostics —
        the text ``autobridge(check=True)`` raises and caches, so parallel
        and sequential search paths produce identical verdicts."""
        return "; ".join(f"{d.code}: {d.message}" for d in self.errors)

    def as_dict(self) -> dict:
        """JSON-ready form (the ``python -m repro.analysis --json`` shape)."""
        return {
            "graph": self.graph_name,
            "ok": self.ok,
            "deadlock": self.deadlock,
            "min_cycles": self.min_cycles,
            "repetition": self.repetition,
            "max_firings": dict(self.max_firings),
            "diagnostics": [dataclasses.asdict(d) for d in self.diagnostics],
        }
