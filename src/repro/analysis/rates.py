"""SDF-style rate-consistency pass: balance equations, repetition vector,
and a static cycles lower bound.

The core IR is *homogeneous* SDF — every firing moves exactly one token
per port — so the balance equations ``rep(src) * rate_out = rep(dst) *
rate_in`` are trivially consistent with an all-ones repetition vector.
Designs may still annotate multi-rate intent on a stream's ``meta``
(``rate_src`` / ``rate_dst`` tokens per firing, defaulting to the stream's
width on both ends, i.e. rate ratio 1): the pass solves the balance
equations over ``fractions.Fraction`` per weakly-connected component and
flags inconsistencies (``R001``) — a graph whose declared rates cannot be
balanced loses tokens somewhere and will starve or flood at steady state
once the multi-rate semantics are implemented.

The cycles bound is simulator-true and ignores the annotations: with unit
rates, task ``t``'s first firing cannot happen before the longest data
path into it has filled (1 cycle per hop + the stream's pipeline latency),
and its ``firings``-th firing trails by ``(firings - 1) * II(t)``.  The
completion wave therefore needs at least

    max over non-detached t of  fill(t) + (firings - 1) * II(t)  +  1

cycles — a lower bound every engine run must respect (asserted against the
event engine in the tests).  Cyclic data graphs skip the fill term (the
deadlock pass owns that story).
"""
from __future__ import annotations

from fractions import Fraction
from math import lcm
from typing import Mapping

from repro.core.graph import TaskGraph

from .report import WARN, Report


def _rates(s) -> tuple[float, float]:
    """(producer, consumer) tokens-per-firing of one stream; the width is
    the default on both ends, so unannotated streams have ratio 1."""
    return (float(s.meta.get("rate_src", s.width)),
            float(s.meta.get("rate_dst", s.width)))


def repetition_vector(graph: TaskGraph,
                      report: Report | None = None) -> dict[str, int] | None:
    """Smallest positive integer repetition vector of the data graph, or
    ``None`` when the balance equations are inconsistent (``R001``) or a
    rate annotation is non-positive (``R002``)."""
    data = [s for s in graph.streams if not s.control
            and s.src in graph.tasks and s.dst in graph.tasks
            and s.src != s.dst]
    for s in data:
        p, c = _rates(s)
        if p <= 0 or c <= 0:
            if report is not None:
                report.add("R002-nonpositive-rate", WARN,
                           f"stream {s.name!r} declares non-positive rate "
                           f"({p:g} -> {c:g})",
                           subjects=(s.name,),
                           hint="rates (and widths) must be positive")
            return None

    adj: dict[str, list[tuple[str, Fraction]]] = {n: [] for n in graph.tasks}
    for s in data:
        p, c = _rates(s)
        ratio = Fraction(p).limit_denominator(10**9) / \
            Fraction(c).limit_denominator(10**9)
        # rep(dst) = rep(src) * p / c along the stream, and inversely back
        adj[s.src].append((s.dst, ratio))
        adj[s.dst].append((s.src, 1 / ratio))

    rep: dict[str, Fraction] = {}
    for root in graph.tasks:
        if root in rep:
            continue
        rep[root] = Fraction(1)
        work = [root]
        while work:
            n = work.pop()
            for m, ratio in adj[n]:
                want = rep[n] * ratio
                if m not in rep:
                    rep[m] = want
                    work.append(m)
                elif rep[m] != want:
                    if report is not None:
                        report.add(
                            "R001-rate-inconsistent", WARN,
                            f"balance equations conflict at task {m!r}: "
                            f"{rep[m]} vs {want} relative firings",
                            subjects=(m,),
                            hint="make the per-path rate products agree "
                            "(classic SDF consistency)")
                    return None
    scale = lcm(*(f.denominator for f in rep.values())) if rep else 1
    ints = {n: int(f * scale) for n, f in rep.items()}
    # normalize each weakly-connected component is overkill here: one
    # global scale keeps the vector integral, which is all consumers need
    return ints


def min_cycles_bound(graph: TaskGraph, *, firings: int,
                     latency: Mapping[str, int] | None = None,
                     ii: Mapping[str, int] | None = None) -> int | None:
    """Static lower bound on completion cycles of a ``firings`` wave, or
    ``None`` when the data graph is cyclic (deadlock territory) or no
    non-detached task exists."""
    latency = latency or {}
    ii = ii or {}
    data = [s for s in graph.streams if not s.control
            and s.src in graph.tasks and s.dst in graph.tasks
            and s.src != s.dst]
    indeg = {n: 0 for n in graph.tasks}
    out: dict[str, list] = {n: [] for n in graph.tasks}
    for s in data:
        indeg[s.dst] += 1
        out[s.src].append(s)
    # Kahn topological fill: fill(t) = earliest first-firing cycle of t
    fill = {n: 0 for n in graph.tasks}
    ready = [n for n in graph.tasks if indeg[n] == 0]
    done = 0
    while ready:
        n = ready.pop()
        done += 1
        for s in out[n]:
            # a token pushed at cycle u is visible at u + 1 + latency
            fill[s.dst] = max(fill[s.dst], fill[n] + 1 + int(latency.get(s.name, 0)))
            indeg[s.dst] -= 1
            if indeg[s.dst] == 0:
                ready.append(s.dst)
    if done < len(graph.tasks):
        return None                         # data cycle: no finite fill
    waves = [fill[n] + (firings - 1) * max(int(ii.get(n, 1)), 1)
             for n, t in graph.tasks.items() if not t.detached]
    if not waves or firings <= 0:
        return 0
    return max(waves) + 1


def lint_rates(graph: TaskGraph, report: Report, *,
               latency: Mapping[str, int] | None = None,
               ii: Mapping[str, int] | None = None,
               firings: int | None = None) -> None:
    """Append the rate (``R``-code) diagnostics to ``report`` and fill
    ``report.repetition`` / ``report.min_cycles``."""
    report.repetition = repetition_vector(graph, report)
    if firings is not None and not report.deadlock:
        report.min_cycles = min_cycles_bound(graph, firings=firings,
                                             latency=latency, ii=ii)
