"""repro.analysis — static dataflow verifier (pre-flight gate for search).

``analyze(graph)`` classifies a ``TaskGraph`` *without executing a single
firing*: three passes append ``Diagnostic``s (stable code, severity
error/warn/info, subjects, fix hint) to a structured ``Report``.

* **structure** (``A``-codes, ``repro.analysis.structure``): dangling /
  self-loop / zero-width / zero-capacity streams, width changes,
  unreachable or sink-less tasks, pin conflicts against a ``SlotGrid``;
* **deadlock** (``D``-codes, ``repro.analysis.deadlock``): tokenless
  dependency cycles and the per-task static firing bound they imply —
  sound against the event engine (property-tested: a graph ``analyze``
  calls safe never deadlocks in ``simulate`` at the same wave size);
* **rates** (``R``-codes, ``repro.analysis.rates``): SDF balance
  equations / repetition vector plus a static cycles lower bound.

The verifier is wired in as a pre-flight gate across the stack:
``simulate(check="warn"|"raise")``, the search engine's static candidate
gate (``prepare_design_space(static_check=...)``, skipped candidates
counted by ``analysis_counts()``), and ``autobridge(check=True)`` caching
static-infeasibility verdicts in its ``FloorplanCache``.  See
``docs/analysis-guide.md`` for the full code table and semantics.

>>> from repro.core import TaskGraphBuilder
>>> from repro.analysis import analyze
>>> b = TaskGraphBuilder("pipe")
>>> _ = b.stream("s", width=32, depth=2)
>>> _ = b.invoke("P", outs=["s"])
>>> _ = b.invoke("C", ins=["s"])
>>> rep = analyze(b.build(), firings=10)
>>> rep.ok, rep.deadlock, rep.min_cycles
(True, False, 11)
>>> rep.repetition
{'P': 1, 'C': 1}

A data cycle with empty FIFOs can never fire — ``analyze`` proves the
deadlock statically and bounds every starved task's firings:

>>> b = TaskGraphBuilder("loop")
>>> _ = b.stream("ab"); _ = b.stream("ba")
>>> _ = b.invoke("A", ins=["ba"], outs=["ab"])
>>> _ = b.invoke("B", ins=["ab"], outs=["ba"])
>>> rep = analyze(b.build(), firings=10)
>>> rep.ok, rep.deadlock, rep.firing_bound("A")
(False, True, 0)
>>> sorted(rep.codes())
['A007-unreachable-task', 'A008-sinkless-task', 'D001-dead-cycle']

Closing the loop through a latency-tolerant ``control`` stream (the
paper's page-rank pattern) makes it safe:

>>> b = TaskGraphBuilder("loop2")
>>> _ = b.stream("ab"); _ = b.stream("ba", control=True)
>>> _ = b.invoke("A", ins=["ba"], outs=["ab"])
>>> _ = b.invoke("B", ins=["ab"], outs=["ba"])
>>> analyze(b.build(), firings=10).ok
True
"""
from __future__ import annotations

from typing import Mapping

from repro.core.graph import TaskGraph

from .deadlock import firing_bounds, lint_deadlock
from .rates import lint_rates, min_cycles_bound, repetition_vector
from .report import (ERROR, INFO, WARN, Diagnostic, Report,
                     StaticAnalysisError, _ANALYSIS_COUNTS, analysis_counts,
                     reset_analysis_counts)
from .structure import lint_structure

__all__ = [
    "ERROR", "WARN", "INFO", "Diagnostic", "Report", "StaticAnalysisError",
    "analyze", "analysis_counts", "reset_analysis_counts",
    "firing_bounds", "repetition_vector", "min_cycles_bound",
]

_PASSES = ("structure", "deadlock", "rates")


def analyze(graph: TaskGraph, *,
            grid=None,
            latency: Mapping[str, int] | None = None,
            extra_capacity: Mapping[str, int] | None = None,
            ii: Mapping[str, int] | None = None,
            firings: int | None = None,
            passes: tuple[str, ...] = _PASSES) -> Report:
    """Statically verify ``graph`` under the given simulation knobs.

    grid           — enables the pin lints (``A009``-``A011``)
    latency        — per-stream pipeline registers (cycles bound only;
                     latency can never cause a deadlock)
    extra_capacity — per-stream FIFO headroom beyond the declared depth
                     (e.g. ``Plan.sim_extra_capacity``) — enters the
                     capacity/deadlock analysis exactly as in ``simulate``
    ii             — per-task initiation intervals (cycles bound only)
    firings        — the wave size to verify; with it the deadlock pass
                     renders a verdict (``Report.deadlock``) and the rate
                     pass a ``min_cycles`` bound
    passes         — subset of ``("structure", "deadlock", "rates")``
    """
    unknown = set(passes) - set(_PASSES)
    if unknown:
        raise ValueError(f"unknown analysis pass(es) {sorted(unknown)}")
    _ANALYSIS_COUNTS["analyzed"] += 1
    report = Report(graph_name=graph.name)
    if "structure" in passes:
        lint_structure(graph, report, grid=grid,
                       extra_capacity=extra_capacity)
    if "deadlock" in passes:
        lint_deadlock(graph, report, extra_capacity=extra_capacity,
                      firings=firings)
        if report.deadlock:
            _ANALYSIS_COUNTS["doomed"] += 1
    if "rates" in passes:
        lint_rates(graph, report, latency=latency, ii=ii, firings=firings)
    return report
