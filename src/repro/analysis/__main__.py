"""``python -m repro.analysis`` — lint benchmark designs from the CLI.

Runs ``analyze()`` over the named designs of ``repro.fpga.benchmarks``
(``autobridge_suite`` + ``hbm_suite``) against their board's slot grid.
Exits non-zero when any design carries an error-severity diagnostic, which
is what the CI ``lint-designs`` step gates on.

    python -m repro.analysis --all                # every design
    python -m repro.analysis page_rank bucket_sort
    python -m repro.analysis --all --json         # machine-readable
    python -m repro.analysis --list               # show the registry
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.fpga import benchmarks, grid_for

from . import analyze


def _registry() -> dict[str, tuple[str, object]]:
    """``name@board -> (board, graph)`` over both benchmark suites; bare
    design names also resolve when unambiguous."""
    entries: dict[str, tuple[str, object]] = {}
    for name, board, graph in (benchmarks.autobridge_suite()
                               + benchmarks.hbm_suite()):
        entries[f"{name}@{board}"] = (board, graph)
    return entries


def _resolve(entries: dict, names: list[str]) -> list[str]:
    keys = []
    for want in names:
        if want in entries:
            keys.append(want)
            continue
        matches = [k for k in entries if k.split("@", 1)[0] == want]
        if not matches:
            raise SystemExit(f"unknown design {want!r} "
                             "(try --list for the registry)")
        keys.extend(matches)
    return keys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static dataflow lint over the benchmark designs")
    ap.add_argument("designs", nargs="*",
                    help="design names (bare or name@board)")
    ap.add_argument("--all", action="store_true",
                    help="lint every design of both suites")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit one JSON report list instead of text")
    ap.add_argument("--firings", type=int, default=200,
                    help="wave size for the deadlock verdict (default 200)")
    ap.add_argument("--list", action="store_true", dest="list_only",
                    help="list the design registry and exit")
    args = ap.parse_args(argv)

    entries = _registry()
    if args.list_only:
        for k in entries:
            print(k)
        return 0
    if args.all:
        keys = list(entries)
    elif args.designs:
        keys = _resolve(entries, args.designs)
    else:
        ap.error("name at least one design (or pass --all)")

    reports = []
    failed = 0
    for k in keys:
        board, graph = entries[k]
        rep = analyze(graph, grid=grid_for(board), firings=args.firings)
        reports.append((k, rep))
        if not rep.ok:
            failed += 1

    if args.as_json:
        print(json.dumps([dict(design=k, **rep.as_dict())
                          for k, rep in reports], indent=2))
    else:
        for k, rep in reports:
            print(f"{k}: {rep.summary().split(': ', 1)[1]}")
            for d in rep.diagnostics:
                if d.severity != "info":
                    print(f"  {d}")
        print(f"{len(reports)} design(s) linted, {failed} with errors")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
