"""Device grids for multi-backend sweeps: the paper's two boards (§2.3,
§7.1) plus TPU-pod-shaped grids for cross-device comparisons.

  * Alveo U250: 4 dies (SLRs) stacked vertically, DDR/IO column in the
    middle -> 2 cols x 4 rows = 8 slots.  Totals (paper footnote 2):
    5376 BRAM18K, 12288 DSP48E, 3456K FF, 1728K LUT.
  * Alveo U280: 3 dies + HBM (32 channels) along the bottom edge ->
    2 cols x 3 rows = 6 slots.  Totals (footnote 3): 4032 BRAM18K,
    9024 DSP48E, 2607K FF, ~1303K LUT (the footnote's "434K" is the
    per-slot FF figure; we use the physical 1303K total).

Boundary delays: SLR (die) crossings carry the large interposer penalty;
the middle IO column detours routes with a smaller penalty (paper §2.3).
"""
from __future__ import annotations

from repro.core import Boundary, SlotGrid

def _DIE() -> Boundary:
    """Vertical die boundary: expensive; 2 register levels per crossing."""
    return Boundary(weight=1.0, pipeline_depth=2, delay_ns=2.4)


def _IOCOL() -> Boundary:
    """The middle IO/DDR column: cheaper but real."""
    return Boundary(weight=1.0, pipeline_depth=2, delay_ns=1.6)


def u250_grid(max_util: float = 0.70, ddr_channels_per_row: int = 1) -> SlotGrid:
    rows, cols = 4, 2
    cap = {
        "LUT": 1728e3 / (rows * cols),
        "FF": 3456e3 / (rows * cols),
        "BRAM": 5376 / (rows * cols),
        "DSP": 12288 / (rows * cols),
        "URAM": 1280 / (rows * cols),
    }
    # one DDR controller per die, adjacent to the middle column (col 0
    # side); each controller exposes multiple AXI ports via the platform
    # interconnect
    slot_caps = {(r, 0): {"ddr_channels": 4.0 * ddr_channels_per_row}
                 for r in range(rows)}
    return SlotGrid("U250", rows=rows, cols=cols, base_capacity=cap,
                    slot_caps=slot_caps,
                    row_boundaries=[_DIE() for _ in range(rows - 1)],
                    col_boundaries=[_IOCOL() for _ in range(cols - 1)],
                    max_util=max_util)


#: total HBM pseudo-channels on the U280's bottom edge (paper §2.3/§6.2);
#: promoted from the ad-hoc constant in ``benchmarks/hbm_opts.py`` so the
#: channel-math (e.g. BRAM saved by async channel IO = channels x per-port
#: buffer) lives next to the grid that owns the channels.
U280_HBM_CHANNELS = 32


def u280_grid(max_util: float = 0.70, hbm_split: float = 0.5) -> SlotGrid:
    """The U280 grid; ``hbm_split`` tilts the 32-channel HBM binding
    across the two bottom slots (``SlotGrid.with_hbm_binding``) — 0.5 is
    the symmetric platform default of 16 channels per slot."""
    rows, cols = 3, 2
    cap = {
        "LUT": 1303e3 / (rows * cols),
        "FF": 2607e3 / (rows * cols),
        "BRAM": 4032 / (rows * cols),
        "DSP": 9024 / (rows * cols),
        "URAM": 960 / (rows * cols),
    }
    # 32 HBM channels across the bottom row (16 per bottom slot);
    # 2 DDR DIMMs near the top die
    hbm_per_slot = U280_HBM_CHANNELS / 2
    slot_caps = {(0, 0): {"hbm_channels": hbm_per_slot},
                 (0, 1): {"hbm_channels": hbm_per_slot},
                 (2, 0): {"ddr_channels": 4.0},
                 (2, 1): {"ddr_channels": 4.0}}
    grid = SlotGrid("U280", rows=rows, cols=cols, base_capacity=cap,
                    slot_caps=slot_caps,
                    row_boundaries=[_DIE() for _ in range(rows - 1)],
                    col_boundaries=[_IOCOL() for _ in range(cols - 1)],
                    max_util=max_util)
    return grid.with_hbm_binding(hbm_split)


def _ICI() -> Boundary:
    """Intra-pod ICI hop: cheap, one buffer stage per crossing."""
    return Boundary(weight=0.5, pipeline_depth=1, delay_ns=1.0)


def _DCN() -> Boundary:
    """Pod-slice (DCN) split: expensive and deep."""
    return Boundary(weight=2.0, pipeline_depth=4, delay_ns=3.2)


def tpu_pod_grid(rows: int = 4, cols: int = 2,
                 max_util: float = 0.70) -> SlotGrid:
    """A TPU-pod-shaped grid for ``sweep_backends`` cross-device studies:
    ``rows x cols`` chip groups, row boundaries are ICI hops (cheap,
    shallow) and column boundaries are pod-slice/DCN splits (expensive,
    deep) — the same coarse slot/boundary abstraction the paper applies to
    SLRs, re-parameterized to a pod topology.

    Capacities reuse the FPGA resource vocabulary, scaled up so the paper's
    benchmark graphs sweep unchanged across U250/U280/pod grids; every chip
    group faces its own HBM stack (``hbm_channels`` in every slot)."""
    cap = {
        "LUT": 2400e3 / (rows * cols),
        "FF": 4800e3 / (rows * cols),
        "BRAM": 7168 / (rows * cols),
        "DSP": 16384 / (rows * cols),
        "URAM": 1792 / (rows * cols),
    }
    slot_caps = {(r, c): {"hbm_channels": 8.0}
                 for r in range(rows) for c in range(cols)}
    return SlotGrid(f"TPUpod{rows}x{cols}", rows=rows, cols=cols,
                    base_capacity=cap, slot_caps=slot_caps,
                    row_boundaries=[_ICI() for _ in range(rows - 1)],
                    col_boundaries=[_DCN() for _ in range(cols - 1)],
                    max_util=max_util)


#: named device-grid factories for one-call multi-device sweeps
#: (``sweep_backends(graph, {name: grid_for(name) for name in ...})``)
DEVICE_GRIDS = {
    "u250": u250_grid,
    "u280": u280_grid,
    #: channel-aware U280 variants: the HBM binding tilted toward the
    #: left/right bottom slot (SearchSpace(hbm_splits=...) searches the
    #: same axis continuously; these are the named extreme points)
    "u280_hbm_left": lambda max_util=0.70: u280_grid(
        max_util=max_util, hbm_split=0.75),
    "u280_hbm_right": lambda max_util=0.70: u280_grid(
        max_util=max_util, hbm_split=0.25),
    "tpu_pod_4x2": tpu_pod_grid,
    "tpu_pod_2x2": lambda max_util=0.70: tpu_pod_grid(
        rows=2, cols=2, max_util=max_util),
    "tpu_pod_8x4": lambda max_util=0.70: tpu_pod_grid(
        rows=8, cols=4, max_util=max_util),
}


def grid_for(name: str, **kwargs) -> SlotGrid:
    """Instantiate a registered device grid by name.

    Grid factories are cheap and stateless; the expensive per-grid work (the
    floorplan ILPs of a sweep) is memoized by ``repro.core.FloorplanCache``,
    keyed by the grid's shape/capacities/boundary weights — so two calls
    producing equal grids share cached floorplans automatically.

    >>> from repro.fpga import grid_for
    >>> grid_for("u250").rows, grid_for("u250").cols
    (4, 2)
    >>> grid_for("tpu_pod_8x4", max_util=0.8).name
    'TPUpod8x4'
    """
    try:
        factory = DEVICE_GRIDS[name]
    except KeyError:
        raise KeyError(f"unknown device grid {name!r}; "
                       f"known: {sorted(DEVICE_GRIDS)}") from None
    return factory(**kwargs)
