"""FPGA-side reproduction: devices (U250/U280/TPU-pod shapes) and the
paper's benchmarks."""
from .archs import (DEVICE_GRIDS, U280_HBM_CHANNELS, grid_for, tpu_pod_grid,
                    u250_grid, u280_grid)
from . import benchmarks

__all__ = ["DEVICE_GRIDS", "U280_HBM_CHANNELS", "grid_for", "tpu_pod_grid",
           "u250_grid", "u280_grid", "benchmarks"]
