"""FPGA-side reproduction: devices (U250/U280) and the paper's benchmarks."""
from .archs import u250_grid, u280_grid
from . import benchmarks

__all__ = ["u250_grid", "u280_grid", "benchmarks"]
