"""The paper's benchmark suite as TAPA task graphs (§7.2, Fig. 11).

Six AutoBridge families (stencil chain, CNN grid, Gaussian triangle, bucket
sort crossbars, page-rank with cycles, genome broadcast) swept over size x
{U250, U280} = 43 designs, plus the four §7.4 HBM designs (SASA-1/2, SpMM,
SpMV_A16/A24).

Module areas are reverse-calibrated from the paper's utilization tables
(Tables 4-9) so the generated designs occupy the same device fractions.  IO
module areas are the paper's Table 3 measurements:

    mmap (Vitis default):  LUT 1189, FF 3740, BRAM 15
    async_mmap (TAPA §3.4): LUT 1466, FF  162, BRAM  0
"""
from __future__ import annotations

from repro.core import TaskGraph, TaskGraphBuilder

MMAP_IO = {"LUT": 1189.0, "FF": 3740.0, "BRAM": 15.0}
ASYNC_IO = {"LUT": 1466.0, "FF": 162.0, "BRAM": 0.0}


def _io_area(use_async: bool, hbm: bool = False) -> dict[str, float]:
    a = dict(ASYNC_IO if use_async else MMAP_IO)
    if hbm:
        a["hbm_channels"] = 1.0
    else:
        a["ddr_channels"] = 1.0
    return a


# ---------------------------------------------------------------------------
# SODA stencil: linear chain of large kernels (Fig. 11 top-left)
# ---------------------------------------------------------------------------

def stencil(n_kernels: int, use_async: bool = False) -> TaskGraph:
    """Each kernel uses ~half the resources of a slot (paper §7.3)."""
    b = TaskGraphBuilder(f"stencil_x{n_kernels}")
    kern = {"LUT": 100e3, "FF": 150e3, "BRAM": 180.0, "DSP": 288.0}
    b.stream("ld", width=512)
    for i in range(n_kernels - 1):
        b.stream(f"k{i}", width=512)
    b.stream("st", width=512)
    b.invoke("Load", area=_io_area(use_async), outs=["ld"])
    for i in range(n_kernels):
        ins = ["ld"] if i == 0 else [f"k{i-1}"]
        outs = ["st"] if i == n_kernels - 1 else [f"k{i}"]
        b.invoke(f"Kernel{i}", area=dict(kern), ins=ins, outs=outs)
    b.invoke("Store", area=_io_area(use_async), ins=["st"])
    return b.build()


# ---------------------------------------------------------------------------
# PolySA CNN: 13 x N systolic grid (Fig. 11; Tables 4, 11)
# ---------------------------------------------------------------------------

def cnn(n_cols: int, n_rows: int = 13, use_async: bool = False) -> TaskGraph:
    """Grid of PEs + local drains, double row-feeder chains, column feeders
    and drains; 3 DDR IO modules + 2 controllers.  13x2 -> 87 modules / ~141
    streams, matching Table 11's vertex/edge counts."""
    b = TaskGraphBuilder(f"cnn_{n_rows}x{n_cols}")
    PE = {"LUT": 2950.0, "FF": 5200.0, "BRAM": 4.0, "DSP": 40.0}
    LD = {"LUT": 700.0, "FF": 1200.0, "BRAM": 2.0}
    RF = {"LUT": 7600.0, "FF": 14000.0, "BRAM": 30.0}
    CF = {"LUT": 1500.0, "FF": 2500.0, "BRAM": 16.0}
    CTRL = {"LUT": 1000.0, "FF": 1500.0}

    def S(name, width=256):
        b.stream(name, width=width)
        return name

    # IO + controllers
    b.invoke("A_load", area=_io_area(use_async), outs=[S("a_bus", 512)])
    b.invoke("B_load", area=_io_area(use_async), outs=[S("b_bus", 512)])
    b.invoke("C_store", area=_io_area(use_async), ins=[S("c_bus", 512)])
    b.invoke("ctrl0", area=dict(CTRL), outs=[S("cmd0", 32)])
    b.invoke("ctrl1", area=dict(CTRL), ins=[S("st0", 32)])

    # double row-feeder chains down the 13 rows
    prev = "a_bus"
    for r in range(n_rows):
        nxt = S(f"rf{r}", 512) if r < n_rows - 1 else None
        outs = [S(f"a{r}", 256)] + ([nxt] if nxt else [])
        b.invoke(f"RFa_{r}", area=dict(RF), ins=[prev], outs=outs)
        prev = nxt
    prev = "cmd0"
    for r in range(n_rows):
        nxt = S(f"rg{r}", 64) if r < n_rows - 1 else S("gtail", 32)
        outs = [S(f"g{r}", 64), nxt]
        b.invoke(f"RFb_{r}", area=dict(RF), ins=[prev], outs=outs)
        prev = nxt

    # column feeders (B) chained off b_bus, column drains chained into c_bus
    prevb = "b_bus"
    for c in range(n_cols):
        nxtb = S(f"cfb{c}", 512) if c < n_cols - 1 else None
        outs = [S(f"b{c}", 256)] + ([nxtb] if nxtb else [])
        b.invoke(f"CF_{c}", area=dict(CF), ins=[prevb], outs=outs)
        prevb = nxtb
    for c in range(n_cols):
        ins = [S(f"d{c}", 256)]
        if c > 0:
            ins.append(f"dc{c-1}")
        outs = [S(f"dc{c}", 512)] if c < n_cols - 1 else ["c_bus"]
        b.invoke(f"CD_{c}", area=dict(CF), ins=ins, outs=outs)

    # the PE grid: A flows right, B flows down, results drain via LDs
    for r in range(n_rows):
        for c in range(n_cols):
            ins = [f"a{r}" if c == 0 else f"ah_{r}_{c-1}",
                   f"b{c}" if r == 0 else f"bv_{r-1}_{c}"]
            if c == 0:
                ins.append(f"g{r}")   # per-row command lane
            outs = []
            if c < n_cols - 1:
                outs.append(S(f"ah_{r}_{c}", 256))
            if r < n_rows - 1:
                outs.append(S(f"bv_{r}_{c}", 256))
            outs.append(S(f"pd_{r}_{c}", 256))
            b.invoke(f"PE_{r}_{c}", area=dict(PE), ins=ins, outs=outs)
            # local drain chain: LD[r,c] joins PE output with drain from above
            ld_ins = [f"pd_{r}_{c}"]
            if r > 0:
                ld_ins.append(f"ldv_{r-1}_{c}")
            ld_out = S(f"ldv_{r}_{c}", 256) if r < n_rows - 1 else f"d{c}"
            b.invoke(f"LD_{r}_{c}", area=dict(LD), ins=ld_ins, outs=[ld_out])

    # status chain terminates in ctrl1
    b.invoke("status", area=dict(CTRL), ins=["gtail"], outs=["st0"])
    return b.build()


# ---------------------------------------------------------------------------
# AutoSA Gaussian elimination: triangular PE array (Fig. 11; Table 5)
# ---------------------------------------------------------------------------

def gaussian(n: int, use_async: bool = False) -> TaskGraph:
    b = TaskGraphBuilder(f"gaussian_{n}x{n}")
    PE = {"LUT": 2660.0, "FF": 3400.0, "DSP": 4.5}
    MEM = {"LUT": 5000.0, "FF": 9000.0, "BRAM": 28.0}

    def S(name, width=256):
        b.stream(name, width=width)
        return name

    # fixed memory/feed infrastructure (BRAM-heavy, ~constant across sizes,
    # Table 5 shows BRAM pinned at 13.24%)
    b.invoke("Load", area=_io_area(use_async), outs=[S("feed_bus", 512)])
    b.invoke("Store", area=_io_area(use_async), ins=[S("drain_bus", 512)])
    prev = "feed_bus"
    n_mem = 22
    for i in range(n_mem):
        nxt = S(f"mem{i}", 512) if i < n_mem - 1 else S("mem_tail", 64)
        outs = [nxt] + ([S(f"mf{i}", 256)] if i < n else [])
        b.invoke(f"Mem_{i}", area=dict(MEM), ins=[prev], outs=outs)
        prev = nxt
    b.invoke("MemSink", area={"LUT": 200.0}, ins=["mem_tail"])

    # upper-triangular PE array: PE(i,j), 0 <= i <= j < n
    drains = []
    for i in range(n):
        for j in range(i, n):
            ins = []
            if j == i:   # diagonal fed by mem feeders (mf_i for i < n_mem)
                ins.append(f"mf{i}" if i < n_mem else S(f"xf{i}", 256))
                if i >= n_mem:
                    b.invoke(f"XF_{i}", area=dict(MEM), outs=[f"xf{i}"])
            else:
                ins.append(f"gr_{i}_{j-1}")
            if i > 0:
                ins.append(f"gd_{i-1}_{j}")
            outs = []
            if j < n - 1:
                outs.append(S(f"gr_{i}_{j}", 256))
            if i < n - 1 and j > i:
                outs.append(S(f"gd_{i}_{j}", 256))
            if j == n - 1:
                outs.append(S(f"dr_{i}", 256))
                drains.append(f"dr_{i}")
            b.invoke(f"PE_{i}_{j}", area=dict(PE), ins=ins, outs=outs)

    # drain collector chain
    prev = None
    for i, d in enumerate(drains):
        ins = [d] + ([prev] if prev else [])
        out = S(f"dchain{i}", 512) if i < len(drains) - 1 else "drain_bus"
        b.invoke(f"DR_{i}", area={"LUT": 600.0, "FF": 900.0}, ins=ins,
                 outs=[out])
        prev = f"dchain{i}" if i < len(drains) - 1 else None
    return b.build()


# ---------------------------------------------------------------------------
# HBM bucket sort: two fully-connected 8x8 crossbars (Fig. 11; Table 6)
# ---------------------------------------------------------------------------

def bucket_sort(use_async: bool = False) -> TaskGraph:
    b = TaskGraphBuilder("bucket_sort")
    DEC = {"LUT": 13600.0, "FF": 15000.0, "BRAM": 8.0}
    SORT = {"LUT": 16600.0, "FF": 18000.0, "BRAM": 40.0, "DSP": 0.5}
    MRG = {"LUT": 13600.0, "FF": 14000.0, "BRAM": 8.0}

    def S(name, width=256):
        b.stream(name, width=width)
        return name

    for i in range(8):
        b.invoke("In", area=_io_area(use_async, hbm=True),
                 outs=[S(f"in{i}", 512)])
        b.invoke("Dec", area=dict(DEC), ins=[f"in{i}"],
                 outs=[S(f"x1_{i}_{j}") for j in range(8)])
    for j in range(8):
        b.invoke("Sort", area=dict(SORT), ins=[f"x1_{i}_{j}" for i in range(8)],
                 outs=[S(f"x2_{j}_{k}") for k in range(8)])
    for k in range(8):
        b.invoke("Mrg", area=dict(MRG), ins=[f"x2_{j}_{k}" for j in range(8)],
                 outs=[S(f"out{k}", 512)])
        b.invoke("Out", area=_io_area(use_async, hbm=True), ins=[f"out{k}"])
    return b.build()


# ---------------------------------------------------------------------------
# HBM page rank: 8 PUs + central controller, with dependency cycles
# (Fig. 11; Table 7)
# ---------------------------------------------------------------------------

def page_rank(use_async: bool = False) -> TaskGraph:
    b = TaskGraphBuilder("page_rank")
    GATH = {"LUT": 26000.0, "FF": 30000.0, "BRAM": 40.0, "DSP": 70.0}
    APPL = {"LUT": 28000.0, "FF": 34000.0, "BRAM": 50.0, "DSP": 85.0}
    CTRL = {"LUT": 46000.0, "FF": 56000.0, "BRAM": 60.0, "DSP": 60.0}

    def S(name, width=256):
        b.stream(name, width=width)
        return name

    # central controller with 5 HBM ports
    ctrl_ins, ctrl_outs = [], []
    for p in range(5):
        b.invoke("CtrlIO", area=_io_area(use_async, hbm=True),
                 outs=[S(f"cio{p}", 512)])
        ctrl_ins.append(f"cio{p}")
    for i in range(8):
        # command/status handshakes are per-iteration control, not
        # per-token dataflow: latency-tolerant (closes the dependency cycle)
        b.stream(f"cmd{i}", width=64, control=True)
        b.stream(f"stat{i}", width=64, control=True)
        ctrl_outs.append(f"cmd{i}")
        ctrl_ins.append(f"stat{i}")
    b.invoke("Ctrl", area=dict(CTRL), ins=ctrl_ins, outs=ctrl_outs)

    for i in range(8):
        b.invoke("PuIO_a", area=_io_area(use_async, hbm=True),
                 outs=[S(f"pa{i}", 512)])
        b.invoke("PuIO_b", area=_io_area(use_async, hbm=True),
                 outs=[S(f"pb{i}", 512)])
        b.invoke("Gather", area=dict(GATH),
                 ins=[f"pa{i}", f"cmd{i}"], outs=[S(f"gu{i}", 512)])
        # Apply reports status back to Ctrl: the dependency cycle
        b.invoke("Apply", area=dict(APPL),
                 ins=[f"gu{i}", f"pb{i}"], outs=[f"stat{i}"])
    return b.build()


# ---------------------------------------------------------------------------
# Genome sequencing (Minimap2 overlapping): broadcast topology (Fig. 11)
# ---------------------------------------------------------------------------

def genome(n_pe: int = 24, use_async: bool = False) -> TaskGraph:
    b = TaskGraphBuilder(f"genome_x{n_pe}")
    PE = {"LUT": 26000.0, "FF": 34000.0, "BRAM": 44.0, "DSP": 110.0}
    DIST = {"LUT": 9000.0, "FF": 12000.0, "BRAM": 30.0}

    def S(name, width=512):
        b.stream(name, width=width)
        return name

    b.invoke("Load", area=_io_area(use_async), outs=[S("in_bus")])
    b.invoke("Dist", area=dict(DIST), ins=["in_bus"],
             outs=[S(f"bc{i}") for i in range(n_pe)])
    b.invoke("Coll", area=dict(DIST), ins=[S(f"res{i}") for i in range(n_pe)],
             outs=[S("out_bus")])
    b.invoke("Store", area=_io_area(use_async), ins=["out_bus"])
    for i in range(n_pe):
        b.invoke("PE", area=dict(PE), ins=[f"bc{i}"], outs=[f"res{i}"])
    return b.build()


# ---------------------------------------------------------------------------
# §7.4 HBM designs: SASA stencil, SpMM, SpMV
# ---------------------------------------------------------------------------

def sasa(version: int, use_async: bool = True) -> TaskGraph:
    """Hybrid spatial/temporal stencil over many HBM channels; v1 = 24
    channels (12 tiles), v2 = 27 channels (13 tiles + halo unit)."""
    n_tiles = 12 if version == 1 else 13
    b = TaskGraphBuilder(f"sasa_v{version}")
    KERN = ({"LUT": 32000.0, "FF": 42000.0, "DSP": 130.0} if version == 1
            else {"LUT": 32500.0, "FF": 48000.0, "DSP": 330.0})

    def S(name, width=512):
        b.stream(name, width=width)
        return name

    for i in range(n_tiles):
        b.invoke("In", area=_io_area(use_async, hbm=True), outs=[S(f"i{i}")])
        ins = [f"i{i}"]
        if i > 0:
            ins.append(f"halo{i-1}")
        outs = [S(f"o{i}")]
        if i < n_tiles - 1:
            outs.append(S(f"halo{i}", 256))
        b.invoke("Kern", area=dict(KERN), ins=ins, outs=outs)
        b.invoke("Out", area=_io_area(use_async, hbm=True), ins=[f"o{i}"])
    if version == 2:
        b.invoke("HaloIO", area=_io_area(use_async, hbm=True),
                 outs=[S("hx", 256)])
        b.invoke("HaloUnit", area={"LUT": 8000.0, "FF": 10000.0},
                 ins=["hx"], outs=[S("hy", 256)])
        b.invoke("HaloSink", area={"LUT": 2000.0}, ins=["hy"])
    return b.build()


def spmm(use_async: bool = True) -> TaskGraph:
    """Sextans SpMM: 29 HBM channels, URAM-heavy (Table 8)."""
    b = TaskGraphBuilder("spmm")
    PEG = {"LUT": 52000.0, "FF": 60000.0, "BRAM": 306.0, "URAM": 64.0,
           "DSP": 462.0}

    def S(name, width=512):
        b.stream(name, width=width)
        return name

    # 24 sparse-A channels feeding 8 PE groups, 2 dense-B, 2 C, 1 ctrl
    for g in range(8):
        S(f"bb{g}", 512)   # dense-B broadcast lanes (produced by BCast)
    for g in range(8):
        ins = []
        for k in range(3):
            b.invoke("AIn", area=_io_area(use_async, hbm=True),
                     outs=[S(f"a{g}_{k}")])
            ins.append(f"a{g}_{k}")
        b.invoke("PEG", area=dict(PEG), ins=ins + [f"bb{g}"],
                 outs=[S(f"c{g}")])
    for j in range(2):
        b.invoke("BIn", area=_io_area(use_async, hbm=True),
                 outs=[S(f"b{j}")])
        b.invoke("BCast", area={"LUT": 6000.0, "FF": 8000.0},
                 ins=[f"b{j}"], outs=[f"bb{4*j+i}" for i in range(4)])
    for j in range(2):
        b.invoke("CMerge", area={"LUT": 9000.0, "FF": 12000.0},
                 ins=[f"c{4*j+i}" for i in range(4)], outs=[S(f"cm{j}")])
        b.invoke("COut", area=_io_area(use_async, hbm=True), ins=[f"cm{j}"])
    b.invoke("CtrlIO", area=_io_area(use_async, hbm=True), outs=[S("ct", 64)])
    b.invoke("Ctrl", area={"LUT": 4000.0}, ins=["ct"])
    return b.build()


def spmv(n_ch: int, use_async: bool = True) -> TaskGraph:
    """Serpens SpMV: A16 = 20 channels, A24 = 28 channels (Table 8)."""
    n_a = 16 if n_ch == 20 else 24
    b = TaskGraphBuilder(f"spmv_a{n_a}")
    PE = {"LUT": 13000.0, "FF": 16000.0, "BRAM": 80.0, "URAM": 16.0,
          "DSP": 46.0}

    def S(name, width=512):
        b.stream(name, width=width)
        return name

    for i in range(n_a):
        S(f"xb{i}", 256)   # x broadcast lanes (produced by XBcast)
    for i in range(n_a):
        b.invoke("AIn", area=_io_area(use_async, hbm=True), outs=[S(f"a{i}")])
        b.invoke("PE", area=dict(PE), ins=[f"a{i}", f"xb{i}"],
                 outs=[S(f"y{i}", 256)])
    b.invoke("XIn", area=_io_area(use_async, hbm=True), outs=[S("x", 512)])
    b.invoke("XBcast", area={"LUT": 7000.0, "FF": 9000.0}, ins=["x"],
             outs=[f"xb{i}" for i in range(n_a)])
    # adder tree into 3 result channels
    b.invoke("Tree", area={"LUT": 12000.0, "FF": 16000.0, "DSP": 64.0},
             ins=[f"y{i}" for i in range(n_a)],
             outs=[S(f"r{j}") for j in range(3)])
    for j in range(3):
        b.invoke("YOut", area=_io_area(use_async, hbm=True), ins=[f"r{j}"])
    return b.build()


# ---------------------------------------------------------------------------
# suites
# ---------------------------------------------------------------------------

def autobridge_suite() -> list[tuple[str, str, TaskGraph]]:
    """The 43 designs of §7.3: (name, board, graph)."""
    out = []
    for k in range(1, 9):
        out.append((f"stencil_x{k}", "u250", stencil(k)))
        out.append((f"stencil_x{k}", "u280", stencil(k)))
    for n in (2, 4, 6, 8, 10, 12, 14, 16):
        out.append((f"cnn_13x{n}", "u250", cnn(n)))
        out.append((f"cnn_13x{n}", "u280", cnn(n)))
    for n in (12, 16, 20, 24):
        out.append((f"gaussian_{n}", "u250", gaussian(n)))
        out.append((f"gaussian_{n}", "u280", gaussian(n)))
    out.append(("bucket_sort", "u280", bucket_sort()))
    out.append(("page_rank", "u280", page_rank()))
    out.append(("genome_x24", "u250", genome(24)))
    return out


def hbm_suite(use_async: bool = True) -> list[tuple[str, str, TaskGraph]]:
    """The §7.4 HBM designs (always U280)."""
    return [
        ("sasa_v1", "u280", sasa(1, use_async)),
        ("sasa_v2", "u280", sasa(2, use_async)),
        ("spmm", "u280", spmm(use_async)),
        ("spmv_a16", "u280", spmv(20, use_async)),
        ("spmv_a24", "u280", spmv(28, use_async)),
    ]
