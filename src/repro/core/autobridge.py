"""AutoBridge orchestrator: floorplan -> pipeline -> balance, with the
dependency-cycle feedback loop (paper Fig. 1 + §5.2).

``autobridge()`` is the end-to-end co-optimization entry point used by both
the FPGA reproduction and the TPU deployment:

    plan = autobridge(graph, grid)
    plan.floorplan.placement     # task -> slot
    plan.depth["stream"]         # total inserted buffering (lat + balance)

If the balancer reports a pipelined dependency cycle, the cycle's tasks are
constrained into one slot and the floorplan is re-run (at most
``max_feedback`` times), exactly mirroring the paper's behaviour on the
page-rank benchmark.
"""
from __future__ import annotations

import dataclasses

from .balance import BalanceResult, CycleError, balance_graph
from .devicegrid import SlotGrid
from .floorplan import Floorplan, floorplan
from .graph import TaskGraph
from .ilp import InfeasibleError
from .pipelining import PipelineAssignment, assign_pipelining
from .simulate import SimJob, SimResult, simulate_batch


@dataclasses.dataclass
class Plan:
    graph: TaskGraph
    floorplan: Floorplan
    pipelining: PipelineAssignment
    balancing: BalanceResult
    #: total inserted depth per stream (pipelining + balancing)
    depth: dict[str, int]
    #: width-weighted total buffering overhead
    area_overhead: float
    feedback_rounds: int
    co_located: list[set[str]]
    #: streams demoted to latency-tolerant control as a cycle-breaking last
    #: resort (empty in the common case)
    demoted_streams: list[str] = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        return {
            "tasks": self.graph.num_tasks,
            "streams": self.graph.num_streams,
            "crossing_cost": self.floorplan.cost,
            "pipelined_streams": sum(1 for v in self.depth.values() if v),
            "area_overhead": self.area_overhead,
            "feedback_rounds": self.feedback_rounds,
        }

    @property
    def sim_extra_capacity(self) -> dict[str, int]:
        """Almost-full FIFO headroom for simulating this plan: the
        round-trip term (2 per inserted register level, paper Fig. 10).
        The plan owns this term — ``simulate()`` adds no implicit
        headroom."""
        return {name: 2 * d for name, d in self.depth.items()}

    def sim_job(self) -> SimJob:
        """The pipelined+balanced design as a ``simulate_batch`` job."""
        return SimJob(self.graph, latency=dict(self.depth),
                      extra_capacity=self.sim_extra_capacity)

    def verify_throughput(self, *, firings: int = 200,
                          max_cycles: int | None = None,
                          ) -> tuple[SimResult, SimResult]:
        """Simulate the design before and after co-optimization (paper §5's
        throughput theorem): returns ``(base, optimized)``.  A correct plan
        never deadlocks and adds only fill/drain skew to the cycle count."""
        base, opt = simulate_batch(
            [SimJob(self.graph), self.sim_job()],
            firings=firings, max_cycles=max_cycles)
        return base, opt


def autobridge(graph: TaskGraph, grid: SlotGrid, *,
               max_util: float | None = None,
               same_slot: list[set[str]] = (),
               seed: int = 0,
               exact_threshold: int = 22,
               n_starts: int = 8,
               max_feedback: int = 8,
               time_limit_s: float = 6.0,
               row_weight: float = 1.0,
               col_weight: float = 1.0,
               depth_scale: float = 1.0) -> Plan:
    # co-optimization knobs beyond max-util (joint design-space search,
    # §6.3 generalized): realized as a scaled working grid, so the whole
    # floorplan->pipeline->balance chain sees consistent weights/depths.
    grid = grid.with_knobs(row_weight=row_weight, col_weight=col_weight,
                           depth_scale=depth_scale)
    co_located: list[set[str]] = [set(g) for g in same_slot]
    demoted: set[str] = set()      # streams demoted to control (last resort)
    pending_cycle: set[str] | None = None
    for round_ in range(max_feedback + 1):
        try:
            fp = floorplan(graph, grid, max_util=max_util,
                           same_slot=co_located, seed=seed,
                           exact_threshold=exact_threshold,
                           n_starts=n_starts, time_limit_s=time_limit_s)
        except InfeasibleError:
            if pending_cycle is None:
                raise
            # Co-locating the cycle made the floorplan infeasible (merged
            # group too big for any slot).  Fall back: the cycle must close
            # through a latency-tolerant handshake — demote its narrowest
            # stream to a control stream and un-merge.
            co_located = [g for g in co_located if g != pending_cycle]
            cyc_streams = [s for s in graph.streams
                           if s.src in pending_cycle and s.dst in pending_cycle
                           and not s.control]
            if not cyc_streams:
                raise
            narrowest = min(cyc_streams, key=lambda s: s.width)
            narrowest.control = True
            demoted.add(narrowest.name)
            pending_cycle = None
            continue
        pending_cycle = None
        pa = assign_pipelining(graph, fp)
        try:
            bal = balance_graph(graph, pa.lat)
        except CycleError as err:
            if round_ == max_feedback:
                raise InfeasibleError(
                    f"could not break pipelined cycle after {round_} rounds: "
                    f"{err.cycle}") from err
            # paper §5.2: constrain the cycle's vertices into the same slot
            # and re-generate the floorplan.
            cyc = set(err.cycle) & set(graph.tasks)
            new_groups: list[set[str]] = []
            for g in co_located:
                if g & cyc:
                    cyc |= g
                else:
                    new_groups.append(g)
            new_groups.append(cyc)
            co_located = new_groups
            pending_cycle = cyc
            continue
        depth = {name: pa.lat[name] + bal.balance[name] for name in pa.lat}
        width = {s.name: s.width for s in graph.streams}
        overhead = sum(d * width[n] for n, d in depth.items())
        return Plan(graph=graph, floorplan=fp, pipelining=pa, balancing=bal,
                    depth=depth, area_overhead=overhead,
                    feedback_rounds=round_, co_located=co_located,
                    demoted_streams=sorted(demoted))
    raise AssertionError("unreachable")
