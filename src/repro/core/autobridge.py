"""AutoBridge orchestrator: floorplan -> pipeline -> balance, with the
dependency-cycle feedback loop (paper Fig. 1 + §5.2).

``autobridge()`` is the end-to-end co-optimization entry point used by both
the FPGA reproduction and the TPU deployment:

    plan = autobridge(graph, grid)
    plan.floorplan.placement     # task -> slot
    plan.depth["stream"]         # total inserted buffering (lat + balance)

If the balancer reports a pipelined dependency cycle, the cycle's tasks are
constrained into one slot and the floorplan is re-run (at most
``max_feedback`` times), exactly mirroring the paper's behaviour on the
page-rank benchmark.

Floorplan memoization
---------------------
The partitioning ILP is the dominant per-point cost of a design-space
sweep (the AutoBridge observation the paper builds on), and converging
searches revisit knob configurations on purpose: refine rounds re-anchor
on the incumbent frontier, ``sweep_backends`` re-searches the same graph
per device grid, and depth-scale variants share a floorplan outright.
``FloorplanCache`` memoizes ``floorplan()`` results by everything the ILP
actually depends on — graph topology/areas/widths, grid shape/capacities/
boundary *weights* (pipeline depths and physical delays are irrelevant to
the partitioning objective), max-util, co-location constraints and solver
knobs — so re-landing on a solved configuration costs a dict lookup.
``floorplan_counts()`` mirrors ``simulate.engine_counts()``: global
counters benchmarks and the CI regression gate read to *prove* the
memoization actually fired instead of silently re-solving.
"""
from __future__ import annotations

import dataclasses

from .balance import BalanceResult, CycleError, balance_graph
from .devicegrid import SlotGrid
from .floorplan import Floorplan, floorplan
from .graph import TaskGraph
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .ilp import (InfeasibleError, merge_solve_counts, reset_solve_counts,
                  solve_counts)
from .pipelining import PipelineAssignment, assign_pipelining
from .simulate import SimJob, SimResult, simulate_batch

# Floorplan solves / cache hits since the last reset (module-global, like
# the simulator's engine counters): ``solved`` counts actual ILP-backed
# ``floorplan()`` runs, ``cache_hits`` counts solves a ``FloorplanCache``
# answered from memory.  ``floorplan_counts()`` adds the bipartition-solver
# invocation count from ``ilp`` so a sweep can report exactly how many ILPs
# it paid for versus how many points it evaluated.
_FP_COUNTS = _metrics.group(
    "floorplan", {"solved": 0, "cache_hits": 0, "merge_conflicts": 0})


def reset_floorplan_counts() -> None:
    """Zero the global floorplan solve/cache-hit counters (and the
    underlying bipartition-solver counter)."""
    _FP_COUNTS.reset()
    reset_solve_counts()


def floorplan_counts() -> dict[str, int]:
    """Snapshot of floorplan solves, cache hits and raw bipartition-solver
    invocations since the last reset."""
    out = dict(_FP_COUNTS)
    out["ilp_bipartitions"] = solve_counts()["bipartitions"]
    return out


def merge_floorplan_counts(delta: dict[str, int]) -> None:
    """Fold a worker process's counter deltas into this process's globals.

    The solve/cache-hit counters are module globals and therefore
    per-process: a ``floorplan()`` run inside a ``ProcessPoolExecutor``
    worker increments the *worker's* copy and the parent would silently
    read 0.  The worker pool (``repro.search.pool``) snapshots
    ``floorplan_counts()`` before and after each task and ships the
    difference back; merging it here keeps ``floorplan_counts()`` —
    and every benchmark/CI gate built on it — correct regardless of
    where the solve actually ran."""
    _FP_COUNTS["solved"] += int(delta.get("solved", 0))
    _FP_COUNTS["cache_hits"] += int(delta.get("cache_hits", 0))
    _FP_COUNTS["merge_conflicts"] += int(delta.get("merge_conflicts", 0))
    merge_solve_counts(delta.get("ilp_bipartitions", 0))


def _graph_signature(graph: TaskGraph) -> tuple:
    """Everything about the graph the floorplan ILP can observe: task names,
    resource vectors and pins, plus stream endpoints and widths (stream
    depth and control flags never enter the partitioning objective)."""
    return (
        tuple((n, tuple(sorted(t.area.items())), t.pinned)
              for n, t in graph.tasks.items()),
        tuple((s.name, s.src, s.dst, float(s.width)) for s in graph.streams),
    )


def _grid_signature(grid: SlotGrid) -> tuple:
    """Everything about the grid the floorplan ILP can observe: shape,
    capacities and boundary crossing *weights*.  Pipeline depths and
    physical delays only affect pipelining and the fmax surrogate, so
    depth-scale variants of one grid share a signature (and a floorplan)."""
    return (
        grid.rows, grid.cols,
        tuple(sorted(grid.base_capacity.items())),
        tuple(sorted((slot, tuple(sorted(caps.items())))
                     for slot, caps in grid.slot_caps.items())),
        tuple(b.weight for b in grid.row_boundaries),
        tuple(b.weight for b in grid.col_boundaries),
    )


def _entry_values_equal(a: tuple[str, object], b: tuple[str, object]) -> bool:
    """Do two cache entries agree?  ``floorplan()`` is deterministic, so
    two entries under one key must: ``merge`` and the disk store count a
    disagreement (``merge_conflicts``/``conflicts``) instead of letting
    first-writer-wins hide solver nondeterminism."""
    if a[0] != b[0]:
        return False
    if a[0] == "err":
        return a[1] == b[1]
    fa, fb = a[1], b[1]
    return (fa.placement == fb.placement
            and abs(fa.cost - fb.cost) <= 1e-9 * max(1.0, abs(fb.cost)))


class FloorplanCache:
    """Memoizes ``floorplan()`` solves (and infeasibility verdicts) across
    explorer calls, refine rounds and device sweeps.

    The key covers every input the ILP depends on; on a hit the stored
    ``Floorplan`` is returned with its ``grid`` swapped for the caller's
    working grid (same weights by construction — only pipeline depths may
    differ, and those are floorplan-irrelevant).  Infeasible configurations
    are cached too, so a sweep does not re-prove infeasibility per round.

    Instances are plain dict wrappers: share one across the calls whose
    solves you want deduplicated (``search_until_converged`` and
    ``sweep_backends`` do this automatically) and drop it to invalidate.
    """

    def __init__(self) -> None:
        self._entries: dict[tuple, tuple[str, object]] = {}
        self.hits = 0
        self.misses = 0
        #: ``merge``d duplicates whose values disagreed (should stay 0:
        #: ``floorplan()`` is deterministic — nonzero means nondeterminism)
        self.merge_conflicts = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return self._lookup(key) is not None

    # Storage hooks.  Every read goes through ``_lookup`` and every write
    # through ``_put`` so a subclass can add a second storage tier — the
    # disk-backed ``repro.search.store.DiskFloorplanStore`` overrides
    # exactly these two to fall through memory -> disk -> solve and to
    # persist new entries atomically.
    def _lookup(self, key: tuple) -> tuple[str, object] | None:
        return self._entries.get(key)

    def _put(self, key: tuple, value: tuple[str, object]) -> bool:
        """Store ``value`` unless ``key`` is already present (first writer
        wins); returns True when the entry was actually added."""
        if key in self._entries:
            return False
        self._entries[key] = value
        return True

    def stats(self) -> dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses}

    def record_infeasible(self, key: tuple, reason: str) -> None:
        """Pre-seed an infeasibility verdict under ``key`` (first writer
        wins, like ``merge``).  ``autobridge(check=True)`` and the worker
        pool use this to cache *static-analysis* verdicts so a doomed
        configuration is never re-analyzed — a later ``solve()`` or check
        under the same key raises the cached ``InfeasibleError``."""
        if self._lookup(key) is None:
            self._put(key, ("err", reason))

    def cached_error(self, key: tuple) -> str | None:
        """The cached infeasibility reason under ``key``, if any."""
        hit = self._lookup(key)
        return hit[1] if hit is not None and hit[0] == "err" else None

    def merge(self, other: "FloorplanCache") -> int:
        """Adopt ``other``'s entries (a worker's cache shipped back from a
        subprocess); returns the number of entries actually added.

        First writer wins on key conflicts, but a conflicting *value*
        under an existing key is never dropped silently: ``floorplan()``
        is deterministic, so two caches can only ever hold identical
        values under the same key — a disagreement ticks
        ``merge_conflicts`` (instance + global counter, surfaced in BENCH
        JSON and gated to 0 in CI) because it means solver nondeterminism
        corrupted the bit-identity contract.  ``hits``/``misses`` are NOT
        merged: they describe each object's own lookup history, and the
        global solve counters are merged separately via
        ``merge_floorplan_counts``."""
        added = 0
        for k, v in other._entries.items():
            cur = self._lookup(k)
            if cur is None:
                self._put(k, v)
                added += 1
            elif not _entry_values_equal(cur, v):
                self.merge_conflicts += 1
                _FP_COUNTS["merge_conflicts"] += 1
        return added

    @staticmethod
    def key(graph: TaskGraph, grid: SlotGrid, *, max_util: float,
            same_slot: list[set[str]], seed: int, exact_threshold: int,
            n_starts: int, time_limit_s: float) -> tuple:
        return (_graph_signature(graph), _grid_signature(grid),
                float(max_util),
                frozenset(frozenset(g) for g in same_slot),
                seed, exact_threshold, n_starts, float(time_limit_s))

    def solve(self, graph: TaskGraph, grid: SlotGrid, *, max_util: float,
              same_slot: list[set[str]], seed: int, exact_threshold: int,
              n_starts: int, time_limit_s: float) -> Floorplan:
        k = self.key(graph, grid, max_util=max_util, same_slot=same_slot,
                     seed=seed, exact_threshold=exact_threshold,
                     n_starts=n_starts, time_limit_s=time_limit_s)
        hit = self._lookup(k)
        if hit is not None:
            self.hits += 1
            _FP_COUNTS["cache_hits"] += 1
            kind, value = hit
            if kind == "err":
                raise InfeasibleError(value)
            return dataclasses.replace(value, grid=grid)
        self.misses += 1
        _FP_COUNTS["solved"] += 1
        with _trace.span("floorplan.ilp", tasks=len(graph.tasks)) as rec:
            try:
                fp = floorplan(graph, grid, max_util=max_util,
                               same_slot=same_slot, seed=seed,
                               exact_threshold=exact_threshold,
                               n_starts=n_starts, time_limit_s=time_limit_s)
            except InfeasibleError as err:
                if rec is not None:
                    rec["args"]["infeasible"] = True
                self._put(k, ("err", str(err)))
                raise
        self._put(k, ("ok", fp))
        return fp


def initial_floorplan_key(graph: TaskGraph, grid: SlotGrid, *,
                          max_util: float | None = None,
                          same_slot: list[set[str]] = (),
                          seed: int = 0,
                          exact_threshold: int = 22,
                          n_starts: int = 8,
                          time_limit_s: float = 6.0,
                          row_weight: float = 1.0,
                          col_weight: float = 1.0,
                          depth_scale: float = 1.0,
                          hbm_split: float = 0.5,
                          **_ignored) -> tuple:
    """The ``FloorplanCache`` key of ``autobridge``'s FIRST floorplan solve
    under these knobs (cycle-feedback rounds may add further keys, but a
    full run populates those too).  The worker pool uses this to skip
    dispatching points whose solve chain a previous run already cached.
    Defaults mirror ``autobridge``'s; unrelated kwargs are ignored so the
    explorer can forward its ``ab_kwargs`` verbatim."""
    grid = grid.with_hbm_binding(hbm_split).with_knobs(
        row_weight=row_weight, col_weight=col_weight,
        depth_scale=depth_scale)
    util = grid.max_util if max_util is None else max_util
    return FloorplanCache.key(graph, grid, max_util=util,
                              same_slot=[set(g) for g in same_slot],
                              seed=seed, exact_threshold=exact_threshold,
                              n_starts=n_starts, time_limit_s=time_limit_s)


@dataclasses.dataclass
class Plan:
    graph: TaskGraph
    floorplan: Floorplan
    pipelining: PipelineAssignment
    balancing: BalanceResult
    #: total inserted depth per stream (pipelining + balancing)
    depth: dict[str, int]
    #: width-weighted total buffering overhead
    area_overhead: float
    feedback_rounds: int
    co_located: list[set[str]]
    #: streams demoted to latency-tolerant control as a cycle-breaking last
    #: resort (empty in the common case)
    demoted_streams: list[str] = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        return {
            "tasks": self.graph.num_tasks,
            "streams": self.graph.num_streams,
            "crossing_cost": self.floorplan.cost,
            "pipelined_streams": sum(1 for v in self.depth.values() if v),
            "area_overhead": self.area_overhead,
            "feedback_rounds": self.feedback_rounds,
        }

    @property
    def sim_extra_capacity(self) -> dict[str, int]:
        """Almost-full FIFO headroom for simulating this plan: the
        round-trip term (2 per inserted register level, paper Fig. 10).
        The plan owns this term — ``simulate()`` adds no implicit
        headroom."""
        return {name: 2 * d for name, d in self.depth.items()}

    def sim_job(self) -> SimJob:
        """The pipelined+balanced design as a ``simulate_batch`` job."""
        return SimJob(self.graph, latency=dict(self.depth),
                      extra_capacity=self.sim_extra_capacity)

    def verify_throughput(self, *, firings: int = 200,
                          max_cycles: int | None = None,
                          ) -> tuple[SimResult, SimResult]:
        """Simulate the design before and after co-optimization (paper §5's
        throughput theorem): returns ``(base, optimized)``.  A correct plan
        never deadlocks and adds only fill/drain skew to the cycle count."""
        base, opt = simulate_batch(
            [SimJob(self.graph), self.sim_job()],
            firings=firings, max_cycles=max_cycles)
        return base, opt


def autobridge(graph: TaskGraph, grid: SlotGrid, *,
               max_util: float | None = None,
               same_slot: list[set[str]] = (),
               seed: int = 0,
               exact_threshold: int = 22,
               n_starts: int = 8,
               max_feedback: int = 8,
               time_limit_s: float = 6.0,
               row_weight: float = 1.0,
               col_weight: float = 1.0,
               depth_scale: float = 1.0,
               hbm_split: float = 0.5,
               cache: FloorplanCache | None = None,
               check: bool = False) -> Plan:
    # co-optimization knobs beyond max-util (joint design-space search,
    # §6.3 generalized): realized as a scaled working grid, so the whole
    # floorplan->pipeline->balance chain sees consistent weights/depths.
    # hbm_split re-binds the device's HBM channels across the channel
    # slots (SlotGrid.with_hbm_binding) — a different binding is a
    # different grid signature, so the cache keys variants apart.
    grid = grid.with_hbm_binding(hbm_split).with_knobs(
        row_weight=row_weight, col_weight=col_weight,
        depth_scale=depth_scale)
    util = grid.max_util if max_util is None else max_util

    if check:
        # Pre-flight structural verification (repro.analysis): a graph with
        # dangling streams / impossible pins can never floorplan — raise
        # (and cache) the verdict instead of burning an ILP solve.  Lazy
        # import: repro.analysis imports repro.core, so a module-level
        # import here would be circular.
        from repro.analysis import analyze
        from repro.analysis.report import _ANALYSIS_COUNTS
        key = None
        if cache is not None:
            key = FloorplanCache.key(graph, grid, max_util=util,
                                     same_slot=[set(g) for g in same_slot],
                                     seed=seed,
                                     exact_threshold=exact_threshold,
                                     n_starts=n_starts,
                                     time_limit_s=time_limit_s)
            cached = cache.cached_error(key)
            if cached is not None and cached.startswith("static analysis"):
                raise InfeasibleError(cached)   # verdict cached: no re-run
        rep = analyze(graph, grid=grid, passes=("structure",))
        if not rep.ok:
            msg = f"static analysis: {rep.error_summary()}"
            _ANALYSIS_COUNTS["infeasible"] += 1
            if cache is not None:
                cache.record_infeasible(key, msg)
            raise InfeasibleError(msg)

    def _floorplan(groups: list[set[str]]) -> Floorplan:
        if cache is not None:
            return cache.solve(graph, grid, max_util=util,
                               same_slot=groups, seed=seed,
                               exact_threshold=exact_threshold,
                               n_starts=n_starts, time_limit_s=time_limit_s)
        _FP_COUNTS["solved"] += 1
        with _trace.span("floorplan.ilp", tasks=len(graph.tasks)):
            return floorplan(graph, grid, max_util=util, same_slot=groups,
                             seed=seed, exact_threshold=exact_threshold,
                             n_starts=n_starts, time_limit_s=time_limit_s)

    co_located: list[set[str]] = [set(g) for g in same_slot]
    demoted: set[str] = set()      # streams demoted to control (last resort)
    pending_cycle: set[str] | None = None
    for round_ in range(max_feedback + 1):
        try:
            fp = _floorplan(co_located)
        except InfeasibleError:
            if pending_cycle is None:
                raise
            # Co-locating the cycle made the floorplan infeasible (merged
            # group too big for any slot).  Fall back: the cycle must close
            # through a latency-tolerant handshake — demote its narrowest
            # stream to a control stream and un-merge.
            co_located = [g for g in co_located if g != pending_cycle]
            cyc_streams = [s for s in graph.streams
                           if s.src in pending_cycle and s.dst in pending_cycle
                           and not s.control]
            if not cyc_streams:
                raise
            narrowest = min(cyc_streams, key=lambda s: s.width)
            narrowest.control = True
            demoted.add(narrowest.name)
            pending_cycle = None
            continue
        pending_cycle = None
        pa = assign_pipelining(graph, fp)
        try:
            bal = balance_graph(graph, pa.lat)
        except CycleError as err:
            if round_ == max_feedback:
                raise InfeasibleError(
                    f"could not break pipelined cycle after {round_} rounds: "
                    f"{err.cycle}") from err
            # paper §5.2: constrain the cycle's vertices into the same slot
            # and re-generate the floorplan.
            cyc = set(err.cycle) & set(graph.tasks)
            new_groups: list[set[str]] = []
            for g in co_located:
                if g & cyc:
                    cyc |= g
                else:
                    new_groups.append(g)
            new_groups.append(cyc)
            co_located = new_groups
            pending_cycle = cyc
            continue
        depth = {name: pa.lat[name] + bal.balance[name] for name in pa.lat}
        width = {s.name: s.width for s in graph.streams}
        overhead = sum(d * width[n] for n, d in depth.items())
        return Plan(graph=graph, floorplan=fp, pipelining=pa, balancing=bal,
                    depth=depth, area_overhead=overhead,
                    feedback_rounds=round_, co_located=co_located,
                    demoted_streams=sorted(demoted))
    raise AssertionError("unreachable")
