"""Coarse-grained floorplanning by iterative global bipartitioning (paper §4).

The device is an R x C grid of slots (``SlotGrid``).  Starting from every
task in one super-slot spanning the whole grid, we repeatedly split all
current slots in half along one dimension, solving a single global ILP per
iteration (paper §4.3: considering all slots together is what makes the
assignment exact — tightly-connected tasks in different slots still pull on
each other).

Generalizations over the paper (all backwards compatible):
  * boundary *weights*: the cost of crossing a boundary is configurable per
    boundary (pod/DCN boundaries cost more than ICI boundaries on TPU; with
    unit weights the objective is exactly Formula (1));
  * non-power-of-two grids (U280 is 2 x 3): splits may be uneven, handled by
    per-vertex coordinate coefficients in the edge cost;
  * co-location (same-slot) constraints, used by the latency balancer's
    dependency-cycle feedback (paper §5.2) — implemented by merging vertices
    before partitioning;
  * HBM-channel binding (paper §6.2): channels are just another resource
    that only boundary-adjacent slots own (``SlotGrid.slot_caps``).
"""
from __future__ import annotations

import dataclasses

from .devicegrid import SlotGrid
from .graph import TaskGraph, area_add
from .ilp import BipartitionProblem, Edge, InfeasibleError, solve_bipartition


@dataclasses.dataclass
class Floorplan:
    grid: SlotGrid
    placement: dict[str, tuple[int, int]]      # task -> (row, col)
    cost: float                                # weighted crossing cost
    iteration_stats: list[dict]
    max_util: float
    #: per-slot resource loads {slot: {res: used}}
    slot_loads: dict[tuple[int, int], dict[str, float]]

    def utilization(self) -> dict[tuple[int, int], dict[str, float]]:
        out = {}
        for slot, load in self.slot_loads.items():
            cap = dict(self.grid.base_capacity)
            cap.update(self.grid.slot_caps.get(slot, {}))
            util: dict[str, float] = {}
            for k, v in load.items():
                if k not in cap:
                    continue
                if cap[k]:
                    util[k] = v / cap[k]
                else:
                    # nonzero load on a zero-capacity resource is overflow,
                    # not 0% utilization — surface it instead of hiding it.
                    util[k] = float("inf") if v > 0 else 0.0
            out[slot] = util
        return out

    def crossings(self, graph: TaskGraph) -> dict[str, int]:
        """Unweighted boundary crossings per stream (for pipelining)."""
        out = {}
        for s in graph.streams:
            a, b = self.placement[s.src], self.placement[s.dst]
            out[s.name] = abs(a[0] - b[0]) + abs(a[1] - b[1])
        return out


class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, x: int) -> int:
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[rb] = ra


def _wcoord(bounds: list[float], lo: int, hi: int) -> float:
    """Representative weighted coordinate of a slot range [lo, hi):
    midpoint in cumulative-boundary-weight space."""
    return 0.5 * (bounds[lo] + bounds[hi - 1])


def floorplan(graph: TaskGraph, grid: SlotGrid, *,
              max_util: float | None = None,
              same_slot: list[set[str]] = (),
              seed: int = 0,
              exact_threshold: int = 22,
              n_starts: int = 8,
              time_limit_s: float = 6.0,
              retries: int = 3) -> Floorplan:
    """Assign every task to one slot of ``grid``.

    Raises ``InfeasibleError`` if the design cannot fit under ``max_util``
    (the analogue of an unroutable design; the explorer reacts by sweeping
    the knob, paper §6.3).  Top-down splitting can occasionally paint itself
    into a corner (an early co-optimal but skewed cut starves a later
    split); the balanced tie-break makes this rare and ``retries`` reseeds
    the heuristic when it happens.
    """
    last_err: InfeasibleError | None = None
    # alternate split-dimension order across attempts: a row-first plan can
    # strand big tasks in a thin row that no column split can repack (and
    # vice versa)
    orders = ("auto", "col_first", "row_first")
    for attempt in range(max(retries, 1) * len(orders)):
        try:
            return _floorplan_once(
                graph, grid, max_util=max_util, same_slot=same_slot,
                seed=seed + 7919 * (attempt // len(orders)),
                exact_threshold=exact_threshold,
                n_starts=n_starts + 4 * (attempt // len(orders)),
                time_limit_s=time_limit_s,
                dim_order=orders[attempt % len(orders)])
        except InfeasibleError as err:
            last_err = err
    raise last_err


def _floorplan_once(graph: TaskGraph, grid: SlotGrid, *,
                    max_util: float | None, same_slot: list[set[str]],
                    seed: int, exact_threshold: int, n_starts: int,
                    time_limit_s: float, dim_order: str = "auto") -> Floorplan:
    util = grid.max_util if max_util is None else max_util
    names = list(graph.tasks)
    index = {n: i for i, n in enumerate(names)}

    # ---- merge same-slot groups (co-location constraints) ----------------
    uf = _UnionFind(len(names))
    for group in same_slot:
        members = [index[n] for n in group]
        for m in members[1:]:
            uf.union(members[0], m)
    root_of = [uf.find(i) for i in range(len(names))]
    roots = sorted(set(root_of))
    vid = {r: i for i, r in enumerate(roots)}         # merged-vertex ids
    mv_of_task = [vid[root_of[i]] for i in range(len(names))]
    nmv = len(roots)

    areas: list[dict[str, float]] = [{} for _ in range(nmv)]
    pinned_slot: list[tuple[int, int] | None] = [None] * nmv
    for i, n in enumerate(names):
        m = mv_of_task[i]
        areas[m] = area_add(areas[m], graph.tasks[n].area)
        p = graph.tasks[n].pinned
        if p is not None:
            if pinned_slot[m] is not None and pinned_slot[m] != p:
                raise InfeasibleError(
                    f"conflicting pins in co-located group of {n!r}")
            pinned_slot[m] = p

    medges: list[tuple[int, int, float]] = []
    for s in graph.streams:
        u, v = mv_of_task[index[s.src]], mv_of_task[index[s.dst]]
        if u != v:
            medges.append((u, v, float(s.width)))

    # cumulative boundary-weight coordinates (unit weights -> 0,1,2,...)
    rb = [0.0]
    for b in grid.row_boundaries:
        rb.append(rb[-1] + b.weight)
    cb = [0.0]
    for b in grid.col_boundaries:
        cb.append(cb[-1] + b.weight)

    # ---- iterative global splitting ---------------------------------------
    # each merged vertex carries its current slot range (half-open, in final
    # grid coordinates)
    row_rng = [(0, grid.rows)] * nmv
    col_rng = [(0, grid.cols)] * nmv
    stats: list[dict] = []
    it = 0
    while True:
        max_r = max((hi - lo) for lo, hi in row_rng) if nmv else 1
        max_c = max((hi - lo) for lo, hi in col_rng) if nmv else 1
        if max_r <= 1 and max_c <= 1:
            break
        if dim_order == "col_first":
            dim = "col" if max_c > 1 else "row"
        elif dim_order == "row_first":
            dim = "row" if max_r > 1 else "col"
        else:
            dim = "row" if max_r >= max_c else "col"
        bounds = rb if dim == "row" else cb

        # current slots = distinct (row_rng, col_rng) pairs
        slot_key = {}
        group = [0] * nmv
        for i in range(nmv):
            key = (row_rng[i], col_rng[i])
            if key not in slot_key:
                slot_key[key] = len(slot_key)
            group[i] = slot_key[key]
        ngroups = len(slot_key)

        # child ranges per group (split ranges of size>1; size-1 pass through)
        child_rngs: list[tuple[tuple[int, int], tuple[int, int]]] = [None] * ngroups
        cap0: list[dict] = [None] * ngroups
        cap1: list[dict] = [None] * ngroups
        slots0: list[int] = [0] * ngroups
        slots1: list[int] = [0] * ngroups
        for (rr, cc), g in slot_key.items():
            lo, hi = rr if dim == "row" else cc
            if hi - lo > 1:
                mid = (lo + hi + 1) // 2           # upper-half gets the extra
                c0, c1 = (lo, mid), (mid, hi)
            else:
                c0 = c1 = (lo, hi)
            child_rngs[g] = (c0, c1)

            def _cap(split_rng, rr=rr, cc=cc):
                tot: dict[str, float] = {}
                rows = range(*split_rng) if dim == "row" else range(*rr)
                cols = range(*cc) if dim == "row" else range(*split_rng)
                for r in rows:
                    for c in cols:
                        tot = area_add(tot, grid.capacity(r, c, util))
                return tot
            cap0[g] = _cap(c0)
            cap1[g] = _cap(c1)
            n_other = (cc[1] - cc[0]) if dim == "row" else (rr[1] - rr[0])
            slots0[g] = (c0[1] - c0[0]) * n_other
            slots1[g] = (c1[1] - c1[0]) * n_other

        # per-vertex coordinate model: coord(d) = m0 + d * (m1 - m0)
        m0 = [0.0] * nmv
        m1 = [0.0] * nmv
        pin: dict[int, int] = {}
        for i in range(nmv):
            g = group[i]
            c0, c1 = child_rngs[g]
            m0[i] = _wcoord(bounds, *c0)
            m1[i] = _wcoord(bounds, *c1)
            if c0 == c1:
                pin[i] = 0  # slot not splitting in this dim
            elif pinned_slot[i] is not None:
                target = pinned_slot[i][0] if dim == "row" else pinned_slot[i][1]
                if c0[0] <= target < c0[1]:
                    pin[i] = 0
                elif c1[0] <= target < c1[1]:
                    pin[i] = 1
                else:
                    raise InfeasibleError(
                        f"pin {pinned_slot[i]} outside current slot range")

        edges = [Edge(u=u, v=v, w=w,
                      k=m0[u] - m0[v],
                      a=m1[u] - m0[u],
                      b=-(m1[v] - m0[v]))
                 for (u, v, w) in medges]

        # granularity: a vertex is "big" if it exceeds half a leaf slot in
        # some soft resource (two of those can never share a slot)
        min_leaf = {}
        for r in range(grid.rows):
            for c in range(grid.cols):
                for k, v in grid.capacity(r, c, util).items():
                    if k.endswith("_channels"):
                        continue
                    min_leaf[k] = min(min_leaf.get(k, float("inf")), v)
        big = [any(v > 0.5 * min_leaf[k] for k, v in areas[i].items()
                   if k in min_leaf and min_leaf[k] > 0)
               for i in range(nmv)]

        prob = BipartitionProblem(areas=areas, group=group, cap0=cap0,
                                  cap1=cap1, edges=edges, pinned=pin,
                                  big=big, slots0=slots0, slots1=slots1)
        assign, cost, st = solve_bipartition(
            prob, exact_threshold=exact_threshold, n_starts=n_starts,
            seed=seed + it, time_limit_s=time_limit_s)
        st["dim"] = dim
        st["iteration"] = it
        stats.append(st)

        for i in range(nmv):
            c0, c1 = child_rngs[group[i]]
            new = c1 if assign[i] == 1 else c0
            if dim == "row":
                row_rng[i] = new
            else:
                col_rng[i] = new
        it += 1

    placement = {}
    for i, n in enumerate(names):
        m = mv_of_task[i]
        placement[n] = (row_rng[m][0], col_rng[m][0])

    cost = 0.0
    for s in graph.streams:
        cost += s.width * grid.crossing_weight(placement[s.src], placement[s.dst])

    slot_loads: dict[tuple[int, int], dict[str, float]] = {}
    for n, slot in placement.items():
        slot_loads[slot] = area_add(slot_loads.get(slot, {}), graph.tasks[n].area)

    # final capacity check (the iterative caps were aggregate; verify leaf)
    for slot, load in slot_loads.items():
        cap = grid.capacity(*slot, util)
        for k, v in load.items():
            if k in cap and v > cap[k] + 1e-9:
                raise InfeasibleError(
                    f"slot {slot} over capacity on {k}: {v:.1f} > {cap[k]:.1f}")

    return Floorplan(grid=grid, placement=placement, cost=cost,
                     iteration_stats=stats, max_util=util,
                     slot_loads=slot_loads)
