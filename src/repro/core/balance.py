"""Latency balancing of reconvergent paths (paper §5.2).

After the floorplan fixes the pipelining latency ``lat_e`` of every
cross-slot stream, we must add ``balance_e`` extra buffering so that *every
pair of reconvergent paths carries equal total latency* — the cut-set
pipelining condition that guarantees the dataflow throughput is unchanged.

Formulation (verbatim from the paper): integer potentials ``S_i`` per vertex
("maximum pipelining latency from v_i to the sink"), constraints

    S_i >= S_j + lat_ij          for every stream  i -> j
    balance_ij = S_i - S_j - lat_ij

minimize  sum_e width_e * balance_e.

This is a system of difference constraints (SDC): the constraint matrix is a
network matrix, the LP optimum is integral, and the LP dual is a
transshipment (min-cost flow) problem.  We solve it **exactly**:

  1. dual min-cost flow via ``networkx.network_simplex`` (supply
     ``c_i = sum w(out) - sum w(in)`` at each vertex, arc cost ``-lat_e``);
  2. primal potentials recovered by Bellman-Ford over the residual network
     (complementary slackness makes these optimal; we assert strong duality
     numerically).

Infeasibility <=> a dependency cycle with positive inserted latency
(``CycleError``) — the caller (autobridge) reacts by co-locating the cycle's
vertices and re-floorplanning, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses

import networkx as nx

from .graph import TaskGraph


class CycleError(RuntimeError):
    def __init__(self, cycle: list[str]):
        super().__init__(f"pipelined dependency cycle: {' -> '.join(cycle)}")
        self.cycle = cycle


@dataclasses.dataclass
class BalanceResult:
    #: per-stream added balancing latency (same order/keying as input edges)
    balance: dict[str, int]
    #: vertex potentials S (max pipelining latency to sink)
    potentials: dict[str, int]
    #: total area overhead  sum(width * balance)
    overhead: float
    #: LP objective == flow objective (strong duality check value)
    objective: float


def balance_latencies(edges: list[tuple[str, str, str, int, float]],
                      ) -> BalanceResult:
    """Balance a pipelined dataflow graph.

    edges: list of (name, src, dst, lat, width); lat = latency inserted by
    floorplan-aware pipelining, width = stream width (area cost per unit of
    added depth).
    """
    nodes: set[str] = set()
    for _, s, d, _, _ in edges:
        nodes.add(s)
        nodes.add(d)

    # supplies: c_i = sum w(out) - sum w(in); flow constraint out-in = c_i,
    # networkx demand is in-out = -c_i
    c: dict[str, float] = {n: 0.0 for n in nodes}
    for _, s, d, _, w in edges:
        c[s] += w
        c[d] -= w

    # Build flow graph with one midpoint node per edge so parallel streams
    # between the same task pair keep independent duals.
    G = nx.DiGraph()
    for n in nodes:
        G.add_node(n, demand=int(round(-c[n])))
    for name, s, d, lat, w in edges:
        m = ("__mid__", name)
        G.add_node(m, demand=0)
        G.add_edge(s, m, weight=-int(lat))
        G.add_edge(m, d, weight=0)

    try:
        flow_cost, flow = nx.network_simplex(G)
    except nx.NetworkXUnbounded:
        raise _find_cycle(edges)

    # Residual graph: forward arcs always (cost w), backward when f > 0.
    R = nx.DiGraph()
    R.add_nodes_from(G.nodes)
    for u, v, data in G.edges(data=True):
        wgt = data["weight"]
        R.add_edge(u, v, weight=wgt)
        if flow.get(u, {}).get(v, 0) > 0:
            # backward arc; parallel opposite arcs are fine in a DiGraph as
            # distinct (v,u) entries unless an edge v->u exists (cannot: all
            # arcs go through unique midpoints).
            R.add_edge(v, u, weight=-wgt)
    src = ("__src__",)
    R.add_node(src)
    for n in G.nodes:
        R.add_edge(src, n, weight=0)
    dist = nx.single_source_bellman_ford_path_length(R, src)

    S = {n: int(round(dist[n])) for n in nodes}
    # normalize each weakly-connected component to min 0
    U = nx.Graph()
    U.add_nodes_from(nodes)
    U.add_edges_from((s, d) for _, s, d, _, _ in edges)
    for comp in nx.connected_components(U):
        lo = min(S[n] for n in comp)
        for n in comp:
            S[n] -= lo

    balance: dict[str, int] = {}
    overhead = 0.0
    objective = 0.0
    for name, s, d, lat, w in edges:
        b = S[s] - S[d] - lat
        assert b >= 0, f"SDC violated on {name}: {S[s]} - {S[d]} < {lat}"
        balance[name] = int(b)
        overhead += w * b
        objective += w * b
    # strong duality: flow_cost = sum(-lat * f); primal obj = sum w*b =
    # sum w*(S_s - S_d) - sum w*lat ; both equal -(flow_cost) - sum(w*lat)
    # up to component normalization, which we skip asserting here and cover
    # in tests against brute force.
    return BalanceResult(balance=balance, potentials=S, overhead=overhead,
                         objective=objective)


def _find_cycle(edges) -> CycleError:
    """Locate a positive-latency cycle for the floorplan feedback loop."""
    H = nx.DiGraph()
    for name, s, d, lat, w in edges:
        # keep the max-latency arc per pair for detection purposes
        if H.has_edge(s, d):
            H[s][d]["weight"] = min(H[s][d]["weight"], -lat)
        else:
            H.add_edge(s, d, weight=-lat)
    for n in list(H.nodes):
        try:
            cyc = nx.find_negative_cycle(H, n)
            return CycleError(cyc)
        except nx.NetworkXError:
            continue
    # fallback: any directed cycle (all-zero-latency cycles are feasible, so
    # reaching here means numeric trouble; report any cycle)
    try:
        cyc = [u for u, _ in nx.find_cycle(H)]
        return CycleError(cyc + [cyc[0]])
    except nx.NetworkXNoCycle:
        return CycleError(["<unknown>"])


def balance_graph(graph: TaskGraph, lat: dict[str, int]) -> BalanceResult:
    """Convenience wrapper over a TaskGraph + per-stream latency map.

    Control streams (per-phase handshakes) tolerate latency and are excluded
    from the SDC; they report balance 0."""
    edges = [(s.name, s.src, s.dst, int(lat.get(s.name, 0)), float(s.width))
             for s in graph.streams if not s.control]
    res = balance_latencies(edges)
    for s in graph.streams:
        if s.control:
            res.balance[s.name] = 0
    return res
