"""Latency balancing of reconvergent paths (paper §5.2).

After the floorplan fixes the pipelining latency ``lat_e`` of every
cross-slot stream, we must add ``balance_e`` extra buffering so that *every
pair of reconvergent paths carries equal total latency* — the cut-set
pipelining condition that guarantees the dataflow throughput is unchanged.

Formulation (verbatim from the paper): integer potentials ``S_i`` per vertex
("maximum pipelining latency from v_i to the sink"), constraints

    S_i >= S_j + lat_ij          for every stream  i -> j
    balance_ij = S_i - S_j - lat_ij

minimize  sum_e width_e * balance_e.

This is a system of difference constraints (SDC): the constraint matrix is a
network matrix, the LP optimum is integral, and the LP dual is a
transshipment (min-cost flow) problem.  We solve it **exactly**:

  1. dual min-cost flow via ``networkx.network_simplex`` (supply
     ``c_i = sum w(out) - sum w(in)`` at each vertex, arc cost ``-lat_e``);
  2. primal potentials recovered by Bellman-Ford over the residual network
     (complementary slackness makes these optimal; we assert strong duality
     numerically).

Infeasibility <=> a dependency cycle with positive inserted latency
(``CycleError``) — the caller (autobridge) reacts by co-locating the cycle's
vertices and re-floorplanning, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction

import networkx as nx

from .graph import TaskGraph


def _integer_scale(widths: list[float], *, max_denominator: int = 10 ** 6,
                   max_scale: int = 10 ** 9) -> int:
    """Smallest multiplier turning every width into an (approximate)
    integer.  Exact for the common rational widths (0.5, 1.5, ...); falls
    back to a bounded scale for pathological floats."""
    scale = 1
    for w in widths:
        frac = Fraction(w).limit_denominator(max_denominator)
        scale = scale * frac.denominator // math.gcd(scale, frac.denominator)
        if scale > max_scale:
            return max_scale
    return scale


class CycleError(RuntimeError):
    def __init__(self, cycle: list[str]):
        super().__init__(f"pipelined dependency cycle: {' -> '.join(cycle)}")
        self.cycle = cycle


@dataclasses.dataclass
class BalanceResult:
    #: per-stream added balancing latency (same order/keying as input edges)
    balance: dict[str, int]
    #: vertex potentials S (max pipelining latency to sink)
    potentials: dict[str, int]
    #: total area overhead  sum(width * balance)
    overhead: float
    #: LP objective == flow objective (strong duality check value)
    objective: float


def balance_latencies(edges: list[tuple[str, str, str, int, float]],
                      ) -> BalanceResult:
    """Balance a pipelined dataflow graph.

    edges: list of (name, src, dst, lat, width); lat = latency inserted by
    floorplan-aware pipelining, width = stream width (area cost per unit of
    added depth).
    """
    nodes: set[str] = set()
    for _, s, d, _, _ in edges:
        nodes.add(s)
        nodes.add(d)

    # SDC infeasibility <=> a dependency cycle with positive total inserted
    # latency.  Detect it up front (Bellman-Ford negative-cycle search) so
    # the feedback loop always gets a concrete cycle to co-locate, instead
    # of relying on network_simplex's unboundedness heuristic.
    cyc = _positive_lat_cycle(edges)
    if cyc is not None:
        raise CycleError(cyc)

    if all(lat == 0 for _, _, _, lat, _ in edges):
        # nothing pipelined: the zero solution is trivially optimal
        return BalanceResult(balance={name: 0 for name, *_ in edges},
                             potentials={n: 0 for n in nodes},
                             overhead=0.0, objective=0.0)

    # supplies: c_i = sum w(out) - sum w(in); flow constraint out-in = c_i,
    # networkx demand is in-out = -c_i.  network_simplex needs *integer*
    # demands that sum to zero exactly, so scale all widths by the LCM of
    # their denominators first — rounding each node independently (as an
    # earlier revision did) can leave fractional widths like 0.5 with a
    # nonzero demand total (NetworkXUnfeasible) or silently move the
    # optimum.  The scale factor multiplies every supply uniformly, so the
    # dual potentials (and hence the balance solution) are unchanged.
    scale = _integer_scale([w for _, _, _, _, w in edges])
    c: dict[str, int] = {n: 0 for n in nodes}
    for _, s, d, _, w in edges:
        wi = int(round(w * scale))
        c[s] += wi
        c[d] -= wi

    # network_simplex flags "unbounded" whenever some arc carries flow
    # >= faux_inf/2 with faux_inf = 3*max(sum|weights|, max|demand|).  Our
    # width-derived demands can dwarf the latency weights, so a legitimate
    # flow on a wide design used to trip a *false* negative-cycle report
    # (CycleError "<unknown>" on cnn/gaussian).  Scale the arc costs by K
    # so sum|weights| >= total supply: with infinite capacities a basic
    # solution routes at most the total supply through any arc, which is
    # then < faux_inf/2.  The duals scale by exactly K (every residual
    # weight is a multiple of K), undone when recovering S.
    supply = sum(v for v in c.values() if v > 0)
    lat_sum = sum(lat for _, _, _, lat, _ in edges)
    K = max(1, -(-supply // lat_sum))          # ceil(supply / lat_sum)

    # Build flow graph with one midpoint node per edge so parallel streams
    # between the same task pair keep independent duals.
    G = nx.DiGraph()
    for n in nodes:
        G.add_node(n, demand=-c[n])
    for name, s, d, lat, _w in edges:
        m = ("__mid__", name)
        G.add_node(m, demand=0)
        G.add_edge(s, m, weight=-int(lat) * K)
        G.add_edge(m, d, weight=0)

    try:
        flow_cost, flow = nx.network_simplex(G)
    except nx.NetworkXUnbounded:
        raise _find_cycle(edges) from None

    # Residual graph: forward arcs always (cost w), backward when f > 0.
    R = nx.DiGraph()
    R.add_nodes_from(G.nodes)
    for u, v, data in G.edges(data=True):
        wgt = data["weight"]
        R.add_edge(u, v, weight=wgt)
        if flow.get(u, {}).get(v, 0) > 0:
            # backward arc; parallel opposite arcs are fine in a DiGraph as
            # distinct (v,u) entries unless an edge v->u exists (cannot: all
            # arcs go through unique midpoints).
            R.add_edge(v, u, weight=-wgt)
    src = ("__src__",)
    R.add_node(src)
    for n in G.nodes:
        R.add_edge(src, n, weight=0)
    try:
        dist = nx.single_source_bellman_ford_path_length(R, src)
    except nx.NetworkXUnbounded:      # defensive: residual negative cycle
        raise _find_cycle(edges) from None

    S = {n: int(round(dist[n] / K)) for n in nodes}
    # normalize each weakly-connected component to min 0
    U = nx.Graph()
    U.add_nodes_from(nodes)
    U.add_edges_from((s, d) for _, s, d, _, _ in edges)
    for comp in nx.connected_components(U):
        lo = min(S[n] for n in comp)
        for n in comp:
            S[n] -= lo

    balance: dict[str, int] = {}
    overhead = 0.0
    objective = 0.0
    for name, s, d, lat, w in edges:
        b = S[s] - S[d] - lat
        assert b >= 0, f"SDC violated on {name}: {S[s]} - {S[d]} < {lat}"
        balance[name] = int(b)
        overhead += w * b
        objective += w * b
    # strong duality: flow_cost = sum(-lat * f); primal obj = sum w*b =
    # sum w*(S_s - S_d) - sum w*lat ; both equal -(flow_cost) - sum(w*lat)
    # up to component normalization, which we skip asserting here and cover
    # in tests against brute force.
    return BalanceResult(balance=balance, potentials=S, overhead=overhead,
                         objective=objective)


def _positive_lat_cycle(edges) -> list[str] | None:
    """Find a dependency cycle with positive total inserted latency (the
    SDC-infeasibility witness), or None.  One Bellman-Ford negative-cycle
    search from a super-source reaching every vertex."""
    H = nx.DiGraph()
    for _name, s, d, lat, _w in edges:
        # keep the max-latency arc per pair for detection purposes
        if H.has_edge(s, d):
            H[s][d]["weight"] = min(H[s][d]["weight"], -lat)
        else:
            H.add_edge(s, d, weight=-lat)
    src = ("__cycsrc__",)
    H.add_node(src)
    for n in list(H.nodes):
        if n != src:
            H.add_edge(src, n, weight=0)
    try:
        cyc = nx.find_negative_cycle(H, src)
    except nx.NetworkXError:
        return None
    return [n for n in cyc if n != src]


def _find_cycle(edges) -> CycleError:
    """Locate a positive-latency cycle for the floorplan feedback loop."""
    cyc = _positive_lat_cycle(edges)
    if cyc is not None:
        return CycleError(cyc)
    # fallback: any directed cycle (all-zero-latency cycles are feasible, so
    # reaching here means numeric trouble; report any cycle)
    H = nx.DiGraph()
    for _name, s, d, _lat, _w in edges:
        H.add_edge(s, d)
    try:
        cyc = [u for u, _ in nx.find_cycle(H)]
        return CycleError(cyc + [cyc[0]])
    except nx.NetworkXNoCycle:
        return CycleError(["<unknown>"])


def balance_graph(graph: TaskGraph, lat: dict[str, int]) -> BalanceResult:
    """Convenience wrapper over a TaskGraph + per-stream latency map.

    Control streams (per-phase handshakes) tolerate latency and are excluded
    from the SDC; they report balance 0."""
    edges = [(s.name, s.src, s.dst, int(lat.get(s.name, 0)), float(s.width))
             for s in graph.streams if not s.control]
    res = balance_latencies(edges)
    for s in graph.streams:
        if s.control:
            res.balance[s.name] = 0
    return res
