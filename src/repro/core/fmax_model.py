"""Calibrated physical-design surrogate (the "modeled Vivado").

The paper evaluates against AMD/Xilinx Vivado, which we cannot run offline.
This module is an explicit surrogate with the same qualitative behaviour,
calibrated against the paper's §7 tables.  It is used *identically* for the
baseline and the TAPA flow — only placement/pipelining inputs differ — so
relative gains measure our algorithms, not the surrogate.

Baseline flow model (``packed_placement``): the default tool packs connected
logic into as few dies as possible (paper Figs. 3-4), filling each slot to
``pack_util``; a task that almost fits is *split across the die boundary*
("one kernel may be divided among multiple regions", Fig. 4) — recorded as a
straddle.

Timing model (``analyze_timing``):
  T_slot     = t0 + alpha * u_slot^2                    (local congestion)
  T_straddle = T_slot + die_delay                       (unregistered nets
               of a split kernel cross the interposer)
  T_edge     = t0/2 + sum(boundary delays) + congestion (unpipelined stream)
  T_edge_pl  = t_reg + max_segment + t0/4               (pipelined stream)
  Fmax = min(ceiling, 1000 / worst)

Routability rules (calibrated to reproduce ~16/43 baseline failures):
  R1 placement failure: any slot utilization > 1.0
  R2 congestion failure: design uses >= ``dense_design_frac`` of the device
     AND some slot is packed beyond ``dense_slot_util``  (dense multi-die
     packing: big CNN/SODA/Gaussian configs)
  R3 HBM failure: bottom-row (channel-adjacent) slots over ``hbm_row_util``
     (HBM designs whose IO buffers crowd the bottom die)

Known deviations from the paper (documented in EXPERIMENTS.md): the exact
*set* of failing baselines differs (Vivado's routing failures are
capricious, e.g. CNN 13x16 routes while 13x10 does not); the surrogate fails
the largest/densest configurations instead.  Aggregate profile (counts and
averages) matches.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Mapping

from .devicegrid import SlotGrid
from .graph import TaskGraph, area_add


@dataclasses.dataclass(frozen=True)
class PhysicalModel:
    t0_ns: float = 1.8            # intrinsic logic+net delay (~550 MHz cap)
    alpha_ns: float = 2.9         # congestion coefficient (T = t0 + a*u^2)
    t_reg_ns: float = 0.35        # register hop
    edge_scale: float = 0.42      # average routed fraction of worst-case
                                  # wire+congestion delay on unregistered
                                  # crossings (calibration, Table 4 orig)
    fmax_ceiling_mhz: float = 500.0
    pack_util: float = 0.87       # baseline packing density
    straddle_min_frac: float = 0.30   # task splits if >=30% fits in the slot
    straddle_fail_luts: float = 35e3  # R2b: straddle overflow that unroutes
    dense_design_frac: float = 0.45   # R2: design size threshold
    dense_slot_util: float = 0.85     # R2: packed slot threshold
    hbm_row_util: float = 0.95        # R3
    hbm_clk_mhz: float = 450.0
    # FIFO buffering cost model: inserted stream buffering (registers +
    # FIFO storage) occupies real BRAM/LUT in the slots the stream touches.
    # Only applied when ``analyze_timing`` is given ``buffer_bits`` — the
    # profile-driven FIFO sizer credits reclaimed bits back through this.
    bram_bits: float = 18432.0        # one BRAM18K in bits
    fifo_lut_per_bit: float = 0.05    # LUTRAM + control overhead per bit

    def local_delay(self, util: float) -> float:
        return self.t0_ns + self.alpha_ns * max(util, 0.0) ** 2


@dataclasses.dataclass
class Placement:
    """Placement + straddle annotations (baseline flow only)."""
    slots: dict[str, tuple[int, int]]
    #: tasks split across a die boundary: name -> overflow fraction
    straddle: dict[str, float]


@dataclasses.dataclass
class TimingReport:
    fmax_mhz: float            # 0.0 => placement/routing failure
    routed: bool
    fail_reason: str | None
    critical_path_ns: float
    slot_util: dict[tuple[int, int], float]
    hbm_clk_mhz: float | None = None


def _slot_utils(graph: TaskGraph, grid: SlotGrid,
                placement: dict[str, tuple[int, int]],
                extra_load: dict[tuple[int, int], dict[str, float]] | None = None,
                ) -> dict[tuple[int, int], float]:
    loads: dict[tuple[int, int], dict[str, float]] = {}
    for name, slot in placement.items():
        loads[slot] = area_add(loads.get(slot, {}), graph.tasks[name].area)
    for slot, area in (extra_load or {}).items():
        loads[slot] = area_add(loads.get(slot, {}), area)
    utils = {}
    for slot, load in loads.items():
        cap = grid.capacity(*slot, 1.0)
        u = 0.0
        for k, v in load.items():
            if k in cap and cap[k] > 0 and not k.endswith("_channels"):
                u = max(u, v / cap[k])
        utils[slot] = u
    return utils


def _design_frac(graph: TaskGraph, grid: SlotGrid) -> float:
    tot = graph.total_area()
    frac = 0.0
    dev: dict[str, float] = {}
    for slot in grid.slots():
        dev = area_add(dev, grid.capacity(*slot, 1.0))
    for k, v in tot.items():
        if k in dev and dev[k] > 0 and not k.endswith("_channels"):
            frac = max(frac, v / dev[k])
    return frac


def analyze_timing(graph: TaskGraph, grid: SlotGrid,
                   placement: dict[str, tuple[int, int]] | Placement,
                   pipeline_lat: dict[str, int] | None = None,
                   model: PhysicalModel | None = None, *,
                   buffer_bits: Mapping[str, float] | None = None,
                   ) -> TimingReport:
    """Fmax/routability of a placed (optionally pipelined) design.

    buffer_bits — per-stream inserted buffering in bits (register depth +
    FIFO storage, width-weighted).  When given, each stream's bits are
    charged half to its producer slot and half to its consumer slot as
    BRAM (``bits / bram_bits``) and LUT (``bits * fifo_lut_per_bit``)
    load, so slot utilization — and through it fmax — reflects the real
    buffering footprint.  Profile-driven FIFO sizing reclaims capacity,
    lowers these charges, and therefore never scores a lower fmax than
    the uniform-headroom design (the charge is monotone in bits).
    """
    model = model or PhysicalModel()
    if isinstance(placement, Placement):
        slots_of = placement.slots
        straddle = placement.straddle
    else:
        slots_of = placement
        straddle = {}
    lat = pipeline_lat or {}
    extra_load: dict[tuple[int, int], dict[str, float]] | None = None
    if buffer_bits:
        extra_load = {}
        for s in graph.streams:
            bits = float(buffer_bits.get(s.name, 0.0))
            if bits <= 0:
                continue
            for slot in (slots_of[s.src], slots_of[s.dst]):
                load = extra_load.setdefault(slot, {})
                load["BRAM"] = load.get("BRAM", 0.0) \
                    + 0.5 * bits / model.bram_bits
                load["LUT"] = load.get("LUT", 0.0) \
                    + 0.5 * bits * model.fifo_lut_per_bit
    utils = _slot_utils(graph, grid, slots_of, extra_load)

    # ---- R1: placement ----------------------------------------------------
    for slot, u in utils.items():
        if u > 1.0 + 1e-9:
            return TimingReport(0.0, False, f"slot {slot} util {u:.2f} > 1.0",
                                float("inf"), utils)

    # ---- R2: dense multi-die congestion ------------------------------------
    # hot slots are only unroutable when unregistered streams cross into
    # them (TAPA pipelines every crossing, so its plans are immune; the
    # baseline flow never pipelines)
    frac = _design_frac(graph, grid)
    if frac >= model.dense_design_frac:
        hot = {s for s, u in utils.items() if u >= model.dense_slot_util}
        if hot:
            for s in graph.streams:
                a, b = slots_of[s.src], slots_of[s.dst]
                if a != b and lat.get(s.name, 0) <= 0 and (a in hot or b in hot):
                    return TimingReport(
                        0.0, False,
                        f"routing congestion: design {frac:.0%} of device, "
                        f"unregistered {s.name} into packed slot", float("inf"),
                        utils)
    # ---- R2b: a large kernel split across a die boundary is unroutable ----
    for name, frac_over in straddle.items():
        over = frac_over * graph.tasks[name].area.get("LUT", 0.0)
        if over > model.straddle_fail_luts:
            return TimingReport(
                0.0, False,
                f"routing congestion: kernel {name} split across dies "
                f"({over/1e3:.0f}K LUT overflow)", float("inf"), utils)

    # ---- R3: HBM bottom-row pressure ---------------------------------------
    hbm_slots = [s for s in grid.slots()
                 if grid.capacity(*s, 1.0).get("hbm_channels", 0) > 0]
    hbm = None
    if hbm_slots:
        ub = max(utils.get(s, 0.0) for s in hbm_slots)
        if ub > model.hbm_row_util:
            return TimingReport(0.0, False,
                                f"HBM row congestion: util {ub:.2f}",
                                float("inf"), utils)
        hbm = model.hbm_clk_mhz if ub <= 0.80 else max(
            250.0, model.hbm_clk_mhz * (1.0 - 0.55 * (ub - 0.80)))

    # ---- timing -------------------------------------------------------------
    worst = 0.0
    for u in utils.values():
        worst = max(worst, model.local_delay(u))
    # monolithic kernels carry long internal paths HLS cannot retime well
    # (paper 7.3: "avoid designing very large kernels")
    slot_lut = {s: grid.capacity(*s, 1.0).get("LUT", 0.0) for s in grid.slots()}
    for name, t in graph.tasks.items():
        cap = slot_lut.get(slots_of[name], 0.0)
        if cap > 0:
            u_task = t.area.get("LUT", 0.0) / cap
            worst = max(worst, model.t0_ns + model.alpha_ns * u_task)
    # straddling kernels: unregistered internal nets cross the interposer
    for name in straddle:
        slot = slots_of[name]
        d = model.local_delay(utils.get(slot, 0.0))
        d += grid.row_boundaries[min(slot[0], grid.rows - 2)].delay_ns \
            if grid.rows > 1 else 0.0
        worst = max(worst, d)
    for s in graph.streams:
        a, b = slots_of[s.src], slots_of[s.dst]
        if a == b:
            continue
        wire = grid.crossing_delay_ns(a, b)
        cong = 0.5 * ((model.local_delay(utils.get(a, 0.0)) - model.t0_ns)
                      + (model.local_delay(utils.get(b, 0.0)) - model.t0_ns))
        regs = lat.get(s.name, 0)
        if regs <= 0:
            t = 0.5 * model.t0_ns + model.edge_scale * (wire + cong)
        else:
            t = model.t_reg_ns + (wire + cong) / (regs + 1) + 0.25 * model.t0_ns
        worst = max(worst, t)

    fmax = min(model.fmax_ceiling_mhz, 1000.0 / worst)
    return TimingReport(round(fmax, 1), True, None, worst, utils, hbm)


def packed_placement(graph: TaskGraph, grid: SlotGrid,
                     model: PhysicalModel | None = None) -> Placement:
    """Baseline-flow placement: BFS from IO-pinned tasks, packing each slot
    to ``pack_util`` before spilling; almost-fitting tasks straddle."""
    model = model or PhysicalModel()
    order: list[str] = []
    seen: set[str] = set()
    roots = sorted(graph.tasks, key=lambda n: (graph.tasks[n].pinned is None, n))
    dq = deque()
    for root in roots:
        if root in seen:
            continue
        dq.append(root)
        seen.add(root)
        while dq:
            n = dq.popleft()
            order.append(n)
            for s in graph.out_streams(n):
                if s.dst not in seen:
                    seen.add(s.dst)
                    dq.append(s.dst)
            for s in graph.in_streams(n):
                if s.src not in seen:
                    seen.add(s.src)
                    dq.append(s.src)

    # wirelength-driven tools pull logic toward the IO it talks to: fill
    # from the slots owning the channel kinds this design uses
    kinds = {k for t in graph.tasks.values() for k in t.area
             if k.endswith("_channels")}
    anchors = [sl for sl in grid.slots()
               if any(grid.capacity(*sl, 1.0).get(k, 0) > 0 for k in kinds)]
    if not anchors:
        anchors = [(0, 0)]

    def slot_key(rc):
        d = min(abs(rc[0] - a[0]) + abs(rc[1] - a[1]) for a in anchors)
        return (d, rc[1], rc[0])

    slots = sorted(grid.slots(), key=slot_key)
    loads: dict[tuple[int, int], dict[str, float]] = {s: {} for s in slots}
    placement: dict[str, tuple[int, int]] = {}
    straddle: dict[str, float] = {}

    def headroom(slot, area, util):
        """Smallest remaining fraction of `area` that fits in `slot`."""
        cap = grid.capacity(*slot, 1.0)
        cur = loads[slot]
        frac = 1.0
        for k, v in area.items():
            if k in cap and v > 0:
                limit = cap[k] if k.endswith("_channels") else cap[k] * util
                frac = min(frac, max(0.0, (limit - cur.get(k, 0.0)) / v))
        return frac

    # strict fill order: pack the current slot full before moving on
    # (wirelength-driven tools keep connected logic together, Figs. 3-4);
    # an almost-fitting task is split across the boundary to the next slot.
    ptr = 0
    for n in order:
        t = graph.tasks[n]
        if t.pinned is not None:
            placement[n] = t.pinned
            loads[t.pinned] = area_add(loads[t.pinned], t.area)
            continue
        placed = False
        for i in range(ptr, len(slots)):
            f = headroom(slots[i], t.area, model.pack_util)
            if f >= 1.0 - 1e-9:
                placement[n] = slots[i]
                loads[slots[i]] = area_add(loads[slots[i]], t.area)
                ptr = i
                placed = True
                break
            if f >= model.straddle_min_frac and i + 1 < len(slots):
                slot, nxt = slots[i], slots[i + 1]
                placement[n] = slot
                loads[slot] = area_add(
                    loads[slot], {k: v * f for k, v in t.area.items()})
                loads[nxt] = area_add(
                    loads[nxt], {k: v * (1 - f) for k, v in t.area.items()})
                straddle[n] = 1.0 - f
                ptr = i + 1
                placed = True
                break
        if not placed:
            # spill to the least-loaded slot (may violate R1 -> fail)
            slot = min(slots, key=lambda s: sum(loads[s].values()))
            placement[n] = slot
            loads[slot] = area_add(loads[slot], t.area)
    return Placement(slots=placement, straddle=straddle)
