"""Cycle-accurate dataflow FIFO simulator.

Validates the paper's central throughput theorem (§5): pipelining every
cross-slot stream and *balancing* reconvergent paths leaves steady-state
throughput unchanged — total execution cycles grow only by the pipeline
fill/drain skew (paper Tables 4-7 report cycle deltas of ~10 out of 1e5).

Model: each task fires when every input FIFO has a token and every output
FIFO has space; a firing consumes/produces one token per stream.  A stream
has ``capacity`` slots and ``latency`` cycles (a written token becomes
visible to the consumer ``latency`` cycles later — the pipeline registers).
Tasks may have an initiation interval > 1.  This is the FSM/ap_ctrl
hand-shake abstraction of the paper's RTL at the granularity that matters
for inter-task throughput.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from .graph import TaskGraph


@dataclasses.dataclass
class SimResult:
    cycles: int
    fired: dict[str, int]
    deadlocked: bool


def simulate(graph: TaskGraph, *, firings: int,
             latency: dict[str, int] | None = None,
             extra_capacity: dict[str, int] | None = None,
             ii: dict[str, int] | None = None,
             max_cycles: int | None = None) -> SimResult:
    """Run until every non-detached task fired ``firings`` times.

    latency[s]        — pipeline registers on stream s (default 0)
    extra_capacity[s] — added FIFO depth beyond the declared one
    ii[t]             — initiation interval of task t (default 1)
    """
    latency = latency or {}
    extra_capacity = extra_capacity or {}
    ii = ii or {}
    max_cycles = max_cycles or firings * 64 + 10_000

    names = list(graph.tasks)
    # Control streams carry per-phase handshakes, not per-datum tokens:
    # exclude them from the steady-state token simulation.
    data = [s for s in graph.streams if not s.control]
    # FIFO state: queue of (visible_at_cycle) timestamps; occupancy counts
    # in-flight tokens against capacity (they occupy a slot from write).
    queues: dict[str, deque] = {s.name: deque() for s in data}
    cap = {s.name: s.depth + extra_capacity.get(s.name, 0)
           + 2 * latency.get(s.name, 0) for s in data}
    lat = {s.name: latency.get(s.name, 0) for s in data}

    ins = {n: [s.name for s in graph.in_streams(n) if not s.control]
           for n in names}
    outs = {n: [s.name for s in graph.out_streams(n) if not s.control]
            for n in names}
    next_free = {n: 0 for n in names}     # cycle at which task may fire again
    fired = {n: 0 for n in names}
    want = {n: firings for n in names}

    cycle = 0
    while cycle < max_cycles:
        if all(fired[n] >= want[n] for n in names if not graph.tasks[n].detached):
            return SimResult(cycles=cycle, fired=fired, deadlocked=False)
        progressed = False
        # evaluate firings against state at cycle start (synchronous update)
        plans = []
        for n in names:
            if fired[n] >= want[n] or next_free[n] > cycle:
                continue
            if any(not queues[s] or queues[s][0] > cycle for s in ins[n]):
                continue
            if any(len(queues[s]) >= cap[s] for s in outs[n]):
                continue
            plans.append(n)
        for n in plans:
            for s in ins[n]:
                queues[s].popleft()
            for s in outs[n]:
                queues[s].append(cycle + 1 + lat[s])
            fired[n] += 1
            next_free[n] = cycle + ii.get(n, 1)
            progressed = True
        cycle += 1
        in_flight = (any(q and q[0] > cycle - 1 for q in queues.values())
                     or any(next_free[n] > cycle - 1 for n in names))
        if not progressed and not in_flight:
            # nothing fired, nothing in flight, no II wait => deadlock
            if not all(fired[n] >= want[n] for n in names
                       if not graph.tasks[n].detached):
                return SimResult(cycles=cycle, fired=fired, deadlocked=True)
    return SimResult(cycles=cycle, fired=fired,
                     deadlocked=not all(fired[n] >= want[n] for n in names
                                        if not graph.tasks[n].detached))
