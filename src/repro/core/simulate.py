"""Dataflow FIFO simulators: event-driven (default), per-cycle (reference),
and a NumPy-vectorized batch engine for floorplan sweeps.

Validates the paper's central throughput theorem (§5): pipelining every
cross-slot stream and *balancing* reconvergent paths leaves steady-state
throughput unchanged — total execution cycles grow only by the pipeline
fill/drain skew (paper Tables 4-7 report cycle deltas of ~10 out of 1e5).

Model: each task fires when every input FIFO has a visible token and every
output FIFO has space; a firing consumes/produces one token per stream.  A
stream has ``capacity`` slots and ``latency`` cycles (a written token
becomes visible to the consumer ``latency`` cycles later — the pipeline
registers; it occupies a FIFO slot from the moment it is written).  Tasks
may have an initiation interval > 1.  This is the FSM/ap_ctrl hand-shake
abstraction of the paper's RTL at the granularity that matters for
inter-task throughput.

Capacity ownership
------------------
``capacity(s) = s.depth + extra_capacity[s]`` — nothing more.  The
almost-full round-trip headroom a pipelined stream needs to sustain full
throughput (paper Fig. 10) is owned by the *pipeliner*:
``assign_pipelining`` returns it as ``extra_depth = 2 * lat`` and
``Plan.sim_extra_capacity`` exposes it for simulation.  Earlier revisions
silently added another ``2 * latency`` inside ``simulate`` on top of the
pipeliner's term, handing callers 4x headroom that masked real almost-full
stalls; use ``pipeline_headroom`` if you need the term for an ad-hoc
latency map.

Engines
-------
* ``engine="event"`` (default): a ready-heap of (earliest-fire-cycle, task)
  events derived from FIFO token-visibility times, initiation intervals and
  almost-full back-pressure.  Wall-time scales with the number of firings,
  not the number of cycles — a task with II=8 costs one event per firing
  instead of 7 idle scans, and fill/drain phases cost nothing.
* ``engine="cycle"``: the original synchronous per-cycle scan, kept as the
  reference semantics; the event engine is cross-checked against it on
  randomized graphs in the test suite.
* ``simulate_batch``: many (graph, latency, capacity, II) variants at once.
  Jobs are grouped by topology signature and *padded* to the largest
  (task, stream) shape in the batch (the canonical layout lives in
  ``repro.kernels.padded_batch``), so one (V, T*, S*) array-sweep covers
  heterogeneous graphs (cross-design benchmark tables, multi-device
  sweeps) as well as the classic fixed-topology floorplan sweep.  Two
  array backends share that layout: the NumPy sweep (the bit-exact
  oracle) and a ``jax.jit``-compiled port (``repro.kernels.sim_sweep``)
  that ``backend="auto"`` promotes to whenever jax is importable.  The
  event engine is only used when NumPy is missing or ``backend="event"``
  is forced.

All engines implement the exact same synchronous-firing semantics: a task
fires at cycle t iff its constraints hold on the state produced by cycles
< t, so same-cycle firings are order-independent and all four engines
agree bit-for-bit on ``cycles``/``fired``/``deadlocked``.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from collections import deque
from typing import Mapping, Sequence

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .graph import TaskGraph

try:  # NumPy is a hard dependency of the repo, but keep the engine gated.
    import numpy as _np
except ModuleNotFoundError:  # pragma: no cover
    _np = None


@dataclasses.dataclass
class StreamProfile:
    """Observed FIFO pressure of one stream (event engine, paper §6.3 knob
    guidance): how full the FIFO actually ran, so callers can size capacity
    from measured occupancy instead of the uniform ``2*latency`` headroom.

    Occupancy semantics match the engine: a token occupies a slot from the
    cycle it is pushed through the cycle it is popped (the slot becomes
    reusable one cycle after the pop)."""
    name: str
    capacity: int
    #: maximum occupancy ever reached
    peak: int
    #: time-weighted mean occupancy over the simulated horizon
    mean: float
    #: cycles spent completely full (producer-visible back-pressure)
    full_cycles: int
    #: cycles spent empty (consumer starvation)
    empty_cycles: int
    #: occupancy histogram: level -> cycles spent at that level
    hist: dict[int, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SimResult:
    cycles: int
    fired: dict[str, int]
    deadlocked: bool
    #: scheduler steps the engine executed (events processed for the event
    #: engine; cycles scanned for the per-cycle engines).
    steps: int = 0
    engine: str = "event"
    #: per-stream occupancy/stall profiles (event engine with profile=True)
    profiles: dict[str, StreamProfile] | None = None


@dataclasses.dataclass
class SimJob:
    """One simulation variant for ``simulate_batch``."""
    graph: TaskGraph
    latency: dict[str, int] | None = None
    extra_capacity: dict[str, int] | None = None
    ii: dict[str, int] | None = None


# Python-level engine invocations since the last reset: one per event/cycle
# engine run, one per vectorized array-sweep (NumPy or jax-jitted).
# Benchmark drivers read these to prove (and CI to enforce) that a suite's
# simulation phase stayed batched instead of degrading to per-job Python
# loops.  "fallback" ticks whenever ``backend="auto"`` silently degrades
# below the backend it would normally pick (no NumPy, or knobs outside the
# jax sweep's int32 range) — CI gates assert it stays zero.
_ENGINE_INVOCATIONS = _metrics.group(
    "sim.engine",
    {"event": 0, "cycle": 0, "numpy": 0, "jax": 0, "fallback": 0})


def reset_engine_counts() -> None:
    """Zero the global engine-invocation counters."""
    _ENGINE_INVOCATIONS.reset()


def engine_counts() -> dict[str, int]:
    """Snapshot of engine invocations since the last reset."""
    return dict(_ENGINE_INVOCATIONS)


_JAX_READY: bool | None = None


def _jax_ready() -> bool:
    """True when the jitted sweep backend is usable (jax importable and
    NumPy present for the padded-layout builder).  Cached after the first
    probe; importing jax is the expensive part and happens at most once."""
    global _JAX_READY
    if _JAX_READY is None:
        if _np is None:
            _JAX_READY = False
        else:
            try:
                from repro.kernels.sim_sweep import HAVE_JAX
                _JAX_READY = bool(HAVE_JAX)
            except Exception:  # pragma: no cover - defensive
                _JAX_READY = False
    return _JAX_READY


def _static_check(graph: TaskGraph, mode: str, *, firings: int,
                  latency=None, extra_capacity=None, ii=None):
    """Pre-flight ``analyze()`` run for ``simulate(check=...)``.

    Imported lazily: ``repro.analysis`` imports ``repro.core.graph`` (and
    thereby this module, via the package __init__), so a module-level
    import here would be circular."""
    if mode not in ("warn", "raise"):
        raise ValueError(f"check must be None, 'warn' or 'raise', "
                         f"got {mode!r}")
    from repro.analysis import StaticAnalysisError, analyze
    rep = analyze(graph, latency=latency, extra_capacity=extra_capacity,
                  ii=ii, firings=firings)
    if rep.ok:
        return rep
    msg = f"static analysis of {graph.name!r} failed: {rep.error_summary()}"
    if mode == "raise":
        raise StaticAnalysisError(msg, rep)
    warnings.warn(msg, stacklevel=3)
    return rep


def pipeline_headroom(latency: Mapping[str, int]) -> dict[str, int]:
    """Almost-full round-trip FIFO headroom for a latency map (2 per register
    level, paper Fig. 10).  ``assign_pipelining`` computes this for plans;
    use this helper when simulating an ad-hoc latency assignment."""
    return {name: 2 * int(lat) for name, lat in latency.items()}


# ---------------------------------------------------------------------------
# shared model resolution
# ---------------------------------------------------------------------------

class _Model:
    """Graph + per-variant knobs resolved to plain indexed arrays."""

    def __init__(self, graph: TaskGraph, latency, extra_capacity, ii):
        latency = latency or {}
        extra_capacity = extra_capacity or {}
        ii = ii or {}
        self.graph = graph
        self.names = list(graph.tasks)
        # Control streams carry per-phase handshakes, not per-datum tokens:
        # exclude them from the steady-state token simulation.
        self.data = [s for s in graph.streams if not s.control]
        self.lat = {s.name: int(latency.get(s.name, 0)) for s in self.data}
        self.cap = {s.name: int(s.depth) + int(extra_capacity.get(s.name, 0))
                    for s in self.data}
        self.ii = {n: int(ii.get(n, 1)) for n in self.names}
        self.ins = {n: [s.name for s in graph.in_streams(n) if not s.control]
                    for n in self.names}
        self.outs = {n: [s.name for s in graph.out_streams(n) if not s.control]
                     for n in self.names}
        self.producer = {s.name: s.src for s in self.data}
        self.consumer = {s.name: s.dst for s in self.data}
        self.detached = {n: graph.tasks[n].detached for n in self.names}


# ---------------------------------------------------------------------------
# event-driven engine
# ---------------------------------------------------------------------------

def _profiles_from_logs(m: _Model, push_times: Mapping[str, list[int]],
                        pop_times: Mapping[str, list[int]],
                        cycles: int) -> dict[str, StreamProfile]:
    """Occupancy histograms from the engine's append-only push/pop logs.

    A token pushed at cycle u occupies a slot during cycles [u, pop_u]; the
    slot is visible as free again at pop_u + 1 (``qt[k] + 1`` in the engine).
    One merge-sweep per stream over the two already-sorted logs."""
    out: dict[str, StreamProfile] = {}
    horizon = max(cycles, 0)
    for s in m.data:
        name = s.name
        deltas: dict[int, int] = {}
        for t in push_times[name]:
            deltas[t] = deltas.get(t, 0) + 1
        for t in pop_times[name]:
            deltas[t + 1] = deltas.get(t + 1, 0) - 1
        hist: dict[int, int] = {}
        occ = peak = 0
        area = 0
        prev = 0
        for t in sorted(deltas):
            if t >= horizon:
                break
            if t > prev:
                span = t - prev
                hist[occ] = hist.get(occ, 0) + span
                area += occ * span
            occ += deltas[t]
            peak = max(peak, occ)
            prev = max(prev, t)
        if horizon > prev:
            span = horizon - prev
            hist[occ] = hist.get(occ, 0) + span
            area += occ * span
        cap = m.cap[name]
        out[name] = StreamProfile(
            name=name, capacity=cap, peak=peak,
            mean=area / horizon if horizon else 0.0,
            full_cycles=hist.get(cap, 0) if peak >= cap else 0,
            empty_cycles=hist.get(0, 0), hist=hist)
    return out


def _simulate_event(m: _Model, *, firings: int, max_cycles: int,
                    profile: bool = False) -> SimResult:
    _ENGINE_INVOCATIONS["event"] += 1
    names = m.names
    want = firings
    fired = {n: 0 for n in names}
    next_free = {n: 0 for n in names}
    # Append-only firing logs per stream: push/pop timestamps by token index.
    push_times: dict[str, list[int]] = {s.name: [] for s in m.data}
    pop_times: dict[str, list[int]] = {s.name: [] for s in m.data}

    def finish(res: SimResult) -> SimResult:
        if profile:
            res.profiles = _profiles_from_logs(m, push_times, pop_times,
                                               res.cycles)
        return res

    remaining = sum(1 for n in names if not m.detached[n] and want > 0)
    if remaining == 0:
        return finish(SimResult(cycles=0, fired=fired, deadlocked=False,
                                steps=0, engine="event"))

    def bound(n: str) -> int | None:
        """Earliest cycle at which task n's next firing can happen, or None
        if it is blocked on a token/pop that does not exist yet.  Once all
        constraints exist the bound is final for this firing index."""
        f = fired[n]
        if f >= want:
            return None
        t = next_free[n]
        for s in m.ins[n]:
            pt = push_times[s]
            if f >= len(pt):
                return None                       # token not produced yet
            t = max(t, pt[f] + 1 + m.lat[s])      # visibility time
        for s in m.outs[n]:
            k = f - m.cap[s]                      # pop freeing the slot
            if k >= 0:
                qt = pop_times[s]
                if k >= len(qt):
                    return None                   # consumer hasn't freed it
                t = max(t, qt[k] + 1)             # space visible next cycle
        return t

    heap: list[tuple[int, str]] = []
    pending: dict[str, int] = {}

    def schedule(n: str) -> None:
        b = bound(n)
        if b is None:
            return
        cur = pending.get(n)
        if cur is not None and cur <= b:
            return
        pending[n] = b
        heapq.heappush(heap, (b, n))

    for n in names:
        schedule(n)

    steps = 0
    end_time: int | None = None                   # last-completed fire cycle
    truncated = False
    while heap:
        t, n = heapq.heappop(heap)
        if end_time is not None and t > end_time:
            break
        if t >= max_cycles:
            truncated = True
            break
        if pending.get(n) != t:
            continue                              # stale duplicate
        del pending[n]
        b = bound(n)
        if b is None:
            continue
        if b > t:                                 # defensive; bounds final
            schedule(n)
            continue
        # fire at cycle t
        steps += 1
        for s in m.ins[n]:
            pop_times[s].append(t)
        for s in m.outs[n]:
            push_times[s].append(t)
        fired[n] += 1
        next_free[n] = t + max(m.ii[n], 1)
        if not m.detached[n] and fired[n] == want:
            remaining -= 1
            if remaining == 0:
                end_time = t                      # drain same-cycle events
        schedule(n)
        for s in m.outs[n]:
            schedule(m.consumer[s])
        for s in m.ins[n]:
            schedule(m.producer[s])

    if remaining == 0:
        return finish(SimResult(cycles=end_time + 1, fired=fired,
                                deadlocked=False, steps=steps, engine="event"))
    if truncated:
        return finish(SimResult(cycles=max_cycles, fired=fired,
                                deadlocked=True, steps=steps, engine="event"))
    # Deadlock: replicate the per-cycle engine's detection cycle — the first
    # quiet cycle with every FIFO head visible and every II window elapsed.
    # next_free >= last fire + 1 for every task that ever fired (II clamped
    # to >= 1), so its max already bounds the last firing cycle.
    t_dead = max(next_free.values())
    for s in m.data:
        pops, pushes = len(pop_times[s.name]), len(push_times[s.name])
        if pops < pushes:                          # head = oldest unpopped
            t_dead = max(t_dead,
                         push_times[s.name][pops] + 1 + m.lat[s.name])
    return finish(SimResult(cycles=min(t_dead + 1, max_cycles), fired=fired,
                            deadlocked=True, steps=steps, engine="event"))


# ---------------------------------------------------------------------------
# per-cycle reference engine (original semantics, kept for cross-checking)
# ---------------------------------------------------------------------------

def _simulate_cycle(m: _Model, *, firings: int, max_cycles: int) -> SimResult:
    _ENGINE_INVOCATIONS["cycle"] += 1
    names = m.names
    queues: dict[str, deque] = {s.name: deque() for s in m.data}
    cap, lat = m.cap, m.lat
    next_free = {n: 0 for n in names}
    fired = {n: 0 for n in names}
    want = {n: firings for n in names}

    cycle = 0
    while cycle < max_cycles:
        if all(fired[n] >= want[n] for n in names if not m.detached[n]):
            return SimResult(cycles=cycle, fired=fired, deadlocked=False,
                             steps=cycle, engine="cycle")
        progressed = False
        # evaluate firings against state at cycle start (synchronous update)
        plans = []
        for n in names:
            if fired[n] >= want[n] or next_free[n] > cycle:
                continue
            if any(not queues[s] or queues[s][0] > cycle for s in m.ins[n]):
                continue
            if any(len(queues[s]) >= cap[s] for s in m.outs[n]):
                continue
            plans.append(n)
        for n in plans:
            for s in m.ins[n]:
                queues[s].popleft()
            for s in m.outs[n]:
                queues[s].append(cycle + 1 + lat[s])
            fired[n] += 1
            next_free[n] = cycle + m.ii[n]
            progressed = True
        cycle += 1
        in_flight = (any(q and q[0] > cycle - 1 for q in queues.values())
                     or any(next_free[n] > cycle - 1 for n in names))
        # nothing fired, nothing in flight, no II wait => deadlock
        if (not progressed and not in_flight
                and not all(fired[n] >= want[n] for n in names
                            if not m.detached[n])):
            return SimResult(cycles=cycle, fired=fired, deadlocked=True,
                             steps=cycle, engine="cycle")
    return SimResult(cycles=cycle, fired=fired,
                     deadlocked=not all(fired[n] >= want[n] for n in names
                                        if not m.detached[n]),
                     steps=cycle, engine="cycle")


# ---------------------------------------------------------------------------
# public single-run API
# ---------------------------------------------------------------------------

def simulate(graph: TaskGraph, *, firings: int,
             latency: dict[str, int] | None = None,
             extra_capacity: dict[str, int] | None = None,
             ii: dict[str, int] | None = None,
             max_cycles: int | None = None,
             engine: str = "event",
             profile: bool = False,
             check: str | None = None) -> SimResult:
    """Run until every non-detached task fired ``firings`` times.

    latency[s]        — pipeline registers on stream s (default 0)
    extra_capacity[s] — added FIFO depth beyond the declared one; this is
                        the *only* capacity beyond ``Stream.depth`` (pass
                        ``assign_pipelining().extra_depth`` /
                        ``Plan.sim_extra_capacity`` / ``pipeline_headroom``
                        for the almost-full round-trip term)
    ii[t]             — initiation interval of task t (default 1)
    engine            — "event" (default, O(firings)) or "cycle" (reference)
    profile           — attach per-stream ``StreamProfile`` occupancy/stall
                        histograms to the result (event engine only; derived
                        from the push/pop logs, so near-free)
    check             — pre-flight static verification (``repro.analysis``)
                        under the same knobs: ``"warn"`` emits a warning
                        per failed graph, ``"raise"`` raises
                        ``StaticAnalysisError`` (carrying the ``Report``)
                        instead of running a doomed simulation.  ``None``
                        (default) skips the analyzer entirely.
    """
    if check is not None:
        _static_check(graph, check, firings=firings, latency=latency,
                      extra_capacity=extra_capacity, ii=ii)
    max_cycles = max_cycles or firings * 64 + 10_000
    m = _Model(graph, latency, extra_capacity, ii)
    if engine == "event":
        return _simulate_event(m, firings=firings, max_cycles=max_cycles,
                               profile=profile)
    if profile:
        raise ValueError("profile=True requires engine='event'")
    if engine in ("cycle", "legacy"):
        return _simulate_cycle(m, firings=firings, max_cycles=max_cycles)
    raise ValueError(f"unknown engine {engine!r}")


# ---------------------------------------------------------------------------
# batched API
# ---------------------------------------------------------------------------

def _topology_signature(graph: TaskGraph):
    return (tuple(graph.tasks),
            tuple((t.detached,) for t in graph.tasks.values()),
            tuple((s.name, s.src, s.dst, s.depth, s.control)
                  for s in graph.streams))


#: default ``simulate_batch`` byte budget for the padded array state —
#: generous enough that every in-repo suite stays a single array-sweep
#: (the CI gate depends on that), small enough that a thousand-design
#: batch cannot OOM the host on its (V, S*, H) push-history ring.
DEFAULT_MAX_BYTES = 1 << 30


def _job_bytes_estimate(jobs: Sequence[SimJob]) -> int:
    """Upper-bound bytes of padded per-job array state.

    Dominated by the (V, S*, H) cumulative-push ring; the remaining
    (V, S*)/(V, T*) int64/bool state is folded in as a few extra columns.
    Uses raw graph task/stream counts (>= the engine's post-filter counts)
    and the batch-max latency, so the estimate never undershoots."""
    t_max = max(len(j.graph.tasks) for j in jobs)
    s_max = max(len(j.graph.streams) for j in jobs)
    h = 2 + max((max(j.latency.values(), default=0) if j.latency else 0)
                for j in jobs)
    return 8 * (s_max * (h + 6) + 5 * t_max)


def simulate_batch(jobs: Sequence[SimJob | TaskGraph], *, firings: int,
                   max_cycles: int | None = None,
                   backend: str = "auto",
                   max_bytes: int | None = DEFAULT_MAX_BYTES,
                   check: str | None = None) -> list[SimResult]:
    """Simulate many (graph, latency, capacity, II) variants.

    ``jobs`` is a sequence of ``SimJob`` (bare ``TaskGraph``s are promoted
    to default jobs).  Jobs are grouped by topology signature; each group
    shares one set of task/stream index structures, and the groups are
    *padded* to the largest (task, stream) shape in the batch so a single
    synchronous array-sweep advances every job at once.  Padding rows are
    inert: phantom streams are attached to no task (they can never gate a
    firing) and phantom tasks are masked out of the firing rule and the
    termination/deadlock checks, so each job's results are exactly those of
    its own event simulation.

    backend — "auto" (default): the jax-jitted padded engine whenever jax
              is importable and every knob fits the sweep's int32 range;
              otherwise the padded NumPy engine whenever NumPy is present
              and there is more than one job; a lone job runs the event
              engine.  Every degradation below the expected rung (no
              NumPy at all, or int32-unsafe knobs with jax present) ticks
              ``engine_counts()["fallback"]`` and emits a warning.
              "jax": force the jitted sweep (``repro.kernels.sim_sweep``;
              raises when jax is missing or the knobs overflow int32).
              "numpy": force the NumPy array engine (works for any mix of
              topologies; raises only when NumPy itself is missing).
              "event": force per-job event simulation.
    max_bytes — byte budget for the padded array state (default 1 GiB,
              ``None`` = unlimited).  When the batch's padded allocation
              would exceed it, the batch is split into successive
              contiguous array-sweeps ("chunks") that each fit; results
              are identical to the unchunked run, and each chunk counts
              one ``numpy``/``jax`` engine invocation in
              ``engine_counts()`` — i.e. the counters report the chunk
              count.
    check   — pre-flight static verification per job (``repro.analysis``),
              same semantics as ``simulate(check=...)``: ``"warn"`` or
              ``"raise"``; ``None`` (default) skips the analyzer.

    The common cases: a fixed-topology floorplan sweep is one group (no
    padding waste); a cross-design benchmark table or a multi-device
    ``sweep_backends`` comparison is a handful of groups covered by one
    (V, T*, S*) sweep instead of V Python-level event runs.

    >>> from repro.core import SimJob, TaskGraphBuilder, simulate_batch
    >>> b = TaskGraphBuilder("pc")
    >>> _ = b.stream("s", width=32, depth=2)
    >>> _ = b.invoke("P", area={}, outs=["s"])
    >>> _ = b.invoke("C", area={}, ins=["s"])
    >>> g = b.build()
    >>> plain, slow = simulate_batch(
    ...     [SimJob(g), SimJob(g, ii={"C": 2})], firings=10)
    >>> (plain.fired["C"], slow.fired["C"], plain.deadlocked)
    (10, 10, False)
    >>> slow.cycles > plain.cycles          # II=2 consumer takes longer
    True
    >>> chunked = simulate_batch([SimJob(g), SimJob(g, ii={"C": 2})],
    ...                          firings=10, max_bytes=1)   # one job/chunk
    >>> [r.cycles for r in chunked] == [plain.cycles, slow.cycles]
    True
    """
    max_cycles = max_cycles or firings * 64 + 10_000
    norm: list[SimJob] = [j if isinstance(j, SimJob) else SimJob(j)
                          for j in jobs]
    if not norm:
        return []
    if check is not None:
        for j in norm:
            _static_check(j.graph, check, firings=firings,
                          latency=j.latency, extra_capacity=j.extra_capacity,
                          ii=j.ii)
    if backend not in ("auto", "event", "numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "numpy" and _np is None:
        raise ValueError("numpy backend requires NumPy")
    if backend == "jax":
        if not _jax_ready():
            raise ValueError("jax backend requires jax (and NumPy)")
        from repro.kernels.sim_sweep import fits_int32
        if not fits_int32(norm, firings, max_cycles):
            raise ValueError(
                "jax backend is int32-only: firings, max_cycles and every "
                "latency/capacity/II knob must stay below 2**30 "
                "(use backend='numpy' for larger values)")
    resolved = backend
    if backend == "auto":
        if _np is None:
            _ENGINE_INVOCATIONS["fallback"] += 1
            warnings.warn(
                "simulate_batch(backend='auto'): NumPy unavailable, "
                "degrading to per-job event simulation", stacklevel=2)
            resolved = "event"
        elif len(norm) <= 1:
            resolved = "event"          # by design, not a degradation
        elif _jax_ready():
            from repro.kernels.sim_sweep import fits_int32
            if fits_int32(norm, firings, max_cycles):
                resolved = "jax"
            else:
                _ENGINE_INVOCATIONS["fallback"] += 1
                warnings.warn(
                    "simulate_batch(backend='auto'): knobs exceed the jax "
                    "sweep's int32 range, degrading to the NumPy backend",
                    stacklevel=2)
                resolved = "numpy"
        else:
            resolved = "numpy"
    with _trace.span("simulate.batch", backend=resolved, jobs=len(norm),
                     firings=firings):
        if resolved == "event":
            return [simulate(j.graph, firings=firings, latency=j.latency,
                             extra_capacity=j.extra_capacity, ii=j.ii,
                             max_cycles=max_cycles, engine="event")
                    for j in norm]
        sweep = (_simulate_batch_jax if resolved == "jax"
                 else _simulate_batch_numpy)
        chunk = len(norm)
        if max_bytes is not None:
            chunk = max(1, min(chunk,
                               int(max_bytes // _job_bytes_estimate(norm))))
        if chunk >= len(norm):
            return sweep(norm, firings=firings, max_cycles=max_cycles)
        out: list[SimResult] = []
        for i in range(0, len(norm), chunk):
            out.extend(sweep(norm[i:i + chunk], firings=firings,
                             max_cycles=max_cycles))
        return out


def _simulate_batch_jax(jobs: list[SimJob], *, firings: int,
                        max_cycles: int) -> list[SimResult]:
    """Jitted padded ragged-batch engine (``repro.kernels.sim_sweep``).

    Same canonical padded layout as the NumPy engine — both consume
    ``repro.kernels.padded_batch.build_padded_batch`` — driven through one
    ``jax.jit``-compiled ``lax.while_loop`` sweep with donated state
    buffers, compilation cached by the bucketed padded shape.  Results are
    bit-identical to the NumPy oracle; the ``engine`` label is
    ``"jax-padded"``."""
    from repro.kernels.padded_batch import build_padded_batch
    from repro.kernels.sim_sweep import simulate_padded_jax

    _ENGINE_INVOCATIONS["jax"] += 1
    pb = build_padded_batch(jobs)
    cycles, dead, fired, steps = simulate_padded_jax(
        pb, firings=firings, max_cycles=max_cycles)
    return pb.unpack(cycles, dead, fired, steps, "jax-padded")


def _simulate_batch_numpy(jobs: list[SimJob], *, firings: int,
                          max_cycles: int) -> list[SimResult]:
    """Padded ragged-batch synchronous engine.

    State is (V, T*)/(V, S*) integer arrays over *all* jobs, where T*/S*
    are the maximum task/stream counts across topology groups (the
    canonical padded layout built by ``repro.kernels.padded_batch``);
    token visibility uses a ring buffer of cumulative push counts (a token
    pushed at cycle u is visible at u + 1 + lat, so the consumer-visible
    token count at cycle t is the cumulative push count at cycle
    t - 1 - lat).  FIFO order plus constant per-stream latency make that
    view exact.  Per-group incidence matmuls run on contiguous row slices
    inside the one shared cycle loop; everything else is a full-batch
    array op.
    """
    np = _np
    _ENGINE_INVOCATIONS["numpy"] += 1
    from repro.kernels.padded_batch import build_padded_batch

    pb = build_padded_batch(jobs)
    V, T, S, H = pb.V, pb.T, pb.S, pb.H
    groups = pb.groups
    lat, cap, ii = pb.lat, pb.cap, pb.ii
    task_active, counted = pb.task_active, pb.counted

    hist = np.zeros((V, S, H), dtype=np.int64)     # cum pushes at cycle slot
    pops = np.zeros((V, S), dtype=np.int64)
    pushes = np.zeros((V, S), dtype=np.int64)
    fired = np.zeros((V, T), dtype=np.int64)
    next_free = np.zeros((V, T), dtype=np.int64)

    active = np.ones(V, dtype=bool)
    out_cycles = np.full(V, max_cycles, dtype=np.int64)
    out_dead = np.zeros(V, dtype=bool)
    steps = 0

    def all_done():
        # phantom and detached tasks are vacuously done
        return ((fired >= firings) | ~counted).all(axis=1)

    for t in range(max_cycles):
        newly = active & all_done()
        if newly.any():
            out_cycles[newly] = t
            out_dead[newly] = False
            active &= ~newly
        if not active.any():
            break
        steps += 1

        if S:
            look = (t - 1 - lat) % H               # (V, S) ring slot
            vis_cnt = np.take_along_axis(hist, look[:, :, None],
                                         axis=2)[:, :, 0]
            tok_ok = vis_cnt > pops
            space_ok = (pushes - pops) < cap
        in_ok = np.zeros((V, T), dtype=bool)
        out_ok = np.zeros((V, T), dtype=bool)
        for g in groups:
            if g.S:
                in_ok[g.r0:g.r1, :g.T] = (
                    tok_ok[g.r0:g.r1, :g.S].astype(np.int64) @ g.a_in
                ) == g.indeg
                out_ok[g.r0:g.r1, :g.T] = (
                    space_ok[g.r0:g.r1, :g.S].astype(np.int64) @ g.a_out
                ) == g.outdeg
            else:
                in_ok[g.r0:g.r1, :g.T] = True
                out_ok[g.r0:g.r1, :g.T] = True

        can = (active[:, None] & task_active & (fired < firings)
               & (next_free <= t) & in_ok & out_ok)
        fired += can
        next_free = np.where(can, t + ii, next_free)
        if S:
            for g in groups:
                if g.S:
                    pops[g.r0:g.r1, :g.S] += can[g.r0:g.r1, g.cons]
                    pushes[g.r0:g.r1, :g.S] += can[g.r0:g.r1, g.prod]
            hist[:, :, t % H] = pushes

        progressed = can.any(axis=1)
        # post-update in-flight check at cycle t (matches reference engine);
        # phantom streams never hold tokens, phantom tasks never fire, so
        # the padded columns are inert here too
        if S:
            nonempty = pops < pushes
            head_hidden = nonempty & (vis_cnt <= pops)
            tok_flight = head_hidden.any(axis=1)
        else:
            tok_flight = np.zeros(V, dtype=bool)
        ii_flight = (next_free > t).any(axis=1)
        quiet = active & ~progressed & ~tok_flight & ~ii_flight
        if quiet.any():
            done = all_done()
            out_cycles[quiet] = t + 1
            out_dead[quiet] = ~done[quiet]
            active &= ~quiet
            if not active.any():
                break

    if active.any():
        out_cycles[active] = max_cycles
        out_dead[active] = ~all_done()[active]

    engine = "numpy-batch" if len(groups) == 1 else "numpy-padded"
    return pb.unpack(out_cycles, out_dead, fired, steps, engine)
