"""Floorplan-aware pipelining (paper §5 + §5.3).

Every cross-slot stream gets ``pipeline_depth`` register levels per boundary
crossed (paper default: 2).  The physical realization differs per target:

  * FPGA: almost-full FIFOs whose interface signals are registered
    (paper Fig. 10), so added depth never changes functionality;
  * TPU: extra microbatch buffer slots on the inter-stage channel, realized
    as double/triple-buffered ``ppermute`` sends that overlap compute.

The returned latency map feeds the balancer; ``lat + balance`` is the final
depth of every stream.
"""
from __future__ import annotations

import dataclasses

from .floorplan import Floorplan
from .graph import TaskGraph


@dataclasses.dataclass
class PipelineAssignment:
    #: inserted pipelining latency per stream (from crossings)
    lat: dict[str, int]
    #: extra FIFO depth per stream to keep the producer from stalling while
    #: tokens are in flight (depth >= lat, almost-full headroom)
    extra_depth: dict[str, int]
    #: register-area overhead  sum(lat * width)
    reg_area: float


def assign_pipelining(graph: TaskGraph, fp: Floorplan) -> PipelineAssignment:
    lat: dict[str, int] = {}
    extra: dict[str, int] = {}
    area = 0.0
    for s in graph.streams:
        a, b = fp.placement[s.src], fp.placement[s.dst]
        d = fp.grid.crossing_depth(a, b)
        lat[s.name] = d
        # almost-full FIFOs must absorb the in-flight tokens: grow capacity
        # by the round-trip latency (paper Fig. 10)
        extra[s.name] = 2 * d
        area += d * s.width
    return PipelineAssignment(lat=lat, extra_depth=extra, reg_area=area)
