"""Task-parallel dataflow graph IR — the TAPA programming model (paper §3).

A program is a set of *tasks* (vertices) communicating through unidirectional
*streams* (edges).  Tasks are hierarchical: a parent task instantiates child
tasks and the streams that connect them (``task().invoke(...)``, Listing 1 of
the paper).  We keep the same vocabulary:

  * ``Task``     — one instantiated task (an FSM/RTL module on FPGA; a model
                   subgraph on TPU).  Carries a resource/area vector.
  * ``Stream``   — a FIFO channel with a *width* (bits on FPGA, bytes per
                   microbatch on TPU) and a *depth* (capacity).
  * ``TaskGraph``— the flattened graph handed to the floorplanner.

The builder API mirrors TAPA's C++ interface closely enough that the paper's
benchmarks (stencil chains, CNN grids, crossbars, ...) read like Listing 1.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Iterable, Mapping

# Resource vectors are plain dicts: {"LUT": 1200, "BRAM": 4, ...} on FPGA,
# {"flops": ..., "hbm_bytes": ..., "hbm_channels": 1} on TPU.  Missing keys
# mean zero.
Area = Mapping[str, float]


def area_add(a: Area, b: Area) -> dict[str, float]:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


def area_scale(a: Area, s: float) -> dict[str, float]:
    return {k: v * s for k, v in a.items()}


def area_leq(a: Area, b: Area, *, slack: float = 0.0) -> bool:
    """True if a <= b element-wise (keys missing from b are unconstrained
    unless present in a with positive value and b defines the resource)."""
    for k, v in a.items():
        if k in b and v > b[k] + slack:
            return False
    return True


@dataclasses.dataclass
class Task:
    name: str
    area: dict[str, float] = dataclasses.field(default_factory=dict)
    #: "leaf" tasks compute; "parent" tasks only instantiate children and are
    #: flattened away before floorplanning.
    kind: str = "leaf"
    #: detached tasks (task().invoke<detach>()) never join the parent; they
    #: are placement-wise identical but excluded from termination analysis.
    detached: bool = False
    #: optional hard location constraint: (row, col) slot that this task must
    #: occupy (e.g. an IO module that must sit next to its HBM channel).
    pinned: tuple[int, int] | None = None
    #: module-level metadata (layer index, HLS latency, ...)
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Stream:
    name: str
    src: str
    dst: str
    #: channel width: bits (FPGA) or bytes per microbatch (TPU).
    width: float = 32.0
    #: user-declared FIFO capacity (stream<T, depth>); pipelining may deepen.
    depth: int = 2
    #: control streams carry per-phase handshakes (EoT, commands, status),
    #: not per-datum tokens: they tolerate arbitrary latency, so they are
    #: pipelined but excluded from throughput balancing (and they may close
    #: dependency cycles without forcing co-location).
    control: bool = False
    meta: dict = dataclasses.field(default_factory=dict)


class TaskGraph:
    """Flattened task graph: what the floorplanner and balancer consume."""

    def __init__(self, name: str = "top"):
        self.name = name
        self.tasks: dict[str, Task] = {}
        self.streams: list[Stream] = []
        self._out: dict[str, list[int]] = defaultdict(list)
        self._in: dict[str, list[int]] = defaultdict(list)

    # -- construction -----------------------------------------------------
    def add_task(self, task: Task) -> Task:
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name!r}")
        self.tasks[task.name] = task
        return task

    def add_stream(self, stream: Stream, *, validate: bool = True) -> Stream:
        """Attach a stream; rejects malformed ones at construction time.

        ``validate=False`` is the escape hatch for tests that deliberately
        build broken graphs (self-loops, zero-capacity FIFOs) — the static
        verifier (``repro.analysis``) flags such pre-existing graphs with
        the same conditions as error diagnostics."""
        if stream.src not in self.tasks or stream.dst not in self.tasks:
            raise ValueError(
                f"stream {stream.name!r} connects unknown task "
                f"({stream.src!r} -> {stream.dst!r})")
        if validate:
            if stream.src == stream.dst:
                raise ValueError(
                    f"stream {stream.name!r} is a self-loop on "
                    f"{stream.src!r}")
            if stream.width <= 0:
                raise ValueError(
                    f"stream {stream.name!r} has non-positive width "
                    f"{stream.width!r}")
            if stream.depth <= 0:
                raise ValueError(
                    f"stream {stream.name!r} has non-positive depth "
                    f"{stream.depth!r} (its producer could never write)")
        idx = len(self.streams)
        self.streams.append(stream)
        self._out[stream.src].append(idx)
        self._in[stream.dst].append(idx)
        return stream

    # -- queries ----------------------------------------------------------
    def out_streams(self, task: str) -> list[Stream]:
        return [self.streams[i] for i in self._out[task]]

    def in_streams(self, task: str) -> list[Stream]:
        return [self.streams[i] for i in self._in[task]]

    def total_area(self) -> dict[str, float]:
        tot: dict[str, float] = {}
        for t in self.tasks.values():
            tot = area_add(tot, t.area)
        return tot

    def edge_list(self) -> list[tuple[str, str, float]]:
        return [(s.src, s.dst, s.width) for s in self.streams]

    @property
    def num_tasks(self) -> int:
        return len(self.tasks)

    @property
    def num_streams(self) -> int:
        return len(self.streams)

    def validate(self) -> None:
        """Each stream has exactly one producer and one consumer by
        construction; check the graph is sane (no self-loop streams —
        the paper's model forbids a task streaming to itself)."""
        for s in self.streams:
            if s.src == s.dst:
                raise ValueError(f"stream {s.name!r} is a self-loop on {s.src!r}")

    def __repr__(self) -> str:  # pragma: no cover
        return (f"TaskGraph({self.name!r}, tasks={self.num_tasks}, "
                f"streams={self.num_streams})")


class TaskGraphBuilder:
    """TAPA-style hierarchical builder (paper Listing 1).

    Example::

        b = TaskGraphBuilder("VecAdd")
        a = b.streams("str_a", n=4, width=32)
        bb = b.streams("str_b", n=4, width=32)
        c = b.streams("str_c", n=4, width=32)
        b.invoke("Load", area={"LUT": 900}, outs=a, count=4)
        b.invoke("Load", area={"LUT": 900}, outs=bb, count=4)
        b.invoke("Add", area={"LUT": 300, "DSP": 1}, ins=a + bb, outs=c, count=4)
        b.invoke("Store", area={"LUT": 700}, ins=c, count=4)
        g = b.build()

    ``count=N`` mirrors ``invoke<N>``: N task instances, with stream lists
    distributed round-robin across instances (the common SIMD pattern).
    """

    def __init__(self, name: str = "top"):
        self.graph = TaskGraph(name)
        self._stream_defs: dict[str, Stream] = {}
        self._pending: list[Stream] = []
        self._instance_count: dict[str, int] = defaultdict(int)

    def stream(self, name: str, *, width: float = 32.0, depth: int = 2,
               control: bool = False) -> str:
        if name in self._stream_defs:
            raise ValueError(f"duplicate stream {name!r}")
        s = Stream(name=name, src="", dst="", width=width, depth=depth,
                   control=control)
        self._stream_defs[name] = s
        return name

    def streams(self, prefix: str, *, n: int, width: float = 32.0,
                depth: int = 2, control: bool = False) -> list[str]:
        return [self.stream(f"{prefix}[{i}]", width=width, depth=depth,
                            control=control)
                for i in range(n)]

    def invoke(self, fn: str, *, area: Area | None = None,
               ins: Iterable[str] = (), outs: Iterable[str] = (),
               count: int = 1, detach: bool = False,
               pinned: tuple[int, int] | None = None,
               meta: dict | None = None,
               area_fn: Callable[[int], Area] | None = None) -> list[str]:
        """Instantiate ``count`` instances of task function ``fn``.

        Stream name lists in ``ins``/``outs`` are split round-robin across
        the instances (len must be a multiple of count).  Returns instance
        names.
        """
        ins, outs = list(ins), list(outs)
        names = []
        for i in range(count):
            idx = self._instance_count[fn]
            self._instance_count[fn] += 1
            inst = f"{fn}_{idx}" if (count > 1 or idx > 0) else fn
            a = dict(area_fn(i) if area_fn is not None else (area or {}))
            self.graph.add_task(Task(name=inst, area=a, detached=detach,
                                     pinned=pinned, meta=dict(meta or {})))
            names.append(inst)
        for lst, role in ((ins, "dst"), (outs, "src")):
            if not lst:
                continue
            if len(lst) % count:
                raise ValueError(
                    f"invoke({fn!r}): {len(lst)} streams not divisible by count={count}")
            per = len(lst) // count
            for i, inst in enumerate(names):
                for sname in lst[i * per:(i + 1) * per]:
                    s = self._stream_defs[sname]
                    setattr(s, role, inst)
        return names

    def build(self) -> TaskGraph:
        for s in self._stream_defs.values():
            if not s.src or not s.dst:
                raise ValueError(
                    f"stream {s.name!r} missing "
                    f"{'producer' if not s.src else 'consumer'}")
            self.graph.add_stream(s)
        self.graph.validate()
        return self.graph
