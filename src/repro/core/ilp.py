"""0/1 ILP engine for one partitioning iteration (paper §4.3).

Every floorplan iteration splits *all* current slots in half simultaneously.
Each movable task gets a binary decision variable ``d_v`` (0 = first child
slot, 1 = second).  The objective is the width-weighted slot-crossing count
in the *new* coordinate system; after the coordinate update (Formulas 3-6)
the per-edge contribution is ``w_e * |K_e + d_u - d_v|`` where
``K_e = 2 * (coord_u - coord_v)`` in the dimension being split.  Capacity
constraints are per (current slot, child, resource).

The paper solves this with Gurobi.  Offline, we provide:

  * an **exact branch-and-bound** (default for <= ``exact_threshold`` free
    variables after same-slot merging) with edge-completion lower bounds and
    an FM-seeded incumbent; and
  * a **multi-start Fiduccia-Mattheyses** local search with prefix-rollback
    passes for larger instances (the classic partitioning heuristic the
    paper's related work [4, 33, 58] builds on).

Both honor capacity, pinning and same-slot (co-location) constraints.
``solve_bipartition`` reports whether the returned solution is proven
optimal (``stats["exact"]``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from ..obs import metrics as _metrics

Area = dict[str, float]

# Bipartition-solver invocations since the last reset.  Each floorplan runs
# one solve per split iteration, so this counter is the ground truth for
# "how many ILPs did a sweep actually pay for" — ``floorplan_counts()`` in
# ``autobridge`` folds it into the cache-hit accounting that benchmarks and
# the CI regression gate inspect.
_SOLVE_COUNTS = _metrics.group("ilp", {"bipartitions": 0})


def reset_solve_counts() -> None:
    """Zero the global bipartition-solver invocation counter."""
    _SOLVE_COUNTS.reset()


def solve_counts() -> dict[str, int]:
    """Snapshot of bipartition-solver invocations since the last reset."""
    return dict(_SOLVE_COUNTS)


def merge_solve_counts(bipartitions: int) -> None:
    """Fold a worker process's bipartition-count delta into this process's
    counter.  Module globals are per-process, so solves performed inside a
    ``ProcessPoolExecutor`` worker are invisible here until the pool merges
    the worker's delta back (``repro.search.pool``)."""
    _SOLVE_COUNTS["bipartitions"] += int(bipartitions)


@dataclasses.dataclass
class Edge:
    """Cost term ``w * |k + a*du + b*dv|``.

    For a uniform power-of-two split this reduces to the paper's
    ``w * |K + du - dv|`` (a=1, b=-1); the general coefficients support
    non-power-of-two grids (e.g. U280's 2x3) where child-slot coordinate
    offsets differ per current slot.
    """
    u: int
    v: int
    w: float
    k: float = 0.0
    a: float = 1.0
    b: float = -1.0

    def cost(self, du: int, dv: int) -> float:
        return self.w * abs(self.k + self.a * du + self.b * dv)

    def min_cost(self) -> float:
        return self.w * min(abs(self.k + self.a * du + self.b * dv)
                            for du in (0, 1) for dv in (0, 1))

    def min_cost_given_u(self, du: int) -> float:
        return self.w * min(abs(self.k + self.a * du + self.b * dv)
                            for dv in (0, 1))

    def min_cost_given_v(self, dv: int) -> float:
        return self.w * min(abs(self.k + self.a * du + self.b * dv)
                            for du in (0, 1))


@dataclasses.dataclass
class BipartitionProblem:
    """One global split of all current slots.

    areas[i]  — resource vector of (merged) vertex i
    group[i]  — current-slot index of vertex i
    cap0/cap1 — per current-slot child capacities (list of Area, len = #groups)
    edges     — Edge list over vertex indices
    pinned    — {vertex: 0/1} hard assignments (location constraints)
    big[i]    — vertex too large to share a leaf slot with another big one
                (> half a leaf slot in some soft resource); a child region of
                k leaf slots admits at most k big vertices.  This is the
                granularity guard that keeps aggregate-capacity splits from
                stranding monolithic kernels (e.g. SODA) in regions that can
                never be leaf-packed.
    slots0/1  — leaf-slot count of each group's children
    """
    areas: list[Area]
    group: list[int]
    cap0: list[Area]
    cap1: list[Area]
    edges: list[Edge]
    pinned: dict[int, int] = dataclasses.field(default_factory=dict)
    big: list[bool] | None = None
    slots0: list[int] | None = None
    slots1: list[int] | None = None

    @property
    def n(self) -> int:
        return len(self.areas)


def _resource_keys(p: BipartitionProblem) -> list[str]:
    keys: set[str] = set()
    for a in p.areas:
        keys.update(a)
    out = []
    for k in sorted(keys):
        if any(k in c for c in p.cap0) or any(k in c for c in p.cap1):
            out.append(k)
    return out


class _Loads:
    """Vectorized per-(group, side, resource) load tracking."""

    def __init__(self, p: BipartitionProblem, keys: list[str]):
        self.keys = keys
        ngroups = max(p.group) + 1 if p.group else 1
        self.area = np.zeros((p.n, len(keys)))
        for i, a in enumerate(p.areas):
            for j, k in enumerate(keys):
                self.area[i, j] = a.get(k, 0.0)
        inf = float("inf")
        self.cap = np.full((ngroups, 2, len(keys)), inf)
        for g in range(ngroups):
            for side, caps in ((0, p.cap0), (1, p.cap1)):
                for j, k in enumerate(keys):
                    if k in caps[g]:
                        self.cap[g, side, j] = caps[g][k]
        self.load = np.zeros((ngroups, 2, len(keys)))
        # granularity guard: at most `slots` big vertices per child region
        self.big = np.array(p.big if p.big is not None else [False] * p.n)
        self.big_cap = np.full((ngroups, 2), np.inf)
        if p.slots0 is not None:
            for g in range(ngroups):
                self.big_cap[g, 0] = p.slots0[g]
                self.big_cap[g, 1] = p.slots1[g]
        self.big_load = np.zeros((ngroups, 2))

    def fits(self, g: int, side: int, i: int) -> bool:
        if self.big[i] and self.big_load[g, side] + 1 > self.big_cap[g, side]:
            return False
        return bool(np.all(self.load[g, side] + self.area[i]
                           <= self.cap[g, side] + 1e-9))

    def add(self, g: int, side: int, i: int) -> None:
        self.load[g, side] += self.area[i]
        if self.big[i]:
            self.big_load[g, side] += 1

    def remove(self, g: int, side: int, i: int) -> None:
        self.load[g, side] -= self.area[i]
        if self.big[i]:
            self.big_load[g, side] -= 1

    def imbalance(self) -> float:
        """Sum over groups/resources of |load1 - load0| (tie-break term)."""
        return float(np.abs(self.load[:, 1] - self.load[:, 0]).sum())


def total_cost(p: BipartitionProblem, assign: Sequence[int]) -> float:
    return sum(e.cost(assign[e.u], assign[e.v]) for e in p.edges)


def check_feasible(p: BipartitionProblem, assign: Sequence[int]) -> bool:
    keys = _resource_keys(p)
    loads = _Loads(p, keys)
    for i, d in enumerate(assign):
        if i in p.pinned and d != p.pinned[i]:
            return False
        if not loads.fits(p.group[i], d, i):
            return False
        loads.add(p.group[i], d, i)
    return True


# --------------------------------------------------------------------------
# Greedy feasible construction + FM refinement
# --------------------------------------------------------------------------

def _greedy_initial(p: BipartitionProblem, loads: _Loads,
                    rng: np.random.Generator) -> list[int] | None:
    order = sorted(range(p.n), key=lambda i: -float(loads.area[i].sum()))
    assign = [-1] * p.n
    for i in order:
        if i in p.pinned:
            side = p.pinned[i]
            if not loads.fits(p.group[i], side, i):
                return None
            assign[i] = side
            loads.add(p.group[i], side, i)
            continue
        g = p.group[i]
        # prefer the side with more head-room (normalized), tie-break random
        room = []
        for side in (0, 1):
            cap = loads.cap[g, side]
            with np.errstate(invalid="ignore"):
                frac = np.where(np.isfinite(cap) & (cap > 0),
                                (cap - loads.load[g, side]) / np.maximum(cap, 1e-9),
                                1.0)
            # a zero-resource problem (every area vector empty) has no
            # head-room axis at all: both sides are equally fine
            room.append(float(frac.min()) if frac.size else 1.0)
        first = int(room[1] > room[0] + 1e-12)
        if room[0] == room[1]:
            first = int(rng.integers(0, 2))
        for side in (first, 1 - first):
            if loads.fits(g, side, i):
                assign[i] = side
                loads.add(g, side, i)
                break
        else:
            return None
    return assign


def _fm_refine(p: BipartitionProblem, assign: list[int], loads: _Loads,
               max_passes: int = 12) -> float:
    """FM passes with prefix rollback and O(deg) incremental gain updates.
    Mutates assign/loads in place."""
    n = p.n
    adj: list[list[Edge]] = [[] for _ in range(n)]
    for e in p.edges:
        adj[e.u].append(e)
        adj[e.v].append(e)

    def edge_contrib(e: Edge, v: int) -> float:
        """Gain contribution of edge e to flipping vertex v."""
        du, dv = assign[e.u], assign[e.v]
        cur = e.cost(du, dv)
        if e.u == v:
            return cur - e.cost(1 - du, dv)
        return cur - e.cost(du, 1 - dv)

    # gains[v] = sum of edge contributions; kept incrementally
    contrib: dict[tuple[int, int], float] = {}
    gains = np.zeros(n)
    for idx, e in enumerate(p.edges):
        for v in (e.u, e.v):
            c = edge_contrib(e, v)
            contrib[(idx, v)] = c
            gains[v] += c
    eidx = {id(e): i for i, e in enumerate(p.edges)}

    def apply_move(i: int) -> None:
        loads.remove(p.group[i], assign[i], i)
        assign[i] = 1 - assign[i]
        loads.add(p.group[i], assign[i], i)
        for e in adj[i]:
            idx = eidx[id(e)]
            for v in (e.u, e.v):
                c = edge_contrib(e, v)
                gains[v] += c - contrib[(idx, v)]
                contrib[(idx, v)] = c

    cost = total_cost(p, assign)
    NEG = -1e30
    for _ in range(max_passes):
        locked = np.zeros(n, dtype=bool)
        for i in p.pinned:
            locked[i] = True
        moves: list[int] = []
        costs: list[float] = [cost]
        cur = cost
        for _step in range(n):
            masked = np.where(locked, NEG, gains)
            best = -1
            # try candidates in descending gain until one fits capacity
            for _tries in range(8):
                i = int(np.argmax(masked))
                if masked[i] <= NEG / 2:
                    break
                if loads.fits(p.group[i], 1 - assign[i], i):
                    best = i
                    break
                masked[i] = NEG
            if best < 0:
                break
            g = float(gains[best])
            apply_move(best)
            locked[best] = True
            cur -= g
            moves.append(best)
            costs.append(cur)
            if cur > costs[0] + 4.0 * (abs(costs[0]) + 1.0):
                break  # diverging; rollback will recover the best prefix
        if not moves:
            break
        k = int(np.argmin(costs))  # keep best prefix, undo the rest
        for i in reversed(moves[k:]):
            apply_move(i)
        new_cost = costs[k]
        if new_cost >= cost - 1e-12:
            break
        cost = new_cost
    return cost


def _balance_eps(p: BipartitionProblem, loads: _Loads) -> float:
    """Tie-break weight: small enough that (eps * any imbalance) can never
    override a genuine crossing-cost difference, large enough to prefer
    balanced children among co-optimal cuts (avoids infeasible dead-ends in
    later split iterations)."""
    wsum = sum(abs(e.w) for e in p.edges) + 1.0
    asum = float(loads.area.sum()) + 1.0
    return 1e-7 * wsum / asum


def _rebalance_pass(p: BipartitionProblem, assign: list[int], loads: _Loads,
                    adj: list[list[Edge]], eps: float) -> None:
    """Greedy zero-cost-gain moves that reduce child imbalance."""
    improved = True
    sweeps = 0
    while improved and sweeps < 6:
        sweeps += 1
        improved = False
        for i in range(p.n):
            if i in p.pinned:
                continue
            g, d = p.group[i], assign[i]
            if not loads.fits(g, 1 - d, i):
                continue
            dcost = 0.0
            for e in adj[i]:
                du, dv = assign[e.u], assign[e.v]
                ndu = 1 - du if e.u == i else du
                ndv = 1 - dv if e.v == i else dv
                dcost += e.cost(ndu, ndv) - e.cost(du, dv)
            if dcost > 1e-12:
                continue
            before = loads.imbalance()
            loads.remove(g, d, i)
            loads.add(g, 1 - d, i)
            after = loads.imbalance()
            if dcost < -1e-12 or after < before - 1e-9:
                assign[i] = 1 - d
                improved = True
            else:
                loads.remove(g, 1 - d, i)
                loads.add(g, d, i)


def _heuristic(p: BipartitionProblem, n_starts: int, seed: int,
               keys: list[str]) -> tuple[list[int] | None, float]:
    """Returns (assignment, penalized cost)."""
    adj: list[list[Edge]] = [[] for _ in range(p.n)]
    for e in p.edges:
        adj[e.u].append(e)
        adj[e.v].append(e)
    best, best_cost = None, float("inf")
    for s in range(n_starts):
        rng = np.random.default_rng(seed + 1000003 * s)
        loads = _Loads(p, keys)
        assign = _greedy_initial(p, loads, rng)
        if assign is None:
            continue
        eps = _balance_eps(p, loads)
        cost = _fm_refine(p, assign, loads)
        _rebalance_pass(p, assign, loads, adj, eps)
        pen = cost + eps * loads.imbalance()
        if pen < best_cost:
            best, best_cost = list(assign), pen
    return best, best_cost


# --------------------------------------------------------------------------
# Exact branch and bound
# --------------------------------------------------------------------------

def _branch_and_bound(p: BipartitionProblem, keys: list[str],
                      incumbent: list[int] | None, inc_cost: float,
                      deadline: float) -> tuple[list[int] | None, float, bool]:
    n = p.n
    # order by incident weight (descending) so heavy edges are decided early
    weight = np.zeros(n)
    adj: list[list[Edge]] = [[] for _ in range(n)]
    for e in p.edges:
        weight[e.u] += e.w
        weight[e.v] += e.w
        adj[e.u].append(e)
        adj[e.v].append(e)
    order = sorted(range(n), key=lambda i: -weight[i])

    # minimum possible cost of all edges not yet fully decided at depth t:
    # precompute suffix of "free" minima
    base_min = sum(e.min_cost() for e in p.edges)

    assign = [-1] * n
    loads = _Loads(p, keys)
    eps = _balance_eps(p, loads)
    best = list(incumbent) if incumbent is not None else None
    best_cost = inc_cost  # penalized
    exact = True

    def lb_delta(i: int, side: int) -> float:
        """Change in lower bound when assigning i := side."""
        d = 0.0
        for e in adj[i]:
            other = e.v if e.u == i else e.u
            if assign[other] >= 0:
                du = side if e.u == i else assign[e.u]
                dv = side if e.v == i else assign[e.v]
                d += e.cost(du, dv)
                # previously counted as half-decided min
                d -= (e.min_cost_given_u(assign[e.u]) if e.u == other
                      else e.min_cost_given_v(assign[e.v]))
            else:
                d += (e.min_cost_given_u(side) if e.u == i
                      else e.min_cost_given_v(side)) - e.min_cost()
        return d

    lb_stack = [base_min]

    def rec(t: int) -> None:
        nonlocal best, best_cost, exact
        if time.monotonic() > deadline:
            exact = False
            return
        if t == n:
            pen = lb_stack[-1] + eps * loads.imbalance()
            if pen < best_cost - 1e-15:
                best, best_cost = list(assign), pen
            return
        i = order[t]
        sides = (p.pinned[i],) if i in p.pinned else (0, 1)
        # explore the locally-cheaper side first
        if len(sides) == 2:
            d0 = lb_delta(i, 0)
            d1 = lb_delta(i, 1)
            cand = [(d0, 0), (d1, 1)]
            cand.sort()
        else:
            cand = [(lb_delta(i, sides[0]), sides[0])]
        for delta, side in cand:
            new_lb = lb_stack[-1] + delta
            if new_lb >= best_cost - 1e-12:
                continue
            if not loads.fits(p.group[i], side, i):
                continue
            assign[i] = side
            loads.add(p.group[i], side, i)
            lb_stack.append(new_lb)
            rec(t + 1)
            lb_stack.pop()
            loads.remove(p.group[i], side, i)
            assign[i] = -1

    rec(0)
    return best, best_cost, exact


# --------------------------------------------------------------------------

def solve_bipartition(p: BipartitionProblem, *, exact_threshold: int = 22,
                      n_starts: int = 8, seed: int = 0,
                      time_limit_s: float = 6.0) -> tuple[list[int], float, dict]:
    """Solve one partitioning iteration.  Returns (assignment, cost, stats)."""
    _SOLVE_COUNTS["bipartitions"] += 1
    t0 = time.monotonic()
    keys = _resource_keys(p)
    inc, inc_cost = _heuristic(p, n_starts, seed, keys)
    stats = {"n": p.n, "edges": len(p.edges), "exact": False,
             "heuristic_cost": inc_cost}
    n_free = p.n - len(p.pinned)
    if n_free <= exact_threshold:
        best, best_cost, exact = _branch_and_bound(
            p, keys, inc, inc_cost, deadline=t0 + time_limit_s)
        if best is not None:
            inc, inc_cost = best, best_cost
        stats["exact"] = exact
    if inc is None:
        raise InfeasibleError(
            "bipartition infeasible: tasks do not fit in child slots "
            "(raise max_util or coarsen the grid)")
    cost = total_cost(p, inc)  # raw (un-penalized) objective
    stats["cost"] = cost
    stats["wall_s"] = time.monotonic() - t0
    return inc, cost, stats


def brute_force_bipartition(p: BipartitionProblem) -> tuple[list[int] | None, float]:
    """Exhaustive reference solver for tests (n <= ~16)."""
    n = p.n
    best, best_cost = None, float("inf")
    for mask in range(1 << n):
        assign = [(mask >> i) & 1 for i in range(n)]
        if any(assign[i] != d for i, d in p.pinned.items()):
            continue
        if not check_feasible(p, assign):
            continue
        c = total_cost(p, assign)
        if c < best_cost:
            best, best_cost = assign, c
    return best, best_cost


class InfeasibleError(RuntimeError):
    pass
