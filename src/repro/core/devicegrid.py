"""Coarse-grained device model: a grid of slots (paper §4.1).

A device (FPGA die stack or TPU mesh) is viewed as an R x C grid of *slots*
delimited by physical barriers — die boundaries / IP columns on FPGA, pod
(DCN) boundaries / ICI subgroup boundaries on TPU.  Each slot carries a
resource capacity vector; each boundary carries a crossing *weight* (the
relative cost of a wire/stream crossing it) and a default *pipeline depth*
(registers or microbatch buffer slots inserted per crossing).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Boundary:
    """One grid line between adjacent rows or columns."""
    weight: float = 1.0          # cost multiplier for the floorplan objective
    pipeline_depth: int = 2      # regs / buffer slots inserted per crossing
    delay_ns: float = 2.0        # unpipelined physical delay (fmax model)


@dataclasses.dataclass
class SlotGrid:
    name: str
    rows: int
    cols: int
    #: capacity of one slot (uniform) or per-slot overrides in ``slot_caps``.
    base_capacity: dict[str, float]
    #: per-slot capacity overrides keyed by (row, col); e.g. HBM channels
    #: only exist in row 0 slots (paper §6.2: channels as a slot resource).
    slot_caps: dict[tuple[int, int], dict[str, float]] = dataclasses.field(
        default_factory=dict)
    #: boundaries between rows (len rows-1) and cols (len cols-1).
    row_boundaries: list[Boundary] = dataclasses.field(default_factory=list)
    col_boundaries: list[Boundary] = dataclasses.field(default_factory=list)
    #: maximum utilization ratio applied to every capacity (paper §4.2 (3));
    #: the multi-floorplan explorer sweeps this knob (paper §6.3).
    max_util: float = 0.7

    def __post_init__(self):
        if not self.row_boundaries:
            self.row_boundaries = [Boundary() for _ in range(self.rows - 1)]
        if not self.col_boundaries:
            self.col_boundaries = [Boundary() for _ in range(self.cols - 1)]
        assert len(self.row_boundaries) == self.rows - 1
        assert len(self.col_boundaries) == self.cols - 1

    # -- capacities --------------------------------------------------------
    def resource_keys(self) -> set[str]:
        keys = set(self.base_capacity)
        for caps in self.slot_caps.values():
            keys.update(caps)
        return keys

    def capacity(self, row: int, col: int,
                 max_util: float | None = None) -> dict[str, float]:
        # Every resource known anywhere on the grid is materialized in every
        # slot: a slot that does not own the resource has capacity 0 (e.g.
        # hbm_channels only exist in boundary-adjacent slots, paper §6.2).
        cap = {k: 0.0 for k in self.resource_keys()}
        cap.update(self.base_capacity)
        cap.update(self.slot_caps.get((row, col), {}))
        u = self.max_util if max_util is None else max_util
        # hard resources (hbm_channels, ddr_channels, ...) are integral
        # units, not subject to the utilization head-room knob.
        return {k: (v if k.startswith("hard_") or k.endswith("_channels")
                    else v * u) for k, v in cap.items()}

    def slots(self) -> list[tuple[int, int]]:
        return [(r, c) for r in range(self.rows) for c in range(self.cols)]

    def with_knobs(self, *, row_weight: float = 1.0, col_weight: float = 1.0,
                   depth_scale: float = 1.0) -> "SlotGrid":
        """A copy of the grid with co-optimization knobs applied (the joint
        design-space search axes beyond max-util, paper §6.3 generalized):

        * ``row_weight`` / ``col_weight`` scale the crossing cost of row/col
          boundaries in the floorplan objective — the *ratio* trades die
          (SLR) crossings against column crossings;
        * ``depth_scale`` scales every boundary's inserted pipeline depth
          (more registers shorten routed segments at the cost of buffer
          area and fill/drain skew).  Nonzero depths stay >= 1.

        Physical delays (``delay_ns``) are device properties and are never
        scaled.  With all knobs at 1.0 the grid is returned unchanged."""
        if row_weight == 1.0 and col_weight == 1.0 and depth_scale == 1.0:
            return self

        def scaled(bs: list[Boundary], w: float) -> list[Boundary]:
            return [Boundary(weight=b.weight * w,
                             pipeline_depth=(max(1, round(b.pipeline_depth
                                                          * depth_scale))
                                             if b.pipeline_depth else 0),
                             delay_ns=b.delay_ns)
                    for b in bs]

        return dataclasses.replace(
            self,
            row_boundaries=scaled(self.row_boundaries, row_weight),
            col_boundaries=scaled(self.col_boundaries, col_weight))

    def hbm_slots(self) -> list[tuple[int, int]]:
        """Slots that expose ``hbm_channels`` capacity, in slot order."""
        return [s for s in self.slots()
                if self.slot_caps.get(s, {}).get("hbm_channels", 0) > 0]

    def total_hbm_channels(self) -> float:
        """Total HBM channels across the grid (0 for DDR-only devices)."""
        return sum(self.slot_caps.get(s, {}).get("hbm_channels", 0.0)
                   for s in self.slots())

    def with_hbm_binding(self, split: float) -> "SlotGrid":
        """A copy with the device's HBM channels re-bound across the
        channel-bearing slots (the search axis behind
        ``SearchSpace.hbm_splits``).

        Physically the channel *stacks* are fixed, but the platform's
        channel-to-slot binding — which pseudo-channels the shell routes
        into which slot's crossbar — is a build-time choice.  ``split``
        tilts the per-slot channel shares linearly across the channel
        slots (in slot order): the first share is proportional to
        ``split``, the last to ``1 - split``, with the total channel count
        conserved.  ``split = 0.5`` is the symmetric default binding and
        returns the grid unchanged; designs whose IO tasks crowd one side
        of the die use other splits to buy feasibility (TAPA §6.2's
        channels-as-a-slot-resource model made searchable).

        Grids without HBM slots (or with a single one) are returned
        unchanged for any split."""
        if not 0.0 <= split <= 1.0:
            raise ValueError(f"hbm split must be in [0, 1], got {split!r}")
        slots = self.hbm_slots()
        if len(slots) < 2 or split == 0.5:
            return self
        total = self.total_hbm_channels()
        k = len(slots)
        raw = [split + (1.0 - 2.0 * split) * i / (k - 1) for i in range(k)]
        norm = sum(raw)
        caps = {s: dict(c) for s, c in self.slot_caps.items()}
        for s, w in zip(slots, raw):
            caps[s]["hbm_channels"] = total * w / norm
        if caps == self.slot_caps:
            return self
        return dataclasses.replace(self, slot_caps=caps)

    # -- distances ---------------------------------------------------------
    def crossing_weight(self, a: tuple[int, int], b: tuple[int, int]) -> float:
        """Weighted Manhattan distance: sum of boundary weights crossed.

        With unit weights this is exactly the paper's cost
        |r_a - r_b| + |c_a - c_b| (Formula 1)."""
        (r0, c0), (r1, c1) = a, b
        w = 0.0
        for r in range(min(r0, r1), max(r0, r1)):
            w += self.row_boundaries[r].weight
        for c in range(min(c0, c1), max(c0, c1)):
            w += self.col_boundaries[c].weight
        return w

    def crossing_depth(self, a: tuple[int, int], b: tuple[int, int]) -> int:
        """Total pipeline depth for a stream between slots a and b
        (paper §7.1: 'for each boundary crossing we add two levels')."""
        (r0, c0), (r1, c1) = a, b
        d = 0
        for r in range(min(r0, r1), max(r0, r1)):
            d += self.row_boundaries[r].pipeline_depth
        for c in range(min(c0, c1), max(c0, c1)):
            d += self.col_boundaries[c].pipeline_depth
        return d

    def crossing_delay_ns(self, a: tuple[int, int], b: tuple[int, int]) -> float:
        (r0, c0), (r1, c1) = a, b
        d = 0.0
        for r in range(min(r0, r1), max(r0, r1)):
            d += self.row_boundaries[r].delay_ns
        for c in range(min(c0, c1), max(c0, c1)):
            d += self.col_boundaries[c].delay_ns
        return d
