"""repro.core — the paper's contribution: task-parallel dataflow graphs,
coarse-grained floorplanning co-optimized with compilation, throughput-safe
latency balancing, and HBM/channel binding."""
from .autobridge import (FloorplanCache, Plan, autobridge, floorplan_counts,
                         reset_floorplan_counts)
from .balance import BalanceResult, CycleError, balance_graph, balance_latencies
from .devicegrid import Boundary, SlotGrid
from .floorplan import Floorplan, floorplan
from .graph import Stream, Task, TaskGraph, TaskGraphBuilder
from .explorer import (BackendSweep, Candidate, ConvergedSearch,
                       DeferredSearch, Interval, SearchPoint,
                       SearchResult, SearchSpace, best_candidate,
                       explore_design_space, explore_floorplans,
                       hypervolume, pareto_frontier, pareto_indices,
                       pool_simulations, prepare_design_space,
                       search_until_converged, sweep_backends,
                       timed_pool_simulations)
from .fmax_model import PhysicalModel, TimingReport, analyze_timing, packed_placement
from .ilp import InfeasibleError
from .pipelining import PipelineAssignment, assign_pipelining
from .simulate import (SimJob, SimResult, StreamProfile, engine_counts,
                       pipeline_headroom, reset_engine_counts, simulate,
                       simulate_batch)

__all__ = [
    "FloorplanCache", "Plan", "autobridge", "floorplan_counts",
    "reset_floorplan_counts",
    "BalanceResult", "CycleError", "balance_graph",
    "balance_latencies", "Boundary", "SlotGrid", "Floorplan", "floorplan",
    "Stream", "Task", "TaskGraph", "TaskGraphBuilder", "InfeasibleError",
    "PipelineAssignment", "assign_pipelining",
    "BackendSweep", "Candidate", "ConvergedSearch", "DeferredSearch",
    "best_candidate", "explore_floorplans", "pool_simulations",
    "prepare_design_space", "search_until_converged", "sweep_backends",
    "timed_pool_simulations",
    "Interval", "SearchPoint", "SearchResult", "SearchSpace",
    "explore_design_space", "hypervolume",
    "pareto_frontier", "pareto_indices",
    "PhysicalModel", "TimingReport", "analyze_timing", "packed_placement",
    "SimJob", "SimResult", "StreamProfile", "engine_counts",
    "pipeline_headroom", "reset_engine_counts", "simulate", "simulate_batch",
]
