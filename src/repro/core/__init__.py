"""repro.core — the paper's contribution: task-parallel dataflow graphs,
coarse-grained floorplanning co-optimized with compilation, throughput-safe
latency balancing, and HBM/channel binding.

The design-space search names (``explore_design_space``,
``search_until_converged``, ``SearchSpace``, ...) now live in
``repro.search`` and are re-exported here lazily (PEP 562): the search
package imports this package's submodules, so an eager import would be
circular.  ``from repro.core import explore_design_space`` keeps working
exactly as before."""
from .autobridge import (FloorplanCache, Plan, autobridge, floorplan_counts,
                         initial_floorplan_key, merge_floorplan_counts,
                         reset_floorplan_counts)
from .balance import BalanceResult, CycleError, balance_graph, balance_latencies
from .devicegrid import Boundary, SlotGrid
from .floorplan import Floorplan, floorplan
from .graph import Stream, Task, TaskGraph, TaskGraphBuilder
from .fmax_model import PhysicalModel, TimingReport, analyze_timing, packed_placement
from .ilp import InfeasibleError
from .pipelining import PipelineAssignment, assign_pipelining
from .simulate import (SimJob, SimResult, StreamProfile, engine_counts,
                       pipeline_headroom, reset_engine_counts, simulate,
                       simulate_batch)

#: names re-exported from ``repro.search`` (resolved lazily via
#: ``__getattr__`` below to break the core <-> search import cycle)
_SEARCH_EXPORTS = (
    "BackendSweep", "Candidate", "ConvergedSearch", "DeferredSearch",
    "DiskFloorplanStore", "FaultPlan", "Interval", "SearchJournal",
    "SearchPoint", "SearchResult", "SearchSpace",
    "best_candidate", "explore_design_space", "explore_floorplans",
    "gather_sim_jobs", "hypervolume", "measure_backend_speedup",
    "pareto_frontier", "pareto_indices", "pool_simulations",
    "prepare_design_space", "scatter_sim_results", "search_until_converged",
    "sweep_backends", "timed_pool_simulations",
)

__all__ = [
    "FloorplanCache", "Plan", "autobridge", "floorplan_counts",
    "initial_floorplan_key", "merge_floorplan_counts",
    "reset_floorplan_counts",
    "BalanceResult", "CycleError", "balance_graph",
    "balance_latencies", "Boundary", "SlotGrid", "Floorplan", "floorplan",
    "Stream", "Task", "TaskGraph", "TaskGraphBuilder", "InfeasibleError",
    "PipelineAssignment", "assign_pipelining",
    "PhysicalModel", "TimingReport", "analyze_timing", "packed_placement",
    "SimJob", "SimResult", "StreamProfile", "engine_counts",
    "pipeline_headroom", "reset_engine_counts", "simulate", "simulate_batch",
    *_SEARCH_EXPORTS,
]


def __getattr__(name: str):
    if name in _SEARCH_EXPORTS:
        import repro.search as _search
        return getattr(_search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SEARCH_EXPORTS))
