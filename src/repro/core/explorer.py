"""Backward-compatibility alias: the design-space search moved to
``repro.search`` (PR 5's first-class search subsystem).

``repro.core.explorer`` *is* ``repro.search.engine`` — this module replaces
itself in ``sys.modules`` with the engine module, so every historical use
keeps working unchanged: ``from repro.core.explorer import
explore_design_space``, reaching into internals (``_objective``), and even
monkeypatching module attributes (``explorer_mod.simulate_batch``) all hit
the real engine.  New code should import from ``repro.search`` directly;
see ``docs/search-guide.md``.
"""
import sys

from repro.search import engine as _engine

sys.modules[__name__] = _engine
