"""Batched design-space search over co-optimization knobs (paper §6.3++).

The paper's multi-floorplan methodology "implements all candidates in
parallel and keeps the best", sweeping the per-slot max-utilization knob.
This module generalizes that single axis into a *joint* search space:

    seed x max_util x row/col boundary weight x pipeline depth scale

``SearchSpace`` enumerates joint configurations (full grid or random
sampling); ``explore_design_space`` runs the floorplan -> pipeline ->
balance co-optimization per point, scores every feasible candidate with the
physical model, checks all candidates' throughput in a handful of
``simulate_batch`` calls (the candidates share the design's topology, so
hundreds of variants vectorize into one NumPy sweep), and prunes the result
to the Pareto frontier over (fmax, area overhead, simulated cycles).

Two structural facts keep the search cheap:

  * the floorplan ILP is invariant to ``depth_scale`` (register depth never
    appears in the partitioning objective), so depth variants of one
    (seed, util, weights) cell reuse the expensive floorplan and only re-run
    pipelining + balancing;
  * throughput evaluation is batched: one ``simulate_batch`` call scores the
    shared unpipelined baseline plus every feasible candidate.

With ``fifo_sizing=True`` frontier candidates are additionally profiled by
the event engine (per-stream occupancy histograms from the push/pop logs)
and their FIFO headroom re-sized to the *observed* peak occupancy instead
of the uniform ``2*latency`` round-trip term — trimming to the observed
peak provably preserves the simulated schedule, so the verification batch
must reproduce the same cycle count.

``explore_floorplans`` remains as a thin single-axis compatibility wrapper.
"""
from __future__ import annotations

import copy
import dataclasses
import itertools
import random
from typing import Callable, Sequence

from .autobridge import Plan, autobridge
from .balance import CycleError, balance_graph
from .devicegrid import SlotGrid
from .fmax_model import PhysicalModel, TimingReport, analyze_timing
from .graph import TaskGraph
from .ilp import InfeasibleError
from .pipelining import assign_pipelining
from .simulate import (SimJob, SimResult, StreamProfile, simulate,
                       simulate_batch)

#: the paper's §6.3 max-util sweep (Table 10)
DEFAULT_UTILS = (0.55, 0.60, 0.65, 0.70, 0.75, 0.80, 0.85)


@dataclasses.dataclass(frozen=True)
class SearchPoint:
    """One joint knob configuration."""
    seed: int = 0
    max_util: float = 0.70
    row_weight: float = 1.0
    col_weight: float = 1.0
    depth_scale: float = 1.0

    @property
    def floorplan_key(self) -> tuple:
        """Axes the floorplan depends on.  ``depth_scale`` only affects
        pipelining/balancing, so depth variants share one floorplan."""
        return (self.seed, self.max_util, self.row_weight, self.col_weight)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Axis values of the joint search.  ``grid_points`` enumerates the full
    cartesian product; ``sample`` draws points without replacement (uniform
    over the product) for spaces too big to sweep exhaustively."""
    seeds: tuple[int, ...] = (0,)
    utils: tuple[float, ...] = DEFAULT_UTILS
    row_weights: tuple[float, ...] = (1.0,)
    col_weights: tuple[float, ...] = (1.0,)
    depth_scales: tuple[float, ...] = (1.0,)

    @property
    def size(self) -> int:
        return (len(self.seeds) * len(self.utils) * len(self.row_weights)
                * len(self.col_weights) * len(self.depth_scales))

    def _decode(self, idx: int) -> SearchPoint:
        """Mixed-radix decode of a flat product index (depth_scale fastest,
        seed slowest — matches ``itertools.product`` order)."""
        axes = (self.seeds, self.utils, self.row_weights, self.col_weights,
                self.depth_scales)
        vals = []
        for ax in reversed(axes):
            idx, r = divmod(idx, len(ax))
            vals.append(ax[r])
        d, c, w, u, s = vals
        return SearchPoint(seed=s, max_util=u, row_weight=w, col_weight=c,
                           depth_scale=d)

    def grid_points(self) -> list[SearchPoint]:
        return [SearchPoint(seed=s, max_util=u, row_weight=rw, col_weight=cw,
                            depth_scale=d)
                for s, u, rw, cw, d in itertools.product(
                    self.seeds, self.utils, self.row_weights,
                    self.col_weights, self.depth_scales)]

    def sample(self, n: int, *, seed: int = 0) -> list[SearchPoint]:
        """``n`` distinct points drawn uniformly from the product (the whole
        space, in grid order, when ``n >= size``)."""
        if n >= self.size:
            return self.grid_points()
        rng = random.Random(seed)
        return [self._decode(i) for i in rng.sample(range(self.size), n)]


@dataclasses.dataclass
class Candidate:
    max_util: float
    plan: Plan | None
    report: TimingReport | None
    error: str | None = None
    #: dataflow-simulated cycles of the pipelined+balanced design (filled by
    #: the batched throughput evaluation; None when not requested/feasible)
    sim: SimResult | None = None
    #: cycles of the unpipelined baseline design (shared across candidates)
    base_sim: SimResult | None = None
    #: the joint knob configuration that produced this candidate
    point: SearchPoint | None = None
    #: event-engine occupancy profiles (``fifo_sizing``, frontier only)
    profile: dict[str, StreamProfile] | None = None
    #: per-stream FIFO headroom re-sized to observed peak occupancy
    #: (reverted to None if the verification batch saw different cycles)
    sized_capacity: dict[str, int] | None = None
    #: verified run of the re-sized design — cycle-identical to the
    #: uniform-headroom reference at the same firing count, or None if the
    #: sizing was reverted
    sized_sim: SimResult | None = None

    @property
    def fmax(self) -> float:
        return self.report.fmax_mhz if self.report else 0.0

    @property
    def throughput_preserved(self) -> bool | None:
        """True iff the simulated candidate kept the baseline's steady-state
        throughput (only fill/drain skew added).  None when not simulated."""
        if self.sim is None or self.base_sim is None or self.plan is None:
            return None
        if self.sim.deadlocked:
            return False
        skew = sum(self.plan.depth.values()) + self.plan.graph.num_tasks
        return self.sim.cycles <= self.base_sim.cycles + skew

    @property
    def fifo_savings_bits(self) -> float | None:
        """Width-weighted capacity saved by profile-driven sizing vs the
        uniform ``2*latency`` headroom (None until sized)."""
        if self.sized_capacity is None or self.plan is None:
            return None
        width = {s.name: s.width for s in self.plan.graph.streams}
        uniform = self.plan.sim_extra_capacity
        return sum((uniform.get(n, 0) - e) * width.get(n, 0.0)
                   for n, e in self.sized_capacity.items())


# ---------------------------------------------------------------------------
# Pareto pruning
# ---------------------------------------------------------------------------

def pareto_indices(vectors: Sequence[tuple]) -> list[int]:
    """Indices of non-dominated vectors; every objective is maximized.

    ``a`` dominates ``b`` iff ``a >= b`` element-wise with at least one
    strict inequality — so points with *identical* vectors never dominate
    each other and are all kept (tie handling)."""
    keep = []
    for i, vi in enumerate(vectors):
        dominated = False
        for j, vj in enumerate(vectors):
            if j == i:
                continue
            if (all(a >= b for a, b in zip(vj, vi))
                    and any(a > b for a, b in zip(vj, vi))):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


def pareto_frontier(cands: Sequence[Candidate]) -> list[Candidate]:
    """Feasible, routed, non-deadlocked candidates that are Pareto-optimal
    over (fmax up, area_overhead down, simulated cycles down)."""
    ok = [c for c in cands
          if c.plan is not None and c.report and c.report.routed
          and (c.sim is None or not c.sim.deadlocked)]
    vecs = [(c.report.fmax_mhz, -c.plan.area_overhead,
             -(c.sim.cycles if c.sim is not None else 0)) for c in ok]
    return [ok[i] for i in pareto_indices(vecs)]


# ---------------------------------------------------------------------------
# joint search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    #: every evaluated configuration, in enumeration order (failures kept —
    #: the paper's Table 10 reports those as 'Failed')
    candidates: list[Candidate]
    #: Pareto-optimal subset over (fmax, area_overhead, sim cycles)
    frontier: list[Candidate]
    #: number of ``simulate_batch`` calls the search issued
    sim_calls: int
    #: number of configurations evaluated
    space_size: int

    @property
    def best(self) -> Candidate:
        """Highest-fmax routable candidate (frontier first)."""
        return best_candidate(self.frontier or self.candidates)


def _derive_depth_variant(graph: TaskGraph, grid: SlotGrid, base: Plan,
                          pt: SearchPoint,
                          **ab_kwargs) -> Plan | InfeasibleError:
    """Re-pipeline + re-balance ``base``'s floorplan under ``pt``'s depth
    scale.  The floorplan is depth-invariant, so this skips the ILP; a
    (theoretically unreachable) balance cycle falls back to a full
    autobridge run with the point's knobs."""
    sgrid = grid.with_knobs(row_weight=pt.row_weight, col_weight=pt.col_weight,
                            depth_scale=pt.depth_scale)
    fp = dataclasses.replace(base.floorplan, grid=sgrid)
    pa = assign_pipelining(graph, fp)
    try:
        bal = balance_graph(graph, pa.lat)
    except CycleError:
        try:
            return autobridge(graph, grid, max_util=pt.max_util, seed=pt.seed,
                              row_weight=pt.row_weight,
                              col_weight=pt.col_weight,
                              depth_scale=pt.depth_scale, **ab_kwargs)
        except InfeasibleError as err:
            return err
    depth = {name: pa.lat[name] + bal.balance[name] for name in pa.lat}
    width = {s.name: s.width for s in graph.streams}
    overhead = sum(d * width[n] for n, d in depth.items())
    return Plan(graph=graph, floorplan=fp, pipelining=pa, balancing=bal,
                depth=depth, area_overhead=overhead,
                feedback_rounds=base.feedback_rounds,
                co_located=base.co_located,
                demoted_streams=list(base.demoted_streams))


def explore_design_space(graph: TaskGraph, grid: SlotGrid, *,
                         space: SearchSpace | None = None,
                         mode: str = "grid",
                         n_samples: int = 64,
                         sample_seed: int = 0,
                         model: PhysicalModel = PhysicalModel(),
                         score: Callable[[Plan], TimingReport] | None = None,
                         sim_firings: int | None = None,
                         fifo_sizing: bool = False,
                         fifo_firings: int | None = None,
                         **ab_kwargs) -> SearchResult:
    """Joint batched design-space search (see module docstring).

    mode         — "grid" sweeps the full cartesian product of ``space``;
                   "random" draws ``n_samples`` distinct points from it
    sim_firings  — when set, score *all* feasible candidates' throughput in
                   one vectorized ``simulate_batch`` call (plus the shared
                   unpipelined baseline)
    fifo_sizing  — profile frontier candidates with the event engine and
                   re-size their FIFO headroom to observed peak occupancy;
                   one more batch call verifies cycles are unchanged
    ab_kwargs    — forwarded to ``autobridge`` (e.g. ``same_slot``)
    """
    space = space or SearchSpace()
    if mode == "grid":
        points = space.grid_points()
    elif mode == "random":
        points = space.sample(n_samples, seed=sample_seed)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    cands: list[Candidate] = []
    plans: dict[tuple, tuple[float, Plan | InfeasibleError]] = {}
    # autobridge's cycle-breaking last resort mutates the input graph
    # (stream demotion, autobridge.py) — under a joint sweep that would
    # leak one point's demotion into every later point, the shared
    # baseline, and the caller's graph.  Snapshot the control flags and
    # confine any demotion to a per-candidate graph copy.
    ctrl0 = [s.control for s in graph.streams]

    def _restore_ctrl() -> bool:
        changed = False
        for s, c0 in zip(graph.streams, ctrl0):
            if s.control != c0:
                s.control = c0
                changed = True
        return changed

    def _run_autobridge(g: TaskGraph, pt: SearchPoint):
        return autobridge(g, grid, max_util=pt.max_util, seed=pt.seed,
                          row_weight=pt.row_weight, col_weight=pt.col_weight,
                          depth_scale=pt.depth_scale, **ab_kwargs)

    for pt in points:
        entry = plans.get(pt.floorplan_key)
        if entry is None:
            try:
                made = _run_autobridge(graph, pt)
            except InfeasibleError as err:
                made = err
            if _restore_ctrl() and not isinstance(made, InfeasibleError):
                # this point needs the demotion: re-run on a private copy so
                # the candidate keeps a consistent graph while the shared
                # one stays pristine (simulate_batch detects the topology
                # split and falls back to per-job event simulation for it)
                try:
                    made = _run_autobridge(copy.deepcopy(graph), pt)
                except InfeasibleError as err:
                    made = err
                _restore_ctrl()
            entry = (pt.depth_scale, made)
            plans[pt.floorplan_key] = entry
        base_scale, base = entry
        if isinstance(base, InfeasibleError):
            cands.append(Candidate(max_util=pt.max_util, plan=None,
                                   report=None, error=str(base), point=pt))
            continue
        if pt.depth_scale == base_scale:
            plan = base
        else:
            plan = _derive_depth_variant(base.graph, grid, base, pt,
                                         **ab_kwargs)
            if isinstance(plan, InfeasibleError):
                cands.append(Candidate(max_util=pt.max_util, plan=None,
                                       report=None, error=str(plan),
                                       point=pt))
                continue
        if score is not None:
            rep = score(plan)
        else:
            rep = analyze_timing(plan.graph, grid, plan.floorplan.placement,
                                 plan.depth, model)
        cands.append(Candidate(max_util=pt.max_util, plan=plan, report=rep,
                               point=pt))

    sim_calls = 0
    if sim_firings:
        feasible = [c for c in cands if c.plan is not None]
        if feasible:
            jobs = [SimJob(graph)] + [c.plan.sim_job() for c in feasible]
            results = simulate_batch(jobs, firings=sim_firings)
            sim_calls += 1
            base_res = results[0]
            for c, res in zip(feasible, results[1:]):
                c.sim = res
                c.base_sim = base_res

    frontier = pareto_frontier(cands)

    if fifo_sizing and frontier:
        firings = fifo_firings or sim_firings or 200
        jobs = []
        for c in frontier:
            g = c.plan.graph
            prof = simulate(g, firings=firings, latency=c.plan.depth,
                            extra_capacity=c.plan.sim_extra_capacity,
                            profile=True)
            c.profile = prof.profiles
            # observed-peak trimming: occupancy never exceeded peak, so
            # capacity=peak admits the exact same firing schedule
            declared = {s.name: int(s.depth) for s in g.streams}
            c.sized_capacity = {name: max(0, p.peak - declared[name])
                                for name, p in prof.profiles.items()}
            # sized variant paired with its uniform-headroom reference at
            # the *same* firing count, so the verdict below is well-defined
            # even when fifo_firings != sim_firings
            jobs.append(SimJob(g, latency=dict(c.plan.depth),
                               extra_capacity=dict(c.sized_capacity)))
            jobs.append(c.plan.sim_job())
        results = simulate_batch(jobs, firings=firings)
        sim_calls += 1
        for i, c in enumerate(frontier):
            sized, uniform = results[2 * i], results[2 * i + 1]
            if sized.deadlocked or sized.cycles != uniform.cycles:
                # trimming broke the schedule (theoretically unreachable):
                # revert rather than hand out an unverified sizing
                c.sized_capacity = None
                c.sized_sim = None
            else:
                c.sized_sim = sized

    return SearchResult(candidates=cands, frontier=frontier,
                        sim_calls=sim_calls, space_size=len(points))


# ---------------------------------------------------------------------------
# single-axis compatibility wrapper (paper §6.3 verbatim)
# ---------------------------------------------------------------------------

def explore_floorplans(graph: TaskGraph, grid: SlotGrid, *,
                       utils: tuple[float, ...] = DEFAULT_UTILS,
                       seed: int = 0,
                       model: PhysicalModel = PhysicalModel(),
                       score: Callable[[Plan], TimingReport] | None = None,
                       sim_firings: int | None = None,
                       **ab_kwargs) -> list[Candidate]:
    """Single-axis max-util sweep: one candidate per util point, in sweep
    order, infeasible points kept as failed candidates (paper Table 10).
    Thin wrapper over ``explore_design_space`` with every other axis pinned
    to its default."""
    space = SearchSpace(seeds=(seed,), utils=tuple(utils))
    res = explore_design_space(graph, grid, space=space, model=model,
                               score=score, sim_firings=sim_firings,
                               **ab_kwargs)
    return res.candidates


def best_candidate(cands: list[Candidate]) -> Candidate:
    ok = [c for c in cands
          if c.plan is not None and c.report and c.report.routed
          and (c.sim is None or not c.sim.deadlocked)]
    if not ok:
        raise InfeasibleError("no routable floorplan candidate")
    return max(ok, key=lambda c: c.report.fmax_mhz)
