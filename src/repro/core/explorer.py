"""Multi-floorplan candidate generation (paper §6.3).

HBM designs trade local logic pressure against global routing pressure; the
paper sweeps the per-slot max-utilization knob to generate a set of
Pareto-optimal floorplans and implements all of them in parallel, keeping
the best.  We do the same: sweep ``max_util``, run the full
floorplan->pipeline->balance co-optimization for each value, score every
candidate with the physical model (FPGA) or the roofline step-time model
(TPU), and return all candidates sorted by score.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .autobridge import Plan, autobridge
from .devicegrid import SlotGrid
from .fmax_model import PhysicalModel, TimingReport, analyze_timing
from .graph import TaskGraph
from .ilp import InfeasibleError
from .simulate import SimJob, SimResult, simulate_batch


@dataclasses.dataclass
class Candidate:
    max_util: float
    plan: Plan | None
    report: TimingReport | None
    error: str | None = None
    #: dataflow-simulated cycles of the pipelined+balanced design (filled by
    #: the batched throughput evaluation; None when not requested/feasible)
    sim: SimResult | None = None
    #: cycles of the unpipelined baseline design (shared across candidates)
    base_sim: SimResult | None = None

    @property
    def fmax(self) -> float:
        return self.report.fmax_mhz if self.report else 0.0

    @property
    def throughput_preserved(self) -> bool | None:
        """True iff the simulated candidate kept the baseline's steady-state
        throughput (only fill/drain skew added).  None when not simulated."""
        if self.sim is None or self.base_sim is None or self.plan is None:
            return None
        if self.sim.deadlocked:
            return False
        skew = sum(self.plan.depth.values()) + self.plan.graph.num_tasks
        return self.sim.cycles <= self.base_sim.cycles + skew


def explore_floorplans(graph: TaskGraph, grid: SlotGrid, *,
                       utils: tuple[float, ...] = (0.55, 0.60, 0.65, 0.70,
                                                   0.75, 0.80, 0.85),
                       seed: int = 0,
                       model: PhysicalModel = PhysicalModel(),
                       score: Callable[[Plan], TimingReport] | None = None,
                       sim_firings: int | None = None,
                       **ab_kwargs) -> list[Candidate]:
    """Generate one candidate per max-util point ("implement all of them in
    parallel", paper Table 10).  Infeasible points are kept as failed
    candidates — the paper's Table 10 reports those as 'Failed'.

    With ``sim_firings`` set, every feasible candidate's throughput is
    checked by dataflow simulation in *one* ``simulate_batch`` call (the
    candidates share the design's topology, so the sweep vectorizes across
    max-util points instead of re-running the per-cycle loop per plan).
    """
    out: list[Candidate] = []
    for u in utils:
        try:
            plan = autobridge(graph, grid, max_util=u, seed=seed, **ab_kwargs)
        except InfeasibleError as err:
            out.append(Candidate(max_util=u, plan=None, report=None,
                                 error=str(err)))
            continue
        if score is not None:
            rep = score(plan)
        else:
            rep = analyze_timing(graph, grid, plan.floorplan.placement,
                                 plan.depth, model)
        out.append(Candidate(max_util=u, plan=plan, report=rep))
    if sim_firings:
        feasible = [c for c in out if c.plan is not None]
        if feasible:
            jobs = [SimJob(graph)] + [c.plan.sim_job() for c in feasible]
            results = simulate_batch(jobs, firings=sim_firings)
            base = results[0]
            for c, res in zip(feasible, results[1:]):
                c.sim = res
                c.base_sim = base
    return out


def best_candidate(cands: list[Candidate]) -> Candidate:
    ok = [c for c in cands
          if c.plan is not None and c.report and c.report.routed
          and (c.sim is None or not c.sim.deadlocked)]
    if not ok:
        raise InfeasibleError("no routable floorplan candidate")
    return max(ok, key=lambda c: c.report.fmax_mhz)
