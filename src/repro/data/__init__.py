from .pipeline import SyntheticTokens, MemmapTokens, ShardedLoader
__all__ = ["SyntheticTokens", "MemmapTokens", "ShardedLoader"]
