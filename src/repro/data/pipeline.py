"""Token data pipeline: synthetic + memmap-backed sources with a sharded,
background-prefetching loader.

Production layout: each data-parallel host reads its own shard (shard =
host index over the (pod, data) axes — the floorplanner binds data_in
tasks to ingest slots the same way it binds HBM channels).  Prefetch
runs in a thread so host IO overlaps device compute.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic corpus: mixture of Zipfian unigrams and
    shifted repeats, so language models actually have something to learn
    (loss decreases measurably within a few hundred steps)."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, shard: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=(batch, seq + 1), p=probs)
        # inject learnable structure: second half repeats the first half
        half = (seq + 1) // 2
        toks[:, half:half * 2] = toks[:, :half]
        return toks.astype(np.int32)


class MemmapTokens:
    """Flat uint16/uint32 token file, memory-mapped; shard-strided reads."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16, seed: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, shard: int, batch: int, seq: int) -> np.ndarray:
        n = len(self.data) - (seq + 1)
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        starts = rng.integers(0, n, size=batch)
        return np.stack([self.data[s:s + seq + 1] for s in starts]) \
            .astype(np.int32)


class ShardedLoader:
    """Background prefetch of per-shard batches."""

    def __init__(self, source, *, shard: int, batch: int, seq: int,
                 prefetch: int = 2):
        self.source, self.shard, self.batch, self.seq = \
            source, shard, batch, seq
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = 0
        while not self._stop.is_set():
            b = self.source.batch(step, self.shard, self.batch, self.seq)
            while not self._stop.is_set():
                try:
                    self.q.put(b, timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
