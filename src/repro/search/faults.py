"""Deterministic fault injection for the crash-safe search stack.

The robustness contract of ``repro.search`` (disk store, checkpointed
``search_until_converged``, hardened worker pool) is *bit-identical results
under failure*: a worker crash, a hung solve, a torn store write or a
SIGKILL between rounds may cost wall time and tick counters, but must never
change the produced frontier.  Proving that needs failures on demand, and
needs them **reproducible** — a chaos run that flakes is worse than no
chaos run.

``FaultPlan`` is that reproducible failure schedule.  Every decision is a
pure function of ``(plan.seed, site, token, attempt)`` — no global RNG, no
wall clock — so the same plan against the same workload injects the same
faults every time, in every process:

    with install(FaultPlan(seed=7, worker_crash=0.5)):
        ...                      # ~half of first-attempt solves die

Sites (each a field on the plan; rate 0 disables the site):

====================  =====================================================
``worker_crash``      pool worker calls ``os._exit`` before solving
``worker_hang``       pool worker sleeps ``hang_s`` (trips the pool timeout)
``torn_write``        the disk store truncates an entry blob mid-write
``parent_kill``       the search process SIGKILLs itself after round
                      ``kill_after_round`` (checkpoint-resume drill)
====================  =====================================================

Crash/hang faults are *transient* by default (``attempts=1``): a selected
token faults on its first ``attempt`` and succeeds on the retry, which is
exactly the failure the pool's retry machinery must absorb.  Set
``attempts`` high to model a *poison* input that kills every worker it
touches — the pool must quarantine it, not retry forever.

Plans propagate to subprocesses via the ``REPRO_FAULTS`` environment
variable (a JSON dict of plan fields), so spawn-context pool workers and
benchmark child processes observe the same schedule as the parent.
``install()`` sets both the in-process plan and the env var.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import random
import signal
import time

from ..obs import metrics as _metrics

#: env var carrying a JSON-encoded plan to subprocesses
ENV_VAR = "REPRO_FAULTS"

#: fault sites whose rate is a plan field
_RATE_SITES = ("worker_crash", "worker_hang", "torn_write")

# Faults injected by THIS process since the last reset.  Worker-side
# injections die with the worker; the pool counts those at dispatch time
# (same seeded decision, taken parent-side) so BENCH JSON can report
# injected-vs-observed without cross-process plumbing.
_FAULT_COUNTS = _metrics.group(
    "faults", {site: 0 for site in _RATE_SITES} | {"parent_kill": 0})


def reset_fault_counts() -> None:
    """Zero this process's injected-fault counters."""
    _FAULT_COUNTS.reset()


def fault_counts() -> dict[str, int]:
    """Snapshot of faults injected (or counted at dispatch) per site."""
    return dict(_FAULT_COUNTS)


def count_injected(site: str) -> None:
    """Record an injection decided on behalf of another process (the pool
    counts worker crash/hang selections at dispatch, because the worker's
    own counter dies with it)."""
    _FAULT_COUNTS[site] += 1


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic failure schedule (see module docstring)."""
    seed: int = 0
    #: per-site selection rates in [0, 1]; 0 disables the site
    worker_crash: float = 0.0
    worker_hang: float = 0.0
    torn_write: float = 0.0
    #: SIGKILL the search process after this checkpoint round (None = never)
    kill_after_round: int | None = None
    #: a selected token faults on attempts ``0..attempts-1`` then succeeds;
    #: large values model a poison input that faults forever
    attempts: int = 1
    #: sleep length of an injected hang (set well above the pool timeout)
    hang_s: float = 30.0

    def decide(self, site: str, token: str, attempt: int = 0) -> bool:
        """Pure seeded decision: does ``site`` fault for ``token`` on this
        ``attempt``?  Same inputs -> same answer, in every process."""
        if site == "parent_kill":
            return (self.kill_after_round is not None
                    and int(token) == int(self.kill_after_round))
        rate = getattr(self, site)
        if rate <= 0.0 or attempt >= self.attempts:
            return False
        return random.Random(f"{self.seed}:{site}:{token}").random() < rate

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


_PLAN: FaultPlan | None = None


def active_plan() -> FaultPlan | None:
    """The installed plan, else one parsed from ``REPRO_FAULTS`` (how
    spawn-context workers and benchmark children inherit the schedule)."""
    if _PLAN is not None:
        return _PLAN
    raw = os.environ.get(ENV_VAR)
    if not raw:
        return None
    try:
        return FaultPlan.from_dict(json.loads(raw))
    except (ValueError, TypeError):
        return None


@contextlib.contextmanager
def install(plan: FaultPlan | None, *, env: bool = True):
    """Activate ``plan`` for the enclosed block (and, with ``env=True``,
    for subprocesses started inside it).  ``install(None)`` masks any
    ambient ``REPRO_FAULTS`` so a block provably runs clean."""
    global _PLAN
    prev_plan, prev_env = _PLAN, os.environ.get(ENV_VAR)
    _PLAN = plan
    if env:
        if plan is None:
            os.environ.pop(ENV_VAR, None)
        else:
            os.environ[ENV_VAR] = json.dumps(plan.as_dict())
    try:
        yield plan
    finally:
        _PLAN = prev_plan
        if env:
            if prev_env is None:
                os.environ.pop(ENV_VAR, None)
            else:
                os.environ[ENV_VAR] = prev_env


def fire(site: str, token: str, attempt: int = 0) -> bool:
    """Inject ``site`` for ``token`` if the active plan selects it.

    Side effects happen here: ``worker_crash`` hard-exits the process,
    ``worker_hang`` sleeps ``plan.hang_s``, ``parent_kill`` SIGKILLs the
    process.  ``torn_write`` only counts and returns True — the store owns
    the actual corruption (it truncates the blob it was about to write).
    Returns False (a no-op) when no plan is active or the site passes."""
    plan = active_plan()
    if plan is None or not plan.decide(site, token, attempt):
        return False
    _FAULT_COUNTS[site] += 1
    if site == "worker_crash":
        os._exit(23)
    elif site == "worker_hang":
        time.sleep(plan.hang_s)
    elif site == "parent_kill":
        os.kill(os.getpid(), signal.SIGKILL)
    return True
