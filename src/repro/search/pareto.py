"""Pareto-frontier primitives shared by every search engine.

Vector-level only: non-domination over maximized objective tuples
(``pareto_indices``) and the exact hypervolume indicator the converging
search watches (``hypervolume``).  Candidate-level pruning — which
candidates are feasible, what their objective vector is — lives in
``repro.search.engine``.
"""
from __future__ import annotations

from typing import Sequence


def objective_vector(c) -> tuple[float, float, float]:
    """The maximized objective vector of a feasible candidate — the ONE
    definition of the search's Pareto axes: (fmax, -area overhead,
    -simulated cycles).  Shared by the engine's frontier pruning, the
    hypervolume trajectory and the surrogate's training targets, so the
    axes cannot silently drift apart."""
    return (c.report.fmax_mhz, -c.plan.area_overhead,
            -(c.sim.cycles if c.sim is not None else 0))


def pareto_indices(vectors: Sequence[tuple]) -> list[int]:
    """Indices of non-dominated vectors; every objective is maximized.

    ``a`` dominates ``b`` iff ``a >= b`` element-wise with at least one
    strict inequality — so points with *identical* vectors never dominate
    each other and are all kept (tie handling)."""
    keep = []
    for i, vi in enumerate(vectors):
        dominated = False
        for j, vj in enumerate(vectors):
            if j == i:
                continue
            if (all(a >= b for a, b in zip(vj, vi))
                    and any(a > b for a, b in zip(vj, vi))):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


def hypervolume(vectors: Sequence[tuple], ref: Sequence[float]) -> float:
    """Exact hypervolume of a maximized point set w.r.t. reference ``ref``.

    The dominated volume between ``ref`` and the points — the standard
    Pareto-frontier quality indicator ``search_until_converged`` watches.
    Points are clipped to ``ref`` (a point at or below the reference on an
    axis contributes zero extent there), so the indicator is monotone under
    adding points.  Exact recursive slicing: fine for the tens-of-points
    frontiers this search produces, any dimensionality.

    >>> hypervolume([(2.0, 2.0)], (0.0, 0.0))
    4.0
    >>> hypervolume([(2.0, 1.0), (1.0, 2.0)], (0.0, 0.0))
    3.0
    >>> hypervolume([(2.0, 1.0), (1.0, 2.0), (1.5, 1.5)], (0.0, 0.0))
    3.25
    >>> hypervolume([], (0.0, 0.0))
    0.0
    """
    ref = tuple(ref)
    pts = [tuple(max(v, r) for v, r in zip(p, ref)) for p in vectors]
    pts = [p for p in pts if any(v > r for v, r in zip(p, ref))]

    def hv(points: list[tuple], r: tuple) -> float:
        if not points:
            return 0.0
        if len(r) == 1:
            return max(p[0] for p in points) - r[0]
        # slice along the last axis, top slab first; each slab's area is the
        # (d-1)-dim hypervolume of every point reaching that high or higher
        points = sorted(points, key=lambda p: -p[-1])
        vol = 0.0
        for i, p in enumerate(points):
            lo = points[i + 1][-1] if i + 1 < len(points) else r[-1]
            thick = p[-1] - lo
            if thick > 0:
                vol += thick * hv([q[:-1] for q in points[:i + 1]], r[:-1])
        return vol

    return hv(pts, ref)
