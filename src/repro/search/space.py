"""Search-space definition: the joint co-optimization knob axes.

The paper's §6.3 sweeps a single per-slot max-utilization knob; the search
subsystem generalizes that into a *joint* space

    seed x max_util x row/col boundary weight x pipeline depth scale

where every numeric axis is either a tuple of discrete values or a
continuous ``Interval(lo, hi)``.  ``SearchSpace`` enumerates, samples and
refines this space; the engines in ``repro.search.engine`` consume the
resulting ``SearchPoint`` lists.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Sequence

#: the paper's §6.3 max-util sweep (Table 10)
DEFAULT_UTILS = (0.55, 0.60, 0.65, 0.70, 0.75, 0.80, 0.85)


@dataclasses.dataclass(frozen=True)
class SearchPoint:
    """One joint knob configuration."""
    seed: int = 0
    max_util: float = 0.70
    row_weight: float = 1.0
    col_weight: float = 1.0
    depth_scale: float = 1.0
    #: HBM channel-to-slot binding tilt (``SlotGrid.with_hbm_binding``);
    #: 0.5 = the device's symmetric default binding.  Only meaningful on
    #: grids with HBM slots — everywhere else any value is a no-op.
    hbm_split: float = 0.5

    @property
    def floorplan_key(self) -> tuple:
        """Axes the floorplan depends on.  ``depth_scale`` only affects
        pipelining/balancing, so depth variants share one floorplan."""
        return (self.seed, self.max_util, self.row_weight, self.col_weight,
                self.hbm_split)


@dataclasses.dataclass(frozen=True)
class Interval:
    """A continuous numeric axis ``[lo, hi]`` for ``SearchSpace``.

    Anywhere a ``SearchSpace`` axis accepts a tuple of discrete values it
    also accepts an ``Interval``; sampling then draws uniformly from the
    range via the seeded RNG, and ``refine`` *narrows* the range around the
    Pareto frontier's values instead of halving a grid pitch.

    >>> iv = Interval(0.6, 0.9)
    >>> iv.lo, iv.hi, round(iv.span, 2)
    (0.6, 0.9, 0.3)
    >>> Interval(0.7, 0.7).span
    0.0
    """
    lo: float
    hi: float

    def __post_init__(self):
        if not (self.lo <= self.hi):
            raise ValueError(f"Interval needs lo <= hi, got {self}")

    @property
    def span(self) -> float:
        return self.hi - self.lo

    def clamp(self, v: float) -> float:
        return min(max(v, self.lo), self.hi)


def _is_interval(axis) -> bool:
    return isinstance(axis, Interval)


def _draw_axis(axis, rng: random.Random):
    """One value from a discrete tuple (choice) or ``Interval`` (uniform)."""
    if _is_interval(axis):
        return rng.uniform(axis.lo, axis.hi)
    return axis[rng.randrange(len(axis))]


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Axis values of the joint search.

    Each numeric axis (``utils``, ``row_weights``, ``col_weights``,
    ``depth_scales``) is either a tuple of discrete values or a continuous
    ``Interval(lo, hi)``; ``seeds`` is always discrete (it is categorical).
    ``grid_points`` enumerates the full cartesian product of a fully
    discrete space; ``sample`` draws points without replacement — uniform
    over the product for discrete axes, uniform over the range for
    continuous ones.

    >>> space = SearchSpace(seeds=(0, 1), utils=(0.6, 0.7))
    >>> space.size
    4
    >>> [(p.seed, p.max_util) for p in space.grid_points()]
    [(0, 0.6), (0, 0.7), (1, 0.6), (1, 0.7)]
    >>> cont = SearchSpace(utils=Interval(0.6, 0.9))
    >>> cont.size
    inf
    >>> pts = cont.sample(4, seed=7)
    >>> len(pts) == len(set(pts)) == 4
    True
    >>> all(0.6 <= p.max_util <= 0.9 for p in pts)
    True
    >>> pts == cont.sample(4, seed=7)      # seeded, fully deterministic
    True
    """
    seeds: tuple[int, ...] = (0,)
    utils: tuple[float, ...] | Interval = DEFAULT_UTILS
    row_weights: tuple[float, ...] | Interval = (1.0,)
    col_weights: tuple[float, ...] | Interval = (1.0,)
    depth_scales: tuple[float, ...] | Interval = (1.0,)
    #: HBM channel-binding tilt axis (``SlotGrid.with_hbm_binding``); the
    #: single default value keeps the device's symmetric binding and adds
    #: nothing to the product — sweep e.g. ``(0.25, 0.5, 0.75)`` (or an
    #: ``Interval``) on HBM boards to make channel binding a search axis.
    hbm_splits: tuple[float, ...] | Interval = (0.5,)

    def _axes(self) -> tuple:
        return (self.seeds, self.utils, self.row_weights, self.col_weights,
                self.depth_scales, self.hbm_splits)

    @property
    def continuous(self) -> bool:
        """True when any axis is an ``Interval`` (the space is infinite)."""
        return any(_is_interval(ax) for ax in self._axes())

    @property
    def size(self) -> int | float:
        """Number of grid points (``math.inf`` for continuous spaces)."""
        if self.continuous:
            return math.inf
        return (len(self.seeds) * len(self.utils) * len(self.row_weights)
                * len(self.col_weights) * len(self.depth_scales)
                * len(self.hbm_splits))

    def _decode(self, idx: int) -> SearchPoint:
        """Mixed-radix decode of a flat product index (hbm_split fastest,
        seed slowest — matches ``itertools.product`` order)."""
        axes = self._axes()
        vals = []
        for ax in reversed(axes):
            idx, r = divmod(idx, len(ax))
            vals.append(ax[r])
        h, d, c, w, u, s = vals
        return SearchPoint(seed=s, max_util=u, row_weight=w, col_weight=c,
                           depth_scale=d, hbm_split=h)

    def grid_points(self) -> list[SearchPoint]:
        if self.continuous:
            raise ValueError(
                "grid enumeration needs discrete axes; this space has "
                "Interval axes — use sample()/refine() (random mode)")
        return [SearchPoint(seed=s, max_util=u, row_weight=rw, col_weight=cw,
                            depth_scale=d, hbm_split=h)
                for s, u, rw, cw, d, h in itertools.product(
                    self.seeds, self.utils, self.row_weights,
                    self.col_weights, self.depth_scales, self.hbm_splits)]

    def sample(self, n: int, *, seed: int = 0) -> list[SearchPoint]:
        """``n`` distinct points drawn uniformly from the space (the whole
        grid, in grid order, when the space is discrete and ``n >= size``).

        Continuous axes draw ``uniform(lo, hi)`` per point from the seeded
        RNG, so samples are deterministic and almost surely distinct; the
        draw loop retries collisions (possible when a continuous space also
        has small discrete axes) a bounded number of times."""
        if not self.continuous:
            if n >= self.size:
                return self.grid_points()
            rng = random.Random(seed)
            return [self._decode(i) for i in rng.sample(range(self.size), n)]
        rng = random.Random(seed)
        # the default single-valued hbm axis must not consume randomness:
        # samples from spaces that don't sweep the binding stay bit-identical
        # to the pre-hbm-axis draws (the converged-search trajectories and
        # the uniform-vs-surrogate anchors depend on that stream)
        hbm_degenerate = (not _is_interval(self.hbm_splits)
                          and len(self.hbm_splits) == 1)
        pts: list[SearchPoint] = []
        seen: set[SearchPoint] = set()
        for _ in range(20 * n + 100):
            if len(pts) >= n:
                break
            pt = SearchPoint(seed=_draw_axis(self.seeds, rng),
                             max_util=_draw_axis(self.utils, rng),
                             row_weight=_draw_axis(self.row_weights, rng),
                             col_weight=_draw_axis(self.col_weights, rng),
                             depth_scale=_draw_axis(self.depth_scales, rng),
                             hbm_split=(self.hbm_splits[0] if hbm_degenerate
                                        else _draw_axis(self.hbm_splits,
                                                        rng)))
            if pt not in seen:
                seen.add(pt)
                pts.append(pt)
        return pts

    def refined(self, frontier: Sequence) -> "SearchSpace":
        """The zoomed space around a frontier's knob values.

        Each *discrete* numeric axis keeps the frontier's values plus the
        midpoints toward the adjacent values of this space's axis — halving
        the grid pitch around every winner.  Each *continuous*
        (``Interval``) axis narrows to the frontier values' envelope padded
        by a quarter of *this* space's span (clamped into it), so repeated
        ``space = space.refined(frontier)`` shrinks the ranges
        geometrically around the winners — ``search_until_converged``
        compounds the zoom exactly this way.  Seeds are restricted to those
        the frontier used.  An empty frontier returns the space unchanged."""
        pts = [getattr(c, "point", c) for c in frontier]
        pts = [p for p in pts if p is not None]
        if not pts:
            return self

        def hood(axis, values: set):
            if _is_interval(axis):
                pad = axis.span / 4
                return Interval(axis.clamp(min(values) - pad),
                                axis.clamp(max(values) + pad))
            out = set(values)
            sv = sorted(set(axis) | set(values))
            for v in values:
                i = sv.index(v)
                if i > 0:
                    out.add((v + sv[i - 1]) / 2)
                if i + 1 < len(sv):
                    out.add((v + sv[i + 1]) / 2)
            return tuple(sorted(out))

        return SearchSpace(
            seeds=tuple(sorted({p.seed for p in pts})),
            utils=hood(self.utils, {p.max_util for p in pts}),
            row_weights=hood(self.row_weights, {p.row_weight for p in pts}),
            col_weights=hood(self.col_weights, {p.col_weight for p in pts}),
            depth_scales=hood(self.depth_scales,
                              {p.depth_scale for p in pts}),
            hbm_splits=hood(self.hbm_splits, {p.hbm_split for p in pts}))

    def refine(self, frontier: Sequence, n: int, *,
               seed: int = 0) -> list[SearchPoint]:
        """Adaptive refinement: ``n`` points sampled from the *neighborhood*
        of the frontier's knob values (ROADMAP "zoom into the frontier") —
        ``self.refined(frontier).sample(n)``.  Sampling reuses the
        ``sample`` plumbing (distinct, uniform, deterministic), so
        ``refine`` composes with repeated zooming:
        ``space.refine(res.frontier, 32)`` then search those points via
        ``explore_design_space(points=...)``, and so on.  An empty frontier
        degrades to plain sampling of this space."""
        pts = [getattr(c, "point", c) for c in frontier]
        if not any(p is not None for p in pts):
            return self.sample(n, seed=seed)
        return self.refined(frontier).sample(n, seed=seed)
