"""Crash-consistent persistence for the search stack.

Two pieces live here, sharing one checksummed-blob file format:

``DiskFloorplanStore``
    A ``FloorplanCache`` whose entries survive the process.  Entries are
    **content-addressed**: the cache key (the exact graph/grid/knob
    signature tuple ``FloorplanCache.key`` already produces) is canonical-
    ized (frozensets sorted — their iteration order is not stable across
    processes) and SHA-256 hashed into the file name, so concurrent
    writers in different processes land the same entry at the same path.
    Every write is atomic (temp file + fsync + ``os.replace``) and every
    blob is checksummed, so a reader can never observe a half-written
    entry: a torn or corrupt file is detected, moved to ``quarantine/``
    and treated as a miss — the solve re-runs, the run stays correct.

``SearchJournal``
    The per-round checkpoint of ``search_until_converged``: one pickled
    state blob per completed round plus an append-only human-readable
    ``journal.jsonl``.  Resume loads the newest *valid* state (a blob torn
    by a crash mid-checkpoint is quarantined and the previous round used),
    and a config fingerprint refuses resumption under different search
    arguments — resuming must reproduce the uninterrupted run bit for bit,
    never silently continue a different one.

Store layout (all relative to the store root)::

    entries/<sha256(key)>.fp   one cache entry (solved plan or verdict)
    quarantine/                corrupt blobs, moved aside for post-mortem
    state_r0007.pkl            round-7 checkpoint (SearchJournal)
    journal.jsonl              one JSON line per checkpointed round

Blob format: ``b"RFS1" + sha256(payload) + payload`` where payload is the
pickled ``(key, value)`` pair (or the checkpoint dict).  Truncation,
bit-rot and partial writes all fail the digest check.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
import time
from pathlib import Path

from repro.core.autobridge import FloorplanCache, _entry_values_equal

from ..obs import metrics as _metrics
from . import faults

#: blob magic: repro floorplan store, format 1
_MAGIC = b"RFS1"
_DIGEST_LEN = hashlib.sha256().digest_size

# Disk-store activity since the last reset (module-global, mirroring
# ``pool_counts``/``floorplan_counts``): benchmarks surface these in the
# BENCH JSON ``sim.store`` block and the chaos gate asserts torn entries
# really were quarantined.
_STORE_COUNTS = _metrics.group(
    "store",
    {"writes": 0, "disk_hits": 0, "disk_misses": 0,
     "quarantined": 0, "evictions": 0, "conflicts": 0})

#: disk lookup latency, labelled by outcome (hit / miss) — feeds the
#: BENCH ``sim.store.lookup_s`` block and the top-N trace summary.
_LOOKUP_HIST = _metrics.histogram("store.lookup_s")


def reset_store_counts() -> None:
    """Zero the global disk-store counters (and the lookup-latency
    histogram that rides along with them)."""
    _STORE_COUNTS.reset()
    _LOOKUP_HIST.reset()


def store_counts() -> dict[str, int]:
    """Snapshot of disk-store writes/hits/quarantines since last reset."""
    return dict(_STORE_COUNTS)


def store_lookup_stats() -> dict:
    """Disk-lookup latency aggregates per outcome (BENCH
    ``sim.store.lookup_s``): count/sum/min/max/mean seconds for disk
    hits and misses since the last reset."""
    return {"hit": _LOOKUP_HIST.aggregate(outcome="hit"),
            "miss": _LOOKUP_HIST.aggregate(outcome="miss")}


def _canonical(obj):
    """Recursively rewrite ``obj`` so equal keys stringify identically in
    every process: frozensets iterate in hash order, and string hashing is
    randomized per process, so they must be sorted before hashing."""
    if isinstance(obj, frozenset):
        return ("frozenset",) + tuple(
            sorted((_canonical(x) for x in obj), key=repr))
    if isinstance(obj, tuple):
        return tuple(_canonical(x) for x in obj)
    return obj


def key_digest(key: tuple) -> str:
    """Stable content address of a ``FloorplanCache`` key."""
    return hashlib.sha256(repr(_canonical(key)).encode()).hexdigest()


def _write_blob(path: Path, payload: bytes, *, fault_token: str | None = None,
                ) -> None:
    """Atomically write a checksummed blob: temp file in the same
    directory, fsync, then ``os.replace`` — a crash at any point leaves
    either the old file or the new one, never a mix.  ``fault_token``
    wires in the ``torn_write`` injection site: a selected write truncates
    the blob so the corruption-detection path can be drilled on demand."""
    blob = _MAGIC + hashlib.sha256(payload).digest() + payload
    if fault_token is not None and faults.fire("torn_write", fault_token):
        blob = blob[:max(len(_MAGIC), len(blob) // 2)]
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with _suppress():
            os.unlink(tmp)
        raise


def _read_blob(path: Path) -> bytes | None:
    """Read and verify a blob; None when torn/corrupt (caller quarantines)."""
    try:
        raw = path.read_bytes()
    except OSError:
        return None
    if len(raw) < len(_MAGIC) + _DIGEST_LEN or not raw.startswith(_MAGIC):
        return None
    digest = raw[len(_MAGIC):len(_MAGIC) + _DIGEST_LEN]
    payload = raw[len(_MAGIC) + _DIGEST_LEN:]
    if hashlib.sha256(payload).digest() != digest:
        return None
    return payload


class _suppress:
    """``contextlib.suppress(Exception)`` without the import noise."""
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return True


class DiskFloorplanStore(FloorplanCache):
    """A ``FloorplanCache`` backed by a content-addressed directory.

    Drop-in for every ``cache=`` parameter in the search stack: lookups
    fall through memory -> disk -> ILP solve, and every new entry (solved
    plan or infeasibility verdict) is persisted atomically on the way in.
    Multiple processes may share one root concurrently — first writer wins
    per entry, and because ``floorplan()`` is deterministic a second
    writer can only produce the identical value (verified: a disagreeing
    duplicate ticks the ``conflicts`` counter instead of being dropped
    silently).

    ``verify_on_open`` scrubs existing entries at construction: torn or
    corrupt blobs (a writer killed mid-write on a non-atomic filesystem,
    bit rot, injected ``torn_write`` faults) are moved to ``quarantine/``
    immediately, so a resumed run's store is known-good before any lookup.
    """

    def __init__(self, root: str | os.PathLike, *,
                 max_entries: int | None = None,
                 verify_on_open: bool = True) -> None:
        super().__init__()
        self.root = Path(root)
        self.entries_dir = self.root / "entries"
        self.quarantine_dir = self.root / "quarantine"
        self.entries_dir.mkdir(parents=True, exist_ok=True)
        self.quarantine_dir.mkdir(parents=True, exist_ok=True)
        self.max_entries = max_entries
        self.disk_hits = 0
        self.disk_misses = 0
        self.quarantined = 0
        # a writer killed between mkstemp and replace leaves a .tmp behind;
        # they are garbage by construction (replace is the commit point)
        for stale in self.entries_dir.glob("*.tmp"):
            with _suppress():
                stale.unlink()
        if verify_on_open:
            self.scrub()

    # -- integrity -------------------------------------------------------

    def scrub(self) -> int:
        """Validate every on-disk entry, quarantining failures; returns the
        number of entries quarantined."""
        bad = 0
        for path in sorted(self.entries_dir.glob("*.fp")):
            if self._load_entry(path) is None:
                bad += 1
        return bad

    def _quarantine(self, path: Path) -> None:
        with _suppress():
            os.replace(path, self.quarantine_dir / (path.name + ".corrupt"))
        self.quarantined += 1
        _STORE_COUNTS["quarantined"] += 1

    def _load_entry(self, path: Path) -> tuple[tuple, tuple] | None:
        """Read + verify one entry file; quarantines and returns None on
        any integrity failure."""
        payload = _read_blob(path)
        if payload is None:
            self._quarantine(path)
            return None
        try:
            key, value = pickle.loads(payload)
        except Exception:
            self._quarantine(path)
            return None
        if path.stem != key_digest(key):
            # blob is internally consistent but filed under the wrong
            # address — treat as corrupt rather than serving a wrong key
            self._quarantine(path)
            return None
        return key, value

    # -- FloorplanCache storage hooks ------------------------------------

    def _entry_path(self, key: tuple) -> Path:
        return self.entries_dir / (key_digest(key) + ".fp")

    def _lookup(self, key: tuple):
        hit = self._entries.get(key)
        if hit is not None:
            return hit
        t0 = time.perf_counter()
        path = self._entry_path(key)
        if not path.exists():
            self.disk_misses += 1
            _STORE_COUNTS["disk_misses"] += 1
            _LOOKUP_HIST.observe(time.perf_counter() - t0, outcome="miss")
            return None
        loaded = self._load_entry(path)
        if loaded is None:
            self.disk_misses += 1
            _STORE_COUNTS["disk_misses"] += 1
            _LOOKUP_HIST.observe(time.perf_counter() - t0, outcome="miss")
            return None
        self.disk_hits += 1
        _STORE_COUNTS["disk_hits"] += 1
        _LOOKUP_HIST.observe(time.perf_counter() - t0, outcome="hit")
        self._entries[key] = loaded[1]
        return loaded[1]

    def _put(self, key: tuple, value: tuple) -> bool:
        if not super()._put(key, value):
            return False
        self._persist(key, value)
        return True

    def _persist(self, key: tuple, value: tuple) -> None:
        digest = key_digest(key)
        path = self.entries_dir / (digest + ".fp")
        if path.exists():
            # another process won the race; keep its entry (first writer
            # wins) but verify determinism held
            existing = self._load_entry(path)
            if existing is not None:
                if not _entry_values_equal(existing[1], value):
                    _STORE_COUNTS["conflicts"] += 1
                return
            # existing blob was corrupt (now quarantined): rewrite below
        payload = pickle.dumps((key, value),
                               protocol=pickle.HIGHEST_PROTOCOL)
        _write_blob(path, payload, fault_token=digest)
        _STORE_COUNTS["writes"] += 1
        if self.max_entries is not None:
            self._evict()

    def _evict(self) -> None:
        entries = sorted(self.entries_dir.glob("*.fp"),
                         key=lambda p: (p.stat().st_mtime, p.name))
        while len(entries) > self.max_entries:
            victim = entries.pop(0)
            with _suppress():
                victim.unlink()
            _STORE_COUNTS["evictions"] += 1

    # -- introspection ---------------------------------------------------

    def disk_entries(self) -> int:
        """Number of (valid-or-not-yet-read) entry files on disk."""
        return sum(1 for _ in self.entries_dir.glob("*.fp"))

    def stats(self) -> dict[str, int]:
        out = super().stats()
        out.update(disk_entries=self.disk_entries(),
                   disk_hits=self.disk_hits, disk_misses=self.disk_misses,
                   quarantined=self.quarantined)
        return out


class SearchJournal:
    """Per-round checkpointing for ``search_until_converged`` (see
    ``docs/robustness-guide.md`` for the resume semantics)."""

    STATE_VERSION = 1

    def __init__(self, root: str | os.PathLike, *, config: dict) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.config = config
        self.config_path = self.root / "config.json"
        self.journal_path = self.root / "journal.jsonl"
        if self.config_path.exists():
            try:
                existing = json.loads(self.config_path.read_text())
            except ValueError:
                existing = None
            if existing is not None and existing != config:
                raise ValueError(
                    "checkpoint config mismatch: this directory belongs to "
                    "a search with different arguments — resuming it would "
                    f"not reproduce that run ({self.config_path})")
        else:
            _write_blob(self.config_path.with_suffix(".bin"),
                        json.dumps(config, sort_keys=True).encode())
            # the .json twin is for humans; the checksummed .bin is
            # authoritative only in that it survives torn writes — the
            # comparison above tolerates a missing/torn .json
            self.config_path.write_text(
                json.dumps(config, sort_keys=True, indent=1) + "\n")

    def _state_path(self, round_: int) -> Path:
        return self.root / f"state_r{round_:04d}.pkl"

    def save_round(self, round_: int, state: dict) -> None:
        """Atomically persist the end-of-round state and append the
        human-readable journal line.  The state blob is the commit point;
        a crash while appending the journal line costs nothing on resume
        (state discovery globs the blobs, the journal is informational)."""
        state = dict(state, version=self.STATE_VERSION, round=round_)
        payload = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
        _write_blob(self._state_path(round_), payload)
        line = {"round": round_,
                "hypervolume": state.get("hypervolume"),
                "frontier_size": state.get("frontier_size"),
                "points_evaluated": state.get("points_evaluated"),
                "converged": state.get("converged"),
                "state_sha256": hashlib.sha256(payload).hexdigest()}
        with open(self.journal_path, "a") as f:
            f.write(json.dumps(line, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def load_latest(self) -> dict | None:
        """The newest *valid* checkpoint state, or None for a fresh start.
        A torn newest blob (killed mid-checkpoint) is quarantined and the
        previous round used — resume never trusts an unverified blob."""
        for path in sorted(self.root.glob("state_r*.pkl"), reverse=True):
            payload = _read_blob(path)
            if payload is not None:
                try:
                    state = pickle.loads(payload)
                except Exception:
                    state = None
                if (isinstance(state, dict)
                        and state.get("version") == self.STATE_VERSION):
                    return state
            with _suppress():
                os.replace(path, path.with_suffix(".pkl.corrupt"))
            _STORE_COUNTS["quarantined"] += 1
        return None

    def rounds_on_disk(self) -> int:
        return sum(1 for _ in self.root.glob("state_r*.pkl"))
