"""Parallel floorplan solving: fan cold ILP solves out over a process pool.

The per-point ``autobridge`` ILP solve is the dominant sequential cost of a
design-space round (the AutoBridge observation the paper builds on), and the
solves of one round are independent of each other.  ``warm_floorplan_cache``
ships each *unique-floorplan* point to a ``concurrent.futures
.ProcessPoolExecutor`` worker; the worker runs the full ``autobridge``
co-optimization against a fresh ``FloorplanCache`` (capturing every solve of
the cycle-feedback chain, infeasibility verdicts included) and returns

    (its cache, its counter deltas, the error string if infeasible)

which the parent merges back — ``FloorplanCache.merge`` for the entries,
``merge_floorplan_counts`` for the per-process global counters that would
otherwise silently read 0 in the parent.  The engine then *replays* the
round in-process against the pre-warmed cache, so every floorplan lookup is
a hit and the produced candidates are **bit-identical** to a sequential run:
``floorplan()`` is deterministic, and the replay path is exactly the
``jobs=1`` code path.

``jobs=1`` never touches the pool (the exact in-process fallback); a worker
hitting ``InfeasibleError`` is a *result*, not a failure — the verdict is
cached and the replay marks the candidate failed, the pool survives.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import multiprocessing
import time
from typing import Sequence

from repro.core.autobridge import (FloorplanCache, autobridge,
                                   floorplan_counts, initial_floorplan_key,
                                   merge_floorplan_counts)
from repro.core.devicegrid import SlotGrid
from repro.core.graph import TaskGraph
from repro.core.ilp import InfeasibleError

from .space import SearchPoint

# Pool activity since the last reset (module-global, mirroring the
# simulator's ``engine_counts`` and autobridge's ``floorplan_counts``):
# benchmarks record these in the BENCH JSON ``sim.pool`` block and the CI
# gate checks a parallel run really dispatched and merged worker results.
_POOL_COUNTS = {"dispatched": 0, "merged": 0, "worker_solves": 0,
                "worker_infeasible": 0, "static_skipped": 0}


def reset_pool_counts() -> None:
    """Zero the global worker-pool dispatch/merge counters."""
    for k in _POOL_COUNTS:
        _POOL_COUNTS[k] = 0


def pool_counts() -> dict[str, int]:
    """Snapshot of pool dispatches/merges/worker solves since last reset."""
    return dict(_POOL_COUNTS)


@dataclasses.dataclass
class PoolStats:
    """One search's worker-pool activity (``ConvergedSearch.pool``)."""
    #: worker processes requested (1 = sequential, pool never created)
    jobs: int = 1
    #: points shipped to workers (unique floorplans not already cached)
    dispatched: int = 0
    #: worker results merged back into the parent cache/counters
    merged: int = 0
    #: ILP-backed ``floorplan()`` runs performed inside workers
    worker_solves: int = 0
    #: worker runs that ended in a (cached) infeasibility verdict
    worker_infeasible: int = 0
    #: points never dispatched because the parent's static structural
    #: analysis (``autobridge(check=True)`` pre-flight) doomed the graph
    static_skipped: int = 0
    #: cumulative wall time spent inside pool fan-outs
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def absorb(self, other: "PoolStats") -> None:
        """Accumulate another fan-out's stats (per-round -> per-search)."""
        self.jobs = max(self.jobs, other.jobs)
        self.dispatched += other.dispatched
        self.merged += other.merged
        self.worker_solves += other.worker_solves
        self.worker_infeasible += other.worker_infeasible
        self.static_skipped += other.static_skipped
        self.wall_s += other.wall_s


def _point_kwargs(pt: SearchPoint) -> dict:
    """The ``autobridge`` knob kwargs of one search point."""
    return {"max_util": pt.max_util, "seed": pt.seed,
            "row_weight": pt.row_weight, "col_weight": pt.col_weight,
            "depth_scale": pt.depth_scale}


def _solve_point(graph: TaskGraph, grid: SlotGrid, pt_kwargs: dict,
                 ab_kwargs: dict) -> tuple[FloorplanCache, dict, str | None]:
    """Worker entry point (module-level so it pickles by reference).

    Runs the full autobridge chain for one point against a fresh cache;
    the cache captures every floorplan solve of the feedback loop, so the
    parent replay never pays an ILP.  Counter deltas are before/after
    snapshots: pool workers are reused across tasks, so absolute counter
    values would double-count."""
    before = floorplan_counts()
    cache = FloorplanCache()
    err = None
    try:
        autobridge(graph, grid, cache=cache, **pt_kwargs, **ab_kwargs)
    except InfeasibleError as e:
        err = str(e)
    after = floorplan_counts()
    delta = {k: after[k] - before[k] for k in after}
    return cache, delta, err


def _mp_context():
    """Prefer fork (POSIX); fall back to spawn where fork is unavailable.

    Fork is the only start method that works for unguarded caller scripts
    (``examples/quickstart.py``-style: no ``if __name__ == "__main__"``)
    and interactive sessions — spawn/forkserver re-run ``__main__``
    preparation in every worker.  CPython warns about forking a process
    whose other threads (e.g. jax/XLA pools, once jax is imported) hold
    locks; that hazard applies to children that *use* those runtimes,
    while these workers only run the pure-Python/NumPy solve chain and
    never touch jax — the configuration the whole tier-1 suite exercises."""
    methods = multiprocessing.get_all_start_methods()
    return multiprocessing.get_context(
        "fork" if "fork" in methods else "spawn")


def warm_floorplan_cache(graph: TaskGraph, grid: SlotGrid,
                         points: Sequence[SearchPoint], *,
                         cache: FloorplanCache,
                         jobs: int,
                         ab_kwargs: dict | None = None) -> PoolStats:
    """Solve the given points' floorplans in parallel and merge the results
    into ``cache`` (plus this process's global counters).

    Points whose initial floorplan key is already cached are skipped — a
    prior full run cached their whole solve chain, so re-dispatching would
    only burn a worker.  With ``jobs <= 1`` or nothing to solve this is a
    no-op returning empty stats."""
    ab_kwargs = {k: v for k, v in (ab_kwargs or {}).items() if k != "cache"}
    stats = PoolStats(jobs=max(jobs, 1))
    if jobs <= 1:
        return stats
    todo = [pt for pt in points
            if initial_floorplan_key(graph, grid, **_point_kwargs(pt),
                                     **ab_kwargs) not in cache]
    if not todo:
        return stats
    if ab_kwargs.get("check"):
        # Parent-side pre-flight: structural errors are knob-invariant
        # (``with_knobs`` never moves pins or changes the grid shape), so
        # one analysis stands in for every worker's — when it fails, cache
        # the identical verdict each worker would have produced and skip
        # the dispatch entirely.  Lazy import (circularity, see autobridge).
        from repro.analysis import analyze
        from repro.analysis.report import _ANALYSIS_COUNTS
        rep = analyze(graph, grid=grid, passes=("structure",))
        if not rep.ok:
            msg = f"static analysis: {rep.error_summary()}"
            for pt in todo:
                cache.record_infeasible(
                    initial_floorplan_key(graph, grid, **_point_kwargs(pt),
                                          **ab_kwargs), msg)
            _ANALYSIS_COUNTS["infeasible"] += len(todo)
            stats.static_skipped = len(todo)
            _POOL_COUNTS["static_skipped"] += len(todo)
            return stats
    t0 = time.monotonic()
    with concurrent.futures.ProcessPoolExecutor(
            max_workers=min(jobs, len(todo)),
            mp_context=_mp_context()) as ex:
        futures = [ex.submit(_solve_point, graph, grid, _point_kwargs(pt),
                             ab_kwargs)
                   for pt in todo]
        stats.dispatched = len(futures)
        for fut in futures:
            wcache, delta, err = fut.result()
            cache.merge(wcache)
            merge_floorplan_counts(delta)
            stats.merged += 1
            stats.worker_solves += delta.get("solved", 0)
            if err is not None:
                stats.worker_infeasible += 1
    stats.wall_s = time.monotonic() - t0
    _POOL_COUNTS["dispatched"] += stats.dispatched
    _POOL_COUNTS["merged"] += stats.merged
    _POOL_COUNTS["worker_solves"] += stats.worker_solves
    _POOL_COUNTS["worker_infeasible"] += stats.worker_infeasible
    return stats
