"""Parallel floorplan solving: fan cold ILP solves out over a process pool.

The per-point ``autobridge`` ILP solve is the dominant sequential cost of a
design-space round (the AutoBridge observation the paper builds on), and the
solves of one round are independent of each other.  ``warm_floorplan_cache``
ships each *unique-floorplan* point to a ``concurrent.futures
.ProcessPoolExecutor`` worker; the worker runs the full ``autobridge``
co-optimization against a fresh ``FloorplanCache`` (capturing every solve of
the cycle-feedback chain, infeasibility verdicts included) and returns

    (its cache, its registry delta, its trace spans, the error string)

which the parent merges back — ``FloorplanCache.merge`` for the entries,
the generic ``repro.obs.metrics.merge`` for the per-process counters that
would otherwise silently read 0 in the parent, and ``trace.absorb`` for
the worker's spans (parented under the dispatching round via the trace
token the submit path forwards).  The engine then *replays* the
round in-process against the pre-warmed cache, so every floorplan lookup is
a hit and the produced candidates are **bit-identical** to a sequential run:
``floorplan()`` is deterministic, and the replay path is exactly the
``jobs=1`` code path.

``jobs=1`` never touches the pool (the exact in-process fallback); a worker
hitting ``InfeasibleError`` is a *result*, not a failure — the verdict is
cached and the replay marks the candidate failed, the pool survives.

Fault tolerance
---------------
Worker loss must cost wall time, never results.  Each dispatched point
carries a per-future deadline; a future that misses it is counted
(``timed_out``), its (possibly hung) workers are killed, and the point is
re-dispatched with exponential backoff.  A worker crash surfaces as
``BrokenProcessPool`` on every in-flight future: the executor is rebuilt
(``pool_rebuilds``) and only the *unfinished* points are re-dispatched
(``retried``) — merged results are never recomputed.  Crash attribution is
exact: workers drop a started-marker file per attempt, so only points that
were actually running when the pool broke are charged a crash; a point
charged ``crash_limit`` times (or out of timeout retries) is *poison* — it
is quarantined as a cached infeasibility verdict (``quarantined``) so the
replay sees a verdict instead of re-crashing forever.  Exceptions raised
*by the solve itself* (other than ``InfeasibleError``, handled in-worker)
still propagate: retrying can only mask a real bug.

``REPRO_POOL_CTX`` forces the multiprocessing start method (CI runs one
pool leg under ``spawn``); ``REPRO_POOL_TIMEOUT_S`` / ``REPRO_POOL_RETRIES``
override the per-future deadline and retry budget without code changes.
The ``repro.search.faults`` harness injects deterministic worker crashes
and hangs through this module's worker entry point.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import multiprocessing
import os
import shutil
import tempfile
import time
from concurrent.futures.process import BrokenProcessPool
from typing import Sequence

from repro.core.autobridge import (FloorplanCache, autobridge,
                                   initial_floorplan_key)
from repro.core.devicegrid import SlotGrid
from repro.core.graph import TaskGraph
from repro.core.ilp import InfeasibleError

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from . import faults
from .space import SearchPoint

# Pool activity since the last reset (module-global, mirroring the
# simulator's ``engine_counts`` and autobridge's ``floorplan_counts``):
# benchmarks record these in the BENCH JSON ``sim.pool`` block and the CI
# gate checks a parallel run really dispatched and merged worker results
# (and, in the chaos job, that the fault machinery really fired).
_POOL_COUNTS = _metrics.group(
    "pool",
    {"dispatched": 0, "merged": 0, "worker_solves": 0,
     "worker_infeasible": 0, "static_skipped": 0,
     "retried": 0, "timed_out": 0, "quarantined": 0,
     "pool_rebuilds": 0})

#: default per-future deadline before a point's workers are killed and the
#: point re-dispatched (override: ``REPRO_POOL_TIMEOUT_S`` or the
#: ``timeout_s=`` parameter)
DEFAULT_TIMEOUT_S = 120.0
#: default re-dispatch budget per point for timeouts (``REPRO_POOL_RETRIES``)
DEFAULT_RETRIES = 3
#: worker crashes a single point may be implicated in before quarantine
DEFAULT_CRASH_LIMIT = 3


#: submit→merge latency per dispatched task, labelled by outcome —
#: the pool queue/dispatch timing the BENCH ``sim.pool.task_s`` block
#: and the trace summary surface.
_TASK_HIST = _metrics.histogram("pool.task_s")


def reset_pool_counts() -> None:
    """Zero the global worker-pool dispatch/merge counters."""
    _POOL_COUNTS.reset()
    _TASK_HIST.reset()


def pool_counts() -> dict[str, int]:
    """Snapshot of pool dispatches/merges/worker solves since last reset."""
    return dict(_POOL_COUNTS)


def pool_task_stats() -> dict:
    """Submit→merge latency aggregates per outcome (BENCH
    ``sim.pool.task_s``): count/sum/min/max/mean seconds for dispatched
    tasks that merged cleanly vs. came back infeasible."""
    return {"ok": _TASK_HIST.aggregate(outcome="ok"),
            "infeasible": _TASK_HIST.aggregate(outcome="infeasible")}


@dataclasses.dataclass
class PoolStats:
    """One search's worker-pool activity (``ConvergedSearch.pool``)."""
    #: worker processes requested (1 = sequential, pool never created)
    jobs: int = 1
    #: points shipped to workers (unique floorplans not already cached)
    dispatched: int = 0
    #: worker results merged back into the parent cache/counters
    merged: int = 0
    #: ILP-backed ``floorplan()`` runs performed inside workers
    worker_solves: int = 0
    #: worker runs that ended in a (cached) infeasibility verdict
    worker_infeasible: int = 0
    #: points never dispatched because the parent's static structural
    #: analysis (``autobridge(check=True)`` pre-flight) doomed the graph
    static_skipped: int = 0
    #: re-dispatches beyond a point's first (crash recovery + timeouts)
    retried: int = 0
    #: futures that missed their deadline (hung worker, killed + retried)
    timed_out: int = 0
    #: poison points recorded as cached verdicts instead of retried forever
    quarantined: int = 0
    #: executors rebuilt after a crash (``BrokenProcessPool``) or timeout
    pool_rebuilds: int = 0
    #: cumulative wall time spent inside pool fan-outs
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def absorb(self, other: "PoolStats") -> None:
        """Accumulate another fan-out's stats (per-round -> per-search)."""
        self.jobs = max(self.jobs, other.jobs)
        self.dispatched += other.dispatched
        self.merged += other.merged
        self.worker_solves += other.worker_solves
        self.worker_infeasible += other.worker_infeasible
        self.static_skipped += other.static_skipped
        self.retried += other.retried
        self.timed_out += other.timed_out
        self.quarantined += other.quarantined
        self.pool_rebuilds += other.pool_rebuilds
        self.wall_s += other.wall_s


def _point_kwargs(pt: SearchPoint) -> dict:
    """The ``autobridge`` knob kwargs of one search point."""
    return {"max_util": pt.max_util, "seed": pt.seed,
            "row_weight": pt.row_weight, "col_weight": pt.col_weight,
            "depth_scale": pt.depth_scale, "hbm_split": pt.hbm_split}


def _point_token(pt_kwargs: dict) -> str:
    """Stable per-point identity for fault decisions and crash markers."""
    return repr(tuple(sorted(pt_kwargs.items())))


#: registry entries a worker's delta must NOT carry home: fault
#: injections are counted parent-side at dispatch (the worker's own
#: counter usually dies with it — merging a survivor's would double),
#: and the parent replays the full analysis pass itself, so worker-side
#: analyzer runs are duplicate work the parent already counts.
_WORKER_DELTA_EXCLUDE = ("faults", "analysis")


def _solve_point(graph: TaskGraph, grid: SlotGrid, pt_kwargs: dict,
                 ab_kwargs: dict, token: str = "", attempt: int = 0,
                 marker_dir: str | None = None, trace_token: str = "",
                 trace_on: bool = False,
                 ) -> tuple[FloorplanCache, dict, list, str | None]:
    """Worker entry point (module-level so it pickles by reference).

    Runs the full autobridge chain for one point against a fresh cache;
    the cache captures every floorplan solve of the feedback loop, so the
    parent replay never pays an ILP.  The metrics delta is a before/after
    registry snapshot: pool workers are reused across tasks, so absolute
    counter values would double-count.  The parent folds the delta back
    with the one generic ``metrics.merge`` path and absorbs the worker's
    trace spans, whose roots are parented on ``trace_token`` (the
    dispatching process's innermost open span).

    ``marker_dir`` receives a started-marker file per attempt before any
    work (or injected fault) happens: when a crash breaks the pool, the
    parent charges the crash only to points whose marker exists — points
    still queued are re-dispatched blame-free."""
    if marker_dir:
        with open(os.path.join(marker_dir,
                               f"{_marker_name(token)}.{attempt}"), "w"):
            pass
    _trace.begin_worker(trace_token, enable_tracing=trace_on)
    faults.fire("worker_hang", token, attempt)
    faults.fire("worker_crash", token, attempt)
    before = _metrics.snapshot()
    cache = FloorplanCache()
    err = None
    with _trace.span("pool.worker_solve", attempt=attempt or None):
        try:
            autobridge(graph, grid, cache=cache, **pt_kwargs, **ab_kwargs)
        except InfeasibleError as e:
            err = str(e)
    delta = _metrics.delta(before, exclude=_WORKER_DELTA_EXCLUDE)
    return cache, delta, _trace.drain(), err


def _marker_name(token: str) -> str:
    return hashlib.sha256(token.encode()).hexdigest()[:24]


def _mp_context():
    """Prefer fork (POSIX); fall back to spawn where fork is unavailable.
    ``REPRO_POOL_CTX`` forces a specific start method (the tier-1 CI
    matrix runs one pool leg under ``REPRO_POOL_CTX=spawn`` so the
    fallback path stays tested instead of vestigial).

    Fork is the only start method that works for unguarded caller scripts
    (``examples/quickstart.py``-style: no ``if __name__ == "__main__"``)
    and interactive sessions — spawn/forkserver re-run ``__main__``
    preparation in every worker.  CPython warns about forking a process
    whose other threads (e.g. jax/XLA pools, once jax is imported) hold
    locks; that hazard applies to children that *use* those runtimes,
    while these workers only run the pure-Python/NumPy solve chain and
    never touch jax — the configuration the whole tier-1 suite exercises."""
    methods = multiprocessing.get_all_start_methods()
    override = os.environ.get("REPRO_POOL_CTX")
    if override:
        if override not in methods:
            raise ValueError(
                f"REPRO_POOL_CTX={override!r} is not an available start "
                f"method (have: {', '.join(methods)})")
        return multiprocessing.get_context(override)
    return multiprocessing.get_context(
        "fork" if "fork" in methods else "spawn")


def _hard_shutdown(ex: concurrent.futures.ProcessPoolExecutor) -> None:
    """Tear an executor down even when its workers are hung or dead:
    ``shutdown(wait=True)`` alone would join a worker stuck in user code
    forever, so the worker processes are killed first.

    Killing the workers creates a second hang hazard: the call queue's
    daemon feeder thread may be blocked mid-``write`` into the (now
    reader-less) full pipe, and the executor's NON-daemon manager thread
    joins that feeder during shutdown (``call_queue.join_thread()``) —
    so a blocking ``shutdown(wait=True)`` can deadlock, and even when it
    returns early a stuck manager hangs interpreter exit.
    ``cancel_join_thread()`` makes every later ``join_thread()`` a no-op
    so nothing non-daemon can ever block on the feeder; the bounded
    reader drain then gives the pipe its capacity back so the feeder
    usually flushes its buffer and exits instead of leaking as a
    blocked (harmless, daemon) thread."""
    procs = list(getattr(ex, "_processes", {}).values())
    for proc in procs:
        try:
            proc.kill()
        except Exception:
            pass
    # reap: the executor's shutdown path only skips its sentinel puts
    # once every child reads as dead, and killed-but-unreaped ones don't
    for proc in procs:
        try:
            proc.join(5.0)
        except Exception:
            pass
    call_queue = getattr(ex, "_call_queue", None)
    if call_queue is not None:
        try:
            call_queue.cancel_join_thread()
        except Exception:
            pass
    # non-blocking shutdown first: the manager thread reaches its own
    # close point (which enqueues the feeder's exit sentinel) while the
    # drain below runs
    try:
        ex.shutdown(wait=False, cancel_futures=True)
    except Exception:
        pass
    if call_queue is not None:
        try:
            reader = call_queue._reader
            feeder = call_queue._thread
            deadline = time.monotonic() + 2.0
            while (feeder is not None and feeder.is_alive()
                   and time.monotonic() < deadline):
                # raw os.read, not recv_bytes: a worker killed mid-read
                # can leave a partial message whose garbage framing would
                # make a framed recv block; discarding raw bytes can't
                while reader.poll(0):
                    os.read(reader.fileno(), 1 << 16)
                feeder.join(0.05)
        except Exception:
            pass
    try:
        ex.shutdown(wait=True, cancel_futures=True)
    except Exception:
        pass


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ[name])
    except (KeyError, ValueError):
        return default


@dataclasses.dataclass
class _Task:
    """Parent-side bookkeeping for one dispatched point."""
    pt: SearchPoint
    key: tuple
    token: str
    #: times submitted so far (also the ``attempt`` the worker sees, so
    #: transient injected faults fire on attempt 0 and pass on the retry)
    dispatches: int = 0
    #: pool breaks this point was *running* during (started-marker proof)
    crashes: int = 0
    #: deadlines missed
    timeouts: int = 0
    deadline: float = 0.0
    #: ``time.monotonic()`` at the latest submit (queue+solve latency)
    submitted_at: float = 0.0


def warm_floorplan_cache(graph: TaskGraph, grid: SlotGrid,
                         points: Sequence[SearchPoint], *,
                         cache: FloorplanCache,
                         jobs: int,
                         ab_kwargs: dict | None = None,
                         timeout_s: float | None = None,
                         max_retries: int | None = None,
                         crash_limit: int | None = None,
                         backoff_s: float = 0.05) -> PoolStats:
    """Solve the given points' floorplans in parallel and merge the results
    into ``cache`` (plus this process's global counters).

    Points whose initial floorplan key is already cached are skipped — a
    prior full run cached their whole solve chain, so re-dispatching would
    only burn a worker.  With ``jobs <= 1`` or nothing to solve this is a
    no-op returning empty stats.

    Worker loss is survived, not propagated (module docstring): timeouts
    and ``BrokenProcessPool`` rebuild the executor and re-dispatch only the
    unfinished points, with exponential backoff between rebuilds; a point
    implicated in ``crash_limit`` worker crashes (or out of timeout
    retries) is quarantined as a cached infeasibility verdict."""
    ab_kwargs = {k: v for k, v in (ab_kwargs or {}).items() if k != "cache"}
    stats = PoolStats(jobs=max(jobs, 1))
    if jobs <= 1:
        return stats
    todo = [pt for pt in points
            if initial_floorplan_key(graph, grid, **_point_kwargs(pt),
                                     **ab_kwargs) not in cache]
    if not todo:
        return stats
    if ab_kwargs.get("check"):
        # Parent-side pre-flight: structural errors are knob-invariant
        # (``with_knobs`` never moves pins or changes the grid shape), so
        # one analysis stands in for every worker's — when it fails, cache
        # the identical verdict each worker would have produced and skip
        # the dispatch entirely.  Lazy import (circularity, see autobridge).
        from repro.analysis import analyze
        from repro.analysis.report import _ANALYSIS_COUNTS
        rep = analyze(graph, grid=grid, passes=("structure",))
        if not rep.ok:
            msg = f"static analysis: {rep.error_summary()}"
            for pt in todo:
                cache.record_infeasible(
                    initial_floorplan_key(graph, grid, **_point_kwargs(pt),
                                          **ab_kwargs), msg)
            _ANALYSIS_COUNTS["infeasible"] += len(todo)
            stats.static_skipped = len(todo)
            _POOL_COUNTS["static_skipped"] += len(todo)
            return stats
    if timeout_s is None:
        timeout_s = _env_float("REPRO_POOL_TIMEOUT_S", DEFAULT_TIMEOUT_S)
    if max_retries is None:
        max_retries = int(_env_float("REPRO_POOL_RETRIES", DEFAULT_RETRIES))
    if crash_limit is None:
        crash_limit = DEFAULT_CRASH_LIMIT
    plan = faults.active_plan()

    t0 = time.monotonic()
    _span = _trace.begin("pool.warm", jobs=jobs, points=len(todo))
    tasks = []
    for pt in todo:
        kw = _point_kwargs(pt)
        tasks.append(_Task(pt=pt, token=_point_token(kw),
                           key=initial_floorplan_key(graph, grid, **kw,
                                                     **ab_kwargs)))
    stats.dispatched = len(tasks)
    marker_dir = tempfile.mkdtemp(prefix="repro-pool-")
    ex: concurrent.futures.ProcessPoolExecutor | None = None
    pending: dict[concurrent.futures.Future, _Task] = {}

    def submit(task: _Task) -> None:
        nonlocal ex
        if ex is None:
            ex = concurrent.futures.ProcessPoolExecutor(
                max_workers=min(jobs, len(tasks)), mp_context=_mp_context())
        if plan is not None:
            # the worker's own injection counter dies with the worker;
            # take the same seeded decision here so injected-vs-observed
            # counts survive into the BENCH JSON
            for site in ("worker_crash", "worker_hang"):
                if plan.decide(site, task.token, task.dispatches):
                    faults.count_injected(site)
        fut = ex.submit(_solve_point, graph, grid, _point_kwargs(task.pt),
                        ab_kwargs, task.token, task.dispatches, marker_dir,
                        _trace.current_token(), _trace.enabled())
        if task.dispatches > 0:
            stats.retried += 1
        task.dispatches += 1
        task.submitted_at = time.monotonic()
        task.deadline = task.submitted_at + timeout_s
        pending[fut] = task

    def was_running(task: _Task) -> bool:
        marker = f"{_marker_name(task.token)}.{task.dispatches - 1}"
        return os.path.exists(os.path.join(marker_dir, marker))

    def quarantine(task: _Task, why: str) -> None:
        cache.record_infeasible(task.key, f"quarantined: {why}")
        stats.quarantined += 1

    def rebuild_pool() -> None:
        nonlocal ex
        if ex is not None:
            _hard_shutdown(ex)
            ex = None
        stats.pool_rebuilds += 1
        if backoff_s > 0:
            time.sleep(min(backoff_s * (2 ** (stats.pool_rebuilds - 1)),
                           30.0))

    try:
        for task in tasks:
            submit(task)
        while pending:
            now = time.monotonic()
            wait_s = max(0.05, min(t.deadline for t in pending.values())
                         - now)
            done, _ = concurrent.futures.wait(
                set(pending), timeout=wait_s,
                return_when=concurrent.futures.FIRST_COMPLETED)
            requeue: list[_Task] = []
            broken = False
            for fut in done:
                task = pending.pop(fut)
                try:
                    wcache, delta, wspans, err = fut.result()
                except (BrokenProcessPool,
                        concurrent.futures.BrokenExecutor,
                        concurrent.futures.CancelledError):
                    broken = True
                    requeue.append(task)
                    continue
                cache.merge(wcache)
                _metrics.merge(delta)
                _trace.absorb(wspans)
                _TASK_HIST.observe(time.monotonic() - task.submitted_at,
                                   outcome="infeasible" if err else "ok")
                stats.merged += 1
                stats.worker_solves += (delta.get("floorplan", {})
                                        .get("values", {}).get("solved", 0))
                if err is not None:
                    stats.worker_infeasible += 1
            if broken:
                # the executor is unusable: drain every in-flight future
                # and charge the break only to tasks that were provably
                # running (started marker for their current attempt)
                requeue.extend(pending.values())
                pending.clear()
                survivors = []
                for task in requeue:
                    if was_running(task):
                        task.crashes += 1
                    if task.crashes >= crash_limit:
                        quarantine(task, f"worker crashed "
                                         f"{task.crashes}x on this point")
                    else:
                        survivors.append(task)
                requeue = survivors
                rebuild_pool()
            else:
                now = time.monotonic()
                overdue = [(f, t) for f, t in pending.items()
                           if now >= t.deadline]
                if overdue:
                    stats.timed_out += len(overdue)
                    survivors = []
                    for fut, task in overdue:
                        pending.pop(fut)
                        task.timeouts += 1
                        if task.timeouts > max_retries:
                            quarantine(task, f"timed out {task.timeouts}x "
                                             f"({timeout_s:g}s each)")
                        else:
                            survivors.append(task)
                    # the hung workers must die, which takes every other
                    # in-flight future with them — re-dispatch those too,
                    # blame-free
                    survivors.extend(pending.values())
                    pending.clear()
                    requeue = survivors + requeue
                    rebuild_pool()
            for task in requeue:
                submit(task)
    finally:
        if ex is not None:
            _hard_shutdown(ex)
        shutil.rmtree(marker_dir, ignore_errors=True)
        if _span is not None:
            _span["args"].update(
                merged=stats.merged, retried=stats.retried,
                timed_out=stats.timed_out, quarantined=stats.quarantined,
                pool_rebuilds=stats.pool_rebuilds)
        _trace.end(_span)
    stats.wall_s = time.monotonic() - t0
    for field in ("dispatched", "merged", "worker_solves",
                  "worker_infeasible", "retried", "timed_out",
                  "quarantined", "pool_rebuilds"):
        _POOL_COUNTS[field] += getattr(stats, field)
    return stats
