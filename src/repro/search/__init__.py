"""repro.search — the design-space search subsystem (public entry point).

Everything the co-optimization search offers lives here:

* ``space``     — ``SearchSpace``/``SearchPoint``/``Interval``: the joint
                  knob axes, grid/random sampling, frontier refinement;
* ``pareto``    — non-domination and the exact hypervolume indicator;
* ``engine``    — ``explore_design_space`` (one-shot batched search),
                  ``search_until_converged`` (refine -> search loop),
                  ``sweep_backends`` (one-call multi-device sweeps) and the
                  deferred-scoring plumbing (``DeferredSearch``);
* ``pool``      — the process-pool execution layer: parallel cold ILP
                  solves with mergeable caches/counters (``jobs=``), with
                  per-future timeouts, crash recovery and poison-point
                  quarantine built in;
* ``store``     — crash-consistent persistence: the content-addressed
                  ``DiskFloorplanStore`` and the per-round checkpoint
                  journal behind ``search_until_converged(checkpoint=)``;
* ``faults``    — the seeded deterministic fault-injection harness the
                  robustness tests and the CI chaos job drive;
* ``surrogate`` — response-surface-guided round proposals (``proposer=``).

``repro.core.explorer`` re-exports this module's names for backward
compatibility; new code should import from ``repro.search``.
"""
from .engine import (BackendSweep, Candidate, ConvergedSearch,
                     DeferredSearch, SearchResult, best_candidate,
                     explore_design_space, explore_floorplans,
                     gather_sim_jobs, measure_backend_speedup,
                     pareto_frontier, pool_simulations,
                     prepare_design_space, scatter_sim_results,
                     search_until_converged, sweep_backends,
                     timed_pool_simulations)
from .faults import (FaultPlan, fault_counts, install as install_faults,
                     reset_fault_counts)
from .pareto import hypervolume, objective_vector, pareto_indices
from .pool import (PoolStats, pool_counts, reset_pool_counts,
                   warm_floorplan_cache)
from .space import DEFAULT_UTILS, Interval, SearchPoint, SearchSpace
from .store import (DiskFloorplanStore, SearchJournal, key_digest,
                    reset_store_counts, store_counts)
from .surrogate import (ResponseSurface, SurrogateProposer, UniformProposer,
                        make_proposer)

__all__ = [
    "BackendSweep", "Candidate", "ConvergedSearch", "DeferredSearch",
    "SearchResult", "best_candidate", "explore_design_space",
    "explore_floorplans", "gather_sim_jobs", "measure_backend_speedup",
    "pareto_frontier", "pool_simulations", "prepare_design_space",
    "scatter_sim_results", "search_until_converged", "sweep_backends",
    "timed_pool_simulations",
    "hypervolume", "objective_vector", "pareto_indices",
    "PoolStats", "pool_counts", "reset_pool_counts", "warm_floorplan_cache",
    "DEFAULT_UTILS", "Interval", "SearchPoint", "SearchSpace",
    "FaultPlan", "fault_counts", "install_faults", "reset_fault_counts",
    "DiskFloorplanStore", "SearchJournal", "key_digest",
    "reset_store_counts", "store_counts",
    "ResponseSurface", "SurrogateProposer", "UniformProposer",
    "make_proposer",
]
