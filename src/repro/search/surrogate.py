"""Surrogate-guided round proposals (ROADMAP "smarter round proposals").

``search_until_converged`` historically refined *uniformly* around the
incumbent frontier: every round drew ``points_per_round`` points from the
zoomed space with no regard for what the already-scored points say about
the response surface.  The surrogate proposer closes that gap with the
cheapest model that can rank candidates:

* a **quadratic ridge response surface** over the continuous knob axes
  (max_util, row/col weight, depth_scale — full degree-2 polynomial
  features), fit to the already-evaluated points' objective vectors
  (fmax, -buffer area, -simulated cycles) with ``numpy.linalg.lstsq`` on a
  Tikhonov-augmented system;
* a companion **feasibility surface** fit on ALL evaluated points (target
  1.0 for feasible, 0.0 for infeasible) that discounts candidates the
  model expects to be unroutable;
* **predicted-hypervolume-improvement ranking**: an oversampled uniform
  pool is drawn from the refined space, each candidate's predicted
  objective vector is scored by how much hypervolume it would add to the
  incumbent frontier (times its clipped feasibility probability), and the
  top ``n`` are proposed.

When the fit is underdetermined (fewer feasible samples than active
polynomial features) the proposer degrades to the pool's first ``n`` draws,
which are *exactly* the uniform proposer's draws for the same seed — the
fallback is bit-identical to uniform, never worse.

Everything is deterministic: seeded sampling, ``lstsq`` and stable sorting
introduce no run-to-run variance.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from ..obs import trace as _trace
from .pareto import hypervolume, objective_vector
from .space import SearchPoint, SearchSpace

#: the numeric knob axes the response surface is fit over (seed is
#: categorical and deliberately excluded — the model averages over it)
FEATURE_AXES = ("max_util", "row_weight", "col_weight", "depth_scale")

#: Tikhonov weight of the augmented least-squares rows — small enough to
#: never fight the data, large enough to keep near-collinear quadratic
#: features from exploding the extrapolation
RIDGE = 1e-6


def _raw_features(points: Sequence[SearchPoint]) -> np.ndarray:
    """Degree-2 polynomial feature matrix: bias, linear, squares, pairs."""
    x = np.array([[getattr(p, ax) for ax in FEATURE_AXES] for p in points],
                 dtype=float)
    cols = [np.ones(len(points))]
    d = x.shape[1]
    for i in range(d):
        cols.append(x[:, i])
    for i in range(d):
        cols.append(x[:, i] * x[:, i])
    for i in range(d):
        for j in range(i + 1, d):
            cols.append(x[:, i] * x[:, j])
    return np.stack(cols, axis=1)


@dataclasses.dataclass
class ResponseSurface:
    """Quadratic ridge fit, one output column per target dimension.

    ``fit`` returns False (and ``predict`` raises) when the system is
    underdetermined — fewer samples than *active* features, where a
    feature is active if it varies across the training points (axes pinned
    to a single value contribute nothing and are dropped, so a pure
    max-util search only needs a handful of samples to become fittable).
    """
    ridge: float = RIDGE
    _theta: np.ndarray | None = None
    _active: np.ndarray | None = None

    def fit(self, points: Sequence[SearchPoint],
            targets: np.ndarray) -> bool:
        X = _raw_features(points)
        # bias stays; any other column constant across samples is inactive
        spread = X.max(axis=0) - X.min(axis=0)
        active = spread > 1e-12
        active[0] = True
        Xa = X[:, active]
        if Xa.shape[0] < int(active.sum()):
            self._theta = None
            return False
        y = np.asarray(targets, dtype=float)
        if y.ndim == 1:
            y = y[:, None]
        # Tikhonov augmentation: [X; sqrt(l)*I] theta = [y; 0]
        lam = np.sqrt(self.ridge) * np.eye(Xa.shape[1])
        A = np.vstack([Xa, lam])
        b = np.vstack([y, np.zeros((Xa.shape[1], y.shape[1]))])
        self._theta, *_ = np.linalg.lstsq(A, b, rcond=None)
        self._active = active
        return True

    def predict(self, points: Sequence[SearchPoint]) -> np.ndarray:
        if self._theta is None:
            raise RuntimeError("ResponseSurface.predict before a good fit")
        return _raw_features(points)[:, self._active] @ self._theta


class UniformProposer:
    """Today's behavior, as a named strategy: uniform seeded draws from the
    (already refined) working space.  The bit-identity anchor every other
    proposer's fallback must match."""
    name = "uniform"

    def propose(self, space: SearchSpace, frontier: Sequence,
                evaluated: Sequence, n: int, *, seed: int = 0,
                ref: tuple | None = None) -> list[SearchPoint]:
        return space.sample(n, seed=seed)


class SurrogateProposer:
    """Response-surface-guided proposals (module docstring has the story).

    ``oversample`` controls the candidate pool: ``oversample * n`` uniform
    draws are ranked and the top ``n`` proposed.  A slice of the proposals
    (``explore_fraction``) is always taken verbatim from the uniform draws
    so the model can never fully starve exploration — model-guided search
    with zero exploration famously locks onto early artifacts."""
    name = "surrogate"

    def __init__(self, *, oversample: int = 8,
                 explore_fraction: float = 0.25, ridge: float = RIDGE):
        self.oversample = max(int(oversample), 2)
        self.explore_fraction = min(max(explore_fraction, 0.0), 1.0)
        self.ridge = ridge

    def propose(self, space: SearchSpace, frontier: Sequence,
                evaluated: Sequence, n: int, *, seed: int = 0,
                ref: tuple | None = None) -> list[SearchPoint]:
        with _trace.span("search.propose", proposer=self.name, n=n):
            return self._propose(space, frontier, evaluated, n,
                                 seed=seed, ref=ref)

    def _propose(self, space: SearchSpace, frontier: Sequence,
                 evaluated: Sequence, n: int, *, seed: int = 0,
                 ref: tuple | None = None) -> list[SearchPoint]:
        # the uniform proposal is drawn EXACTLY as UniformProposer draws it
        # (not pool[:n]: a discrete space's oversampled pool degenerates to
        # grid order, which is not what sample(n) returns), so the
        # underdetermined fallback is bit-identical to proposer="uniform"
        uniform = space.sample(n, seed=seed)
        pool = space.sample(self.oversample * n, seed=seed)
        feas = [c for c in evaluated
                if c.point is not None and c.plan is not None
                and c.report is not None and c.report.routed]
        scored_all = [c for c in evaluated if c.point is not None]
        obj = ResponseSurface(ridge=self.ridge)
        if not feas or not obj.fit([c.point for c in feas],
                                   np.array([objective_vector(c)
                                             for c in feas])):
            return uniform           # underdetermined -> uniform fallback
        feasibility = ResponseSurface(ridge=self.ridge)
        have_feas_model = len(scored_all) > len(feas) and feasibility.fit(
            [c.point for c in scored_all],
            np.array([1.0 if c.plan is not None else 0.0
                      for c in scored_all]))

        front_vecs = [objective_vector(c) for c in frontier
                      if c.plan is not None and c.report is not None]
        if ref is None:
            vecs = [objective_vector(c) for c in feas]
            ref = tuple(min(v[i] for v in vecs) - 1.0 for i in range(3))
        base_hv = hypervolume(front_vecs, ref)

        pred = obj.predict(pool)
        p_feas = np.ones(len(pool))
        if have_feas_model:
            p_feas = np.clip(feasibility.predict(pool)[:, 0], 0.0, 1.0)
        scores = np.array([
            max(hypervolume(front_vecs + [tuple(v)], ref) - base_hv, 0.0)
            for v in pred]) * p_feas

        seen = {c.point for c in scored_all}
        # stable ranking: score desc, then pool order — fully deterministic
        order = sorted(range(len(pool)),
                       key=lambda i: (-scores[i], i))
        n_explore = int(round(self.explore_fraction * n))
        picks: list[SearchPoint] = []
        chosen: set[SearchPoint] = set()
        for p in uniform[:n_explore]:          # exploration slice first
            if p not in chosen:
                chosen.add(p)
                picks.append(p)
        for i in order:                        # then the model's ranking
            if len(picks) >= n:
                break
            p = pool[i]
            if p in chosen or p in seen:
                continue
            chosen.add(p)
            picks.append(p)
        for p in pool:                         # pad if dedup starved us
            if len(picks) >= n:
                break
            if p not in chosen:
                chosen.add(p)
                picks.append(p)
        return picks


def make_proposer(spec) -> UniformProposer | SurrogateProposer:
    """Resolve the ``proposer=`` knob: a name ("uniform" | "surrogate") or
    any object with a ``propose`` method (passed through)."""
    if hasattr(spec, "propose"):
        return spec
    if spec == "uniform":
        return UniformProposer()
    if spec == "surrogate":
        return SurrogateProposer()
    raise ValueError(f"unknown proposer {spec!r} "
                     f"(expected 'uniform', 'surrogate' or an object "
                     f"with a .propose method)")
