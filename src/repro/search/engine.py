"""Batched design-space search over co-optimization knobs (paper §6.3++).

The paper's multi-floorplan methodology "implements all candidates in
parallel and keeps the best", sweeping the per-slot max-utilization knob.
``repro.search`` generalizes that single axis into the *joint* space of
``repro.search.space`` (seed x max_util x boundary weights x depth scale)
and runs the floorplan -> pipeline -> balance co-optimization per point,
scores every feasible candidate with the physical model, checks all
candidates' throughput in a handful of ``simulate_batch`` calls (the
candidates share the design's topology, so hundreds of variants vectorize
into one NumPy sweep), and prunes the result to the Pareto frontier over
(fmax, area overhead, simulated cycles).

Two structural facts keep the search cheap:

  * the floorplan ILP is invariant to ``depth_scale`` (register depth never
    appears in the partitioning objective), so depth variants of one
    (seed, util, weights) cell reuse the expensive floorplan and only re-run
    pipelining + balancing;
  * throughput evaluation is batched: one ``simulate_batch`` call scores the
    shared unpipelined baseline plus every feasible candidate.

Two execution engines scale the remaining cost:

  * ``jobs=N`` fans each round's cold ILP floorplan solves out over a
    ``ProcessPoolExecutor`` (``repro.search.pool``): workers solve into
    private ``FloorplanCache``s that are merged back (entries + counter
    deltas) and the round replays in-process against the warm cache —
    bit-identical candidates to ``jobs=1``, minus the sequential ILP wall
    time.  ``jobs=1`` is the exact in-process path, no pool involved.
  * ``proposer="surrogate"`` replaces the uniform round proposals of the
    converging search with response-surface-guided ones
    (``repro.search.surrogate``): a quadratic ridge model fit to the
    already-scored points ranks an oversampled candidate pool by predicted
    hypervolume improvement, falling back to the uniform draws whenever
    the fit is underdetermined.

With ``fifo_sizing=True`` frontier candidates are additionally profiled by
the event engine (per-stream occupancy histograms from the push/pop logs)
and their FIFO headroom re-sized to the *observed* peak occupancy instead
of the uniform ``2*latency`` round-trip term — trimming to the observed
peak provably preserves the simulated schedule, so the verification batch
must reproduce the same cycle count.  The reclaimed bits are then credited
back into the fmax surrogate: ``sized_report`` scores the design with its
real (smaller) buffering footprint charged into slot utilization.

Deferred scoring and multi-device sweeps: ``prepare_design_space`` returns
a ``DeferredSearch`` whose simulation jobs a caller can pool across many
searches; ``sweep_backends`` uses this to compare one design across several
device grids (U250/U280/TPU-pod shapes) with ALL grids' candidates scored
in a single ``simulate_batch`` call — the padded ragged-batch backend
vectorizes across the grids' heterogeneous candidate sets.

``explore_floorplans`` remains as a thin single-axis compatibility wrapper,
and ``SearchSpace.refine`` zooms random sampling into the numeric
neighborhood of a Pareto frontier for adaptive refinement.

Converging search: numeric axes may be continuous ``Interval(lo, hi)``
ranges instead of discrete value lists, and ``search_until_converged``
closes the refine -> search loop automatically — every round re-anchors on
the incumbent frontier, refines the space around it, and stops when the
frontier's hypervolume improvement falls below ``tol``.  One unpipelined
baseline simulation and one ``FloorplanCache`` (memoized ILP floorplans,
``autobridge.floorplan_counts()``) are shared across all rounds, so
revisited configurations cost a dict lookup instead of an ILP solve.

See ``docs/search-guide.md`` for the end-to-end guide.
"""
from __future__ import annotations

import copy
import dataclasses
import os
import time
from typing import Callable, Mapping, Sequence

from repro.core.autobridge import (FloorplanCache, Plan, _graph_signature,
                                   _grid_signature, autobridge)
from repro.core.balance import CycleError, balance_graph
from repro.core.devicegrid import SlotGrid
from repro.core.fmax_model import PhysicalModel, TimingReport, analyze_timing
from repro.core.graph import TaskGraph
from repro.core.ilp import InfeasibleError
from repro.core.pipelining import assign_pipelining
from repro.core.simulate import (SimJob, SimResult, StreamProfile,
                                 engine_counts, reset_engine_counts,
                                 simulate, simulate_batch)

from . import faults
from ..obs import trace as _trace
from .pareto import hypervolume, objective_vector, pareto_indices
from .pool import PoolStats, warm_floorplan_cache
from .space import (DEFAULT_UTILS, Interval, SearchPoint,  # noqa: F401
                    SearchSpace)
from .store import DiskFloorplanStore, SearchJournal, key_digest
from .surrogate import make_proposer


@dataclasses.dataclass
class Candidate:
    max_util: float
    plan: Plan | None
    report: TimingReport | None
    error: str | None = None
    #: dataflow-simulated cycles of the pipelined+balanced design (filled by
    #: the batched throughput evaluation; None when not requested/feasible)
    sim: SimResult | None = None
    #: cycles of the unpipelined baseline design (shared across candidates)
    base_sim: SimResult | None = None
    #: the joint knob configuration that produced this candidate
    point: SearchPoint | None = None
    #: event-engine occupancy profiles (``fifo_sizing``, frontier only)
    profile: dict[str, StreamProfile] | None = None
    #: per-stream FIFO headroom re-sized to observed peak occupancy
    #: (reverted to None if the verification batch saw different cycles)
    sized_capacity: dict[str, int] | None = None
    #: verified run of the re-sized design — cycle-identical to the
    #: uniform-headroom reference at the same firing count, or None if the
    #: sizing was reverted
    sized_sim: SimResult | None = None
    #: timing of the sized design with its (smaller) buffering footprint
    #: charged into slot utilization (``analyze_timing(buffer_bits=...)``) —
    #: reclaimed BRAM/LUT credited back, so never below ``uniform_report``
    sized_report: TimingReport | None = None
    #: the uniform-headroom twin scored under the same buffering charge
    #: (the comparison anchor for the FIFO-sizing credit)
    uniform_report: TimingReport | None = None

    @property
    def fmax(self) -> float:
        return self.report.fmax_mhz if self.report else 0.0

    @property
    def throughput_preserved(self) -> bool | None:
        """True iff the simulated candidate kept the baseline's steady-state
        throughput (only fill/drain skew added).  None when not simulated."""
        if self.sim is None or self.base_sim is None or self.plan is None:
            return None
        if self.sim.deadlocked:
            return False
        skew = sum(self.plan.depth.values()) + self.plan.graph.num_tasks
        return self.sim.cycles <= self.base_sim.cycles + skew

    @property
    def fifo_savings_bits(self) -> float | None:
        """Width-weighted capacity saved by profile-driven sizing vs the
        uniform ``2*latency`` headroom (None until sized)."""
        if self.sized_capacity is None or self.plan is None:
            return None
        width = {s.name: s.width for s in self.plan.graph.streams}
        uniform = self.plan.sim_extra_capacity
        return sum((uniform.get(n, 0) - e) * width.get(n, 0.0)
                   for n, e in self.sized_capacity.items())


# ---------------------------------------------------------------------------
# Pareto pruning (candidate level; vector primitives live in .pareto)
# ---------------------------------------------------------------------------

#: the maximized objective vector shared by ``pareto_frontier``, the
#: hypervolume trajectory and the surrogate's training targets — one
#: definition, in ``repro.search.pareto`` (historical private name kept:
#: tests and downstream code import ``_objective`` from here/explorer)
_objective = objective_vector


def pareto_frontier(cands: Sequence[Candidate]) -> list[Candidate]:
    """Feasible, routed, non-deadlocked candidates that are Pareto-optimal
    over (fmax up, area_overhead down, simulated cycles down)."""
    ok = [c for c in cands
          if c.plan is not None and c.report and c.report.routed
          and (c.sim is None or not c.sim.deadlocked)]
    return [ok[i] for i in pareto_indices([_objective(c) for c in ok])]


# ---------------------------------------------------------------------------
# joint search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    #: every evaluated configuration, in enumeration order (failures kept —
    #: the paper's Table 10 reports those as 'Failed')
    candidates: list[Candidate]
    #: Pareto-optimal subset over (fmax, area_overhead, sim cycles)
    frontier: list[Candidate]
    #: number of ``simulate_batch`` calls the search issued
    sim_calls: int
    #: number of configurations evaluated
    space_size: int

    @property
    def best(self) -> Candidate:
        """Highest-fmax routable candidate (frontier first)."""
        return best_candidate(self.frontier or self.candidates)


def _derive_depth_variant(graph: TaskGraph, grid: SlotGrid, base: Plan,
                          pt: SearchPoint,
                          **ab_kwargs) -> Plan | InfeasibleError:
    """Re-pipeline + re-balance ``base``'s floorplan under ``pt``'s depth
    scale.  The floorplan is depth-invariant, so this skips the ILP; a
    (theoretically unreachable) balance cycle falls back to a full
    autobridge run with the point's knobs."""
    sgrid = grid.with_hbm_binding(pt.hbm_split).with_knobs(
        row_weight=pt.row_weight, col_weight=pt.col_weight,
        depth_scale=pt.depth_scale)
    fp = dataclasses.replace(base.floorplan, grid=sgrid)
    pa = assign_pipelining(graph, fp)
    try:
        bal = balance_graph(graph, pa.lat)
    except CycleError:
        try:
            return autobridge(graph, grid, max_util=pt.max_util, seed=pt.seed,
                              row_weight=pt.row_weight,
                              col_weight=pt.col_weight,
                              depth_scale=pt.depth_scale,
                              hbm_split=pt.hbm_split, **ab_kwargs)
        except InfeasibleError as err:
            return err
    depth = {name: pa.lat[name] + bal.balance[name] for name in pa.lat}
    width = {s.name: s.width for s in graph.streams}
    overhead = sum(d * width[n] for n, d in depth.items())
    return Plan(graph=graph, floorplan=fp, pipelining=pa, balancing=bal,
                depth=depth, area_overhead=overhead,
                feedback_rounds=base.feedback_rounds,
                co_located=base.co_located,
                demoted_streams=list(base.demoted_streams))


@dataclasses.dataclass
class DeferredSearch:
    """Candidate enumeration with throughput scoring deferred.

    ``prepare_design_space`` runs the floorplan -> pipeline -> balance
    co-optimization and the physical model for every point but leaves the
    simulator out, so a caller can pool the simulation jobs of *many*
    searches — different designs, different device grids — into one
    ``simulate_batch`` call (mixed topologies vectorize through the padded
    backend).  ``sim_jobs`` exposes this search's slice of jobs,
    ``attach_sim`` distributes that call's results back onto the
    candidates, and ``finish`` computes the Pareto frontier.

    ``base_sim`` carries an already-simulated unpipelined baseline: when
    set (``search_until_converged`` reuses round 1's baseline this way),
    ``sim_jobs`` omits the baseline job and ``attach_sim`` stamps the
    stored result onto every candidate instead."""
    graph: TaskGraph
    grid: SlotGrid
    model: PhysicalModel
    candidates: list[Candidate]
    space_size: int
    base_sim: SimResult | None = None
    #: worker-pool activity of the preparation phase (None when ``jobs=1``)
    pool: PoolStats | None = None
    #: run the static pre-flight gate before simulating candidates
    #: (``prepare_design_space(static_check=...)``)
    static_check: bool = True
    #: ``simulate_batch`` backend this search's jobs should be scored with
    #: ("auto" / "jax" / "numpy" / "event"; honored by ``pool_simulations``
    #: and the drivers that consume ``sim_jobs()``)
    sim_backend: str = "auto"

    @property
    def feasible(self) -> list[Candidate]:
        return [c for c in self.candidates if c.plan is not None]

    def _pending(self) -> list[Candidate]:
        """Feasible candidates still awaiting a simulation result (the
        static gate stamps doomed candidates' ``sim`` up front, so they
        drop out of the job list here)."""
        return [c for c in self.candidates
                if c.plan is not None and c.sim is None]

    def apply_static_gate(self, firings: int) -> int:
        """Statically verify every pending candidate's *as-simulated* graph
        variant (``repro.analysis`` deadlock pass over the plan's graph —
        including any cycle-breaking stream demotions — at the plan's FIFO
        headroom) and skip the simulation of provably-doomed ones.

        A skipped candidate gets a synthetic ``SimResult`` with
        ``deadlocked=True`` and ``engine="static"`` — by the soundness of
        the analyzer (a doomed verdict implies the event engine deadlocks)
        this is exactly the verdict the skipped simulation would have
        produced, so the Pareto frontier is bit-identical to the ungated
        path while the doomed candidates' simulations never run.  Returns
        the number of candidates skipped (also accumulated into
        ``analysis_counts()['skipped']``)."""
        if not self.static_check or not firings:
            return 0
        from repro.analysis import analyze
        from repro.analysis.report import _ANALYSIS_COUNTS
        skipped = 0
        for c in self._pending():
            job = c.plan.sim_job()
            rep = analyze(job.graph, extra_capacity=job.extra_capacity,
                          firings=firings, passes=("deadlock",))
            if rep.deadlock:
                c.sim = SimResult(
                    cycles=0, fired={n: 0 for n in job.graph.tasks},
                    deadlocked=True, steps=0, engine="static")
                c.error = ("static deadlock: "
                           + "; ".join(d.message for d in rep.errors))
                skipped += 1
        _ANALYSIS_COUNTS["skipped"] += skipped
        return skipped

    def sim_jobs(self) -> list[SimJob]:
        """The shared unpipelined baseline (omitted when ``base_sim`` is
        already known) followed by one job per pending feasible candidate
        (empty when there is nothing left to simulate)."""
        feas = self._pending()
        if not feas:
            return []
        jobs = [c.plan.sim_job() for c in feas]
        if self.base_sim is None:
            jobs.insert(0, SimJob(self.graph))
        return jobs

    def attach_sim(self, results: Sequence[SimResult]) -> None:
        """Distribute ``simulate_batch`` results produced from
        ``sim_jobs()`` (same order: baseline first unless ``base_sim``
        was supplied up front)."""
        feas = self._pending()
        if not feas:
            return
        if self.base_sim is None:
            self.base_sim = results[0]
            results = results[1:]
        for c, res in zip(feas, results):
            c.sim = res
            c.base_sim = self.base_sim

    def finish(self, *, sim_calls: int = 0) -> SearchResult:
        return SearchResult(candidates=self.candidates,
                            frontier=pareto_frontier(self.candidates),
                            sim_calls=sim_calls,
                            space_size=self.space_size)


def gather_sim_jobs(preps: Sequence[DeferredSearch], *,
                    firings: int) -> tuple[list[SimJob],
                                           list[tuple[int, int]]]:
    """Apply the static pre-flight gate and collect every search's pending
    simulation jobs into one flat list.  Returns ``(jobs, spans)`` where
    ``spans[i]`` is search i's slice of ``jobs`` — feed the batched results
    back with ``scatter_sim_results``.  Split out of ``pool_simulations``
    so drivers can hold on to the job list (e.g. to re-time it under
    another backend with ``measure_backend_speedup``)."""
    jobs: list[SimJob] = []
    spans: list[tuple[int, int]] = []
    for prep in preps:
        prep.apply_static_gate(firings)
    for prep in preps:
        pj = prep.sim_jobs()
        spans.append((len(jobs), len(jobs) + len(pj)))
        jobs.extend(pj)
    return jobs, spans


def scatter_sim_results(preps: Sequence[DeferredSearch],
                        spans: Sequence[tuple[int, int]],
                        results: Sequence[SimResult]) -> None:
    """Distribute one batched call's results back onto the searches whose
    jobs ``gather_sim_jobs`` collected (inverse of the concatenation)."""
    for prep, (lo, hi) in zip(preps, spans):
        prep.attach_sim(results[lo:hi])


def _resolve_backend(preps: Sequence[DeferredSearch],
                     backend: str | None) -> str:
    """An explicit ``backend`` wins; otherwise the searches' unanimous
    ``sim_backend``, or "auto" when they disagree."""
    if backend is not None:
        return backend
    kinds = {p.sim_backend for p in preps}
    return kinds.pop() if len(kinds) == 1 else "auto"


def pool_simulations(preps: Sequence[DeferredSearch], *, firings: int,
                     backend: str | None = None) -> list[SimResult]:
    """Score many deferred searches' jobs in ONE ``simulate_batch`` call.

    Concatenates every search's ``sim_jobs()``, runs the single batched
    call (mixed topologies vectorize through the padded backend), and
    distributes each search's slice back via ``attach_sim``.  ``backend``
    forces a ``simulate_batch`` backend; by default the searches' own
    ``sim_backend`` is used (falling back to "auto" when they disagree).
    Returns the flat result list ([] when there was nothing to score) so
    callers can record metadata such as the engines used."""
    jobs, spans = gather_sim_jobs(preps, firings=firings)
    if not jobs:
        return []
    results = simulate_batch(jobs, firings=firings,
                             backend=_resolve_backend(preps, backend))
    scatter_sim_results(preps, spans, results)
    return results


def measure_backend_speedup(jobs: Sequence[SimJob], *,
                            firings: int) -> dict:
    """Measured NumPy-vs-jax wall time on one job list (the BENCH JSON
    ``sim.speedup`` block): times the padded NumPy sweep, then the jitted
    sweep with its compilation warmed up outside the timed window (the
    compile cost is reported separately as ``jax_compile_s``).  When jax
    is unavailable the jax fields are None and ``speedup`` is None — the
    figure is *measured*, never asserted.  Engine counters are restored
    afterwards so gates on the main call's counts stay unpolluted."""
    from repro.core.simulate import _ENGINE_INVOCATIONS, _jax_ready
    jobs = list(jobs)
    saved = dict(_ENGINE_INVOCATIONS)
    try:
        t0 = time.monotonic()
        simulate_batch(jobs, firings=firings, backend="numpy")
        numpy_wall = time.monotonic() - t0
        out = {"jobs": len(jobs), "firings": firings,
               "numpy_wall_s": numpy_wall, "jax_compile_s": None,
               "jax_wall_s": None, "speedup": None}
        if _jax_ready():
            t0 = time.monotonic()
            simulate_batch(jobs, firings=firings, backend="jax")  # warm-up
            out["jax_compile_s"] = time.monotonic() - t0
            t0 = time.monotonic()
            simulate_batch(jobs, firings=firings, backend="jax")
            out["jax_wall_s"] = time.monotonic() - t0
            out["speedup"] = numpy_wall / max(out["jax_wall_s"], 1e-9)
        return out
    finally:
        _ENGINE_INVOCATIONS.clear()
        _ENGINE_INVOCATIONS.update(saved)


def timed_pool_simulations(preps: Sequence[DeferredSearch], *, firings: int,
                           backend: str | None = None,
                           measure_speedup: bool = False,
                           ) -> tuple[list[SimResult], dict]:
    """``pool_simulations`` plus the benchmark drivers' metadata recording:
    resets the global engine counters, times the batched call, and returns
    ``(results, meta)`` where ``meta`` is the JSON-ready dict every
    ``BENCH_*.json`` writer stores under its top-level ``"sim"`` key —
    ``{firings, jobs, invocations, counts, backends, backend, wall_s,
    analysis}`` — and the CI regression gate inspects to prove the suite
    stayed vectorized (and, via ``analysis``, that the static pre-flight
    gate actually ran).  ``analysis`` is a *snapshot* of
    ``analysis_counts()``, not a delta: drivers reset the counters up
    front so the snapshot also covers the preparation phase's
    ``autobridge(check=True)`` verdicts.

    The jitted sweep's compile-cache counters always ride along as
    ``meta["jit_cache"]`` (zeroed when the jax backend never ran, so
    gates can't pass vacuously), and
    ``measure_speedup=True`` re-times the same job list under both array
    backends into ``meta["speedup"]`` (``measure_backend_speedup``) —
    after the counts snapshot, so the gates' counters stay clean."""
    from repro.analysis import analysis_counts
    resolved = _resolve_backend(preps, backend)
    reset_engine_counts()
    t0 = time.monotonic()
    jobs, spans = gather_sim_jobs(preps, firings=firings)
    results = (simulate_batch(jobs, firings=firings, backend=resolved)
               if jobs else [])
    wall = time.monotonic() - t0
    counts = engine_counts()
    meta = {"firings": firings, "jobs": len(results),
            "invocations": sum(counts.values()), "counts": counts,
            "backends": sorted({r.engine for r in results}),
            "backend": resolved,
            "wall_s": wall,
            "analysis": analysis_counts()}
    # always emitted (zeroed when the jax backend never ran) so the CI
    # gates can distinguish "no compiles" from "counters never recorded"
    from repro.kernels.sim_sweep import sweep_cache_stats
    meta["jit_cache"] = sweep_cache_stats()
    if measure_speedup and jobs:
        meta["speedup"] = measure_backend_speedup(jobs, firings=firings)
    scatter_sim_results(preps, spans, results)
    return results, meta


def prepare_design_space(graph: TaskGraph, grid: SlotGrid, *,
                         jobs: int = 1, **kwargs) -> DeferredSearch:
    """Span-wrapped front door of ``_prepare_design_space`` (which holds
    the real signature and documentation): everything between here and
    the deferred simulation — point enumeration, pool warm-up, the
    in-process autobridge replay and physical scoring — is one
    ``search.prepare`` trace span."""
    with _trace.span("search.prepare", jobs=jobs if jobs > 1 else None):
        return _prepare_design_space(graph, grid, jobs=jobs, **kwargs)


def _prepare_design_space(graph: TaskGraph, grid: SlotGrid, *,
                         space: SearchSpace | None = None,
                         mode: str = "grid",
                         n_samples: int = 64,
                         sample_seed: int = 0,
                         points: Sequence[SearchPoint] | None = None,
                         model: PhysicalModel | None = None,
                         score: Callable[[Plan], TimingReport] | None = None,
                         floorplan_cache: FloorplanCache | None = None,
                         base_sim: SimResult | None = None,
                         jobs: int = 1,
                         static_check: bool = True,
                         sim_backend: str = "auto",
                         **ab_kwargs) -> DeferredSearch:
    """Enumerate and physically score every search point, deferring the
    batched throughput simulation to the caller (see ``DeferredSearch``).

    mode    — "grid" sweeps the full cartesian product of ``space``;
              "random" draws ``n_samples`` distinct points from it.  A
              continuous space (``Interval`` axes) cannot be enumerated,
              so "grid" silently degrades to "random" there.
    points  — explicit point list (e.g. from ``SearchSpace.refine``);
              overrides ``mode``
    floorplan_cache — memoizes the ILP floorplan solves across calls
              (refine rounds, device sweeps); see ``FloorplanCache``
    base_sim — an already-simulated unpipelined baseline to reuse instead
              of scheduling the baseline job again (``DeferredSearch``)
    jobs    — fan the points' cold floorplan solves out over a process
              pool of this many workers (``repro.search.pool``), then
              replay in-process against the merged cache; ``jobs=1`` is
              the exact sequential path (results are bit-identical either
              way, the pool only moves the ILP wall time)
    static_check — pre-flight static verification (``repro.analysis``):
              ``autobridge`` refuses structurally-broken graphs before the
              ILP (verdict cached in the floorplan cache) and, once a
              firing count is known, ``DeferredSearch.apply_static_gate``
              skips the simulation of provably-deadlocked candidates.
              The produced frontier is bit-identical to
              ``static_check=False`` by the analyzer's soundness; only the
              doomed work disappears (counted by ``analysis_counts()``).
    sim_backend — ``simulate_batch`` backend the deferred jobs should be
              scored with ("auto"/"jax"/"numpy"/"event"); recorded on the
              returned ``DeferredSearch`` and honored by
              ``pool_simulations``/``timed_pool_simulations``.
    """
    model = model or PhysicalModel()
    space = space or SearchSpace()
    if static_check:
        ab_kwargs = {**ab_kwargs, "check": True}
    if mode == "grid" and space.continuous and points is None:
        mode = "random"
    if points is not None:
        points = list(points)
    elif mode == "grid":
        points = space.grid_points()
    elif mode == "random":
        points = space.sample(n_samples, seed=sample_seed)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    pool_stats: PoolStats | None = None
    if jobs > 1:
        if floorplan_cache is None:
            floorplan_cache = FloorplanCache()
        uniq: list[SearchPoint] = []
        seen_keys: set[tuple] = set()
        for pt in points:
            if pt.floorplan_key not in seen_keys:
                seen_keys.add(pt.floorplan_key)
                uniq.append(pt)
        pool_stats = warm_floorplan_cache(graph, grid, uniq,
                                          cache=floorplan_cache, jobs=jobs,
                                          ab_kwargs=ab_kwargs)
    if floorplan_cache is not None:
        ab_kwargs = {**ab_kwargs, "cache": floorplan_cache}

    cands: list[Candidate] = []
    plans: dict[tuple, tuple[float, Plan | InfeasibleError]] = {}
    # autobridge's cycle-breaking last resort mutates the input graph
    # (stream demotion, autobridge.py) — under a joint sweep that would
    # leak one point's demotion into every later point, the shared
    # baseline, and the caller's graph.  Snapshot the control flags and
    # confine any demotion to a per-candidate graph copy.
    ctrl0 = [s.control for s in graph.streams]

    def _restore_ctrl() -> bool:
        changed = False
        for s, c0 in zip(graph.streams, ctrl0):
            if s.control != c0:
                s.control = c0
                changed = True
        return changed

    def _run_autobridge(g: TaskGraph, pt: SearchPoint):
        return autobridge(g, grid, max_util=pt.max_util, seed=pt.seed,
                          row_weight=pt.row_weight, col_weight=pt.col_weight,
                          depth_scale=pt.depth_scale,
                          hbm_split=pt.hbm_split, **ab_kwargs)

    for pt in points:
        entry = plans.get(pt.floorplan_key)
        if entry is None:
            try:
                made = _run_autobridge(graph, pt)
            except InfeasibleError as err:
                made = err
            if _restore_ctrl() and not isinstance(made, InfeasibleError):
                # this point needs the demotion: re-run on a private copy so
                # the candidate keeps a consistent graph while the shared
                # one stays pristine (simulate_batch groups the split
                # topology separately inside the same padded array-sweep)
                try:
                    made = _run_autobridge(copy.deepcopy(graph), pt)
                except InfeasibleError as err:
                    made = err
                _restore_ctrl()
            entry = (pt.depth_scale, made)
            plans[pt.floorplan_key] = entry
        base_scale, base = entry
        if isinstance(base, InfeasibleError):
            cands.append(Candidate(max_util=pt.max_util, plan=None,
                                   report=None, error=str(base), point=pt))
            continue
        if pt.depth_scale == base_scale:
            plan = base
        else:
            plan = _derive_depth_variant(base.graph, grid, base, pt,
                                         **ab_kwargs)
            if isinstance(plan, InfeasibleError):
                cands.append(Candidate(max_util=pt.max_util, plan=None,
                                       report=None, error=str(plan),
                                       point=pt))
                continue
        if score is not None:
            rep = score(plan)
        else:
            rep = analyze_timing(plan.graph, grid, plan.floorplan.placement,
                                 plan.depth, model)
        cands.append(Candidate(max_util=pt.max_util, plan=plan, report=rep,
                               point=pt))

    return DeferredSearch(graph=graph, grid=grid, model=model,
                          candidates=cands, space_size=len(points),
                          base_sim=base_sim, pool=pool_stats,
                          static_check=static_check, sim_backend=sim_backend)


def _buffer_bits(plan: Plan, extra_capacity: dict[str, int]) -> dict[str, float]:
    """Per-stream inserted buffering in bits: declared FIFO storage plus
    pipeline registers plus the given headroom, width-weighted — the
    quantity ``analyze_timing(buffer_bits=...)`` charges into slots."""
    return {s.name: (int(s.depth) + plan.depth.get(s.name, 0)
                     + extra_capacity.get(s.name, 0)) * s.width
            for s in plan.graph.streams}


def _size_fifos(res: SearchResult, grid: SlotGrid, model: PhysicalModel,
                firings: int, backend: str = "auto") -> None:
    """Profile-driven FIFO sizing of the frontier (one more batch call),
    plus the area-model feedback: both the sized design and its
    uniform-headroom twin are re-scored with their buffering footprint
    charged into slot utilization, so reclaimed bits show up as fmax."""
    frontier = res.frontier
    jobs = []
    for c in frontier:
        g = c.plan.graph
        prof = simulate(g, firings=firings, latency=c.plan.depth,
                        extra_capacity=c.plan.sim_extra_capacity,
                        profile=True)
        c.profile = prof.profiles
        # observed-peak trimming: occupancy never exceeded peak, so
        # capacity=peak admits the exact same firing schedule.  Streams the
        # profiler does not model (control streams) keep their uniform
        # headroom — they were never observed, so nothing was reclaimed and
        # no area credit may be taken for them.
        declared = {s.name: int(s.depth) for s in g.streams}
        c.sized_capacity = dict(c.plan.sim_extra_capacity)
        c.sized_capacity.update({name: max(0, p.peak - declared[name])
                                 for name, p in prof.profiles.items()})
        # sized variant paired with its uniform-headroom reference at
        # the *same* firing count, so the verdict below is well-defined
        # even when fifo_firings != sim_firings
        jobs.append(SimJob(g, latency=dict(c.plan.depth),
                           extra_capacity=dict(c.sized_capacity)))
        jobs.append(c.plan.sim_job())
    results = simulate_batch(jobs, firings=firings, backend=backend)
    res.sim_calls += 1
    for i, c in enumerate(frontier):
        sized, uniform = results[2 * i], results[2 * i + 1]
        if sized.deadlocked or sized.cycles != uniform.cycles:
            # trimming broke the schedule (theoretically unreachable):
            # revert rather than hand out an unverified sizing
            c.sized_capacity = None
            c.sized_sim = None
            continue
        c.sized_sim = sized
        placement = c.plan.floorplan.placement
        c.uniform_report = analyze_timing(
            c.plan.graph, grid, placement, c.plan.depth, model,
            buffer_bits=_buffer_bits(c.plan, c.plan.sim_extra_capacity))
        c.sized_report = analyze_timing(
            c.plan.graph, grid, placement, c.plan.depth, model,
            buffer_bits=_buffer_bits(c.plan, c.sized_capacity))


def explore_design_space(graph: TaskGraph, grid: SlotGrid, *,
                         space: SearchSpace | None = None,
                         mode: str = "grid",
                         n_samples: int = 64,
                         sample_seed: int = 0,
                         points: Sequence[SearchPoint] | None = None,
                         model: PhysicalModel | None = None,
                         score: Callable[[Plan], TimingReport] | None = None,
                         sim_firings: int | None = None,
                         fifo_sizing: bool = False,
                         fifo_firings: int | None = None,
                         jobs: int = 1,
                         static_check: bool = True,
                         sim_backend: str = "auto",
                         **ab_kwargs) -> SearchResult:
    """Joint batched design-space search (see module docstring).

    mode         — "grid" sweeps the full cartesian product of ``space``;
                   "random" draws ``n_samples`` distinct points from it
    points       — explicit point list (``SearchSpace.refine`` output);
                   overrides ``mode``
    sim_firings  — when set, score *all* feasible candidates' throughput in
                   one vectorized ``simulate_batch`` call (plus the shared
                   unpipelined baseline)
    fifo_sizing  — profile frontier candidates with the event engine and
                   re-size their FIFO headroom to observed peak occupancy;
                   one more batch call verifies cycles are unchanged, and
                   the reclaimed bits are credited back into slot
                   utilization (``sized_report`` vs ``uniform_report``)
    jobs         — worker processes for the cold floorplan solves
                   (``jobs=1`` = exact sequential path, same results)
    static_check — pre-flight static verification gate (see
                   ``prepare_design_space``); frontier unchanged by
                   construction, doomed candidates never simulated
    sim_backend  — ``simulate_batch`` backend for the throughput scoring
                   (and FIFO-sizing verification) calls
    ab_kwargs    — forwarded to ``autobridge`` (e.g. ``same_slot``)

    >>> from repro.core import (SearchSpace, SlotGrid, TaskGraphBuilder,
    ...                         explore_design_space)
    >>> b = TaskGraphBuilder("chain")
    >>> _ = b.stream("s0", width=64)
    >>> _ = b.invoke("P", area={"LUT": 100}, outs=["s0"])
    >>> _ = b.invoke("C", area={"LUT": 100}, ins=["s0"])
    >>> grid = SlotGrid("g", rows=1, cols=2, base_capacity={"LUT": 150},
    ...                 max_util=1.0)
    >>> res = explore_design_space(b.build(), grid,
    ...                            space=SearchSpace(utils=(0.9, 1.0)),
    ...                            sim_firings=50)
    >>> res.space_size, res.sim_calls
    (2, 1)
    >>> res.best.throughput_preserved
    True
    """
    model = model or PhysicalModel()
    prep = prepare_design_space(graph, grid, space=space, mode=mode,
                                n_samples=n_samples, sample_seed=sample_seed,
                                points=points, model=model, score=score,
                                jobs=jobs, static_check=static_check,
                                sim_backend=sim_backend, **ab_kwargs)
    sim_calls = 0
    if sim_firings:
        prep.apply_static_gate(sim_firings)
        jobs_list = prep.sim_jobs()
        if jobs_list:
            prep.attach_sim(simulate_batch(jobs_list, firings=sim_firings,
                                           backend=sim_backend))
            sim_calls += 1
    res = prep.finish(sim_calls=sim_calls)
    if fifo_sizing and res.frontier:
        _size_fifos(res, grid, model, fifo_firings or sim_firings or 200,
                    backend=sim_backend)
    return res


# ---------------------------------------------------------------------------
# converging search: refine -> search until the frontier stops moving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ConvergedSearch:
    """Result of ``search_until_converged``: per-round results, the merged
    Pareto frontier over every evaluated point, and the hypervolume
    trajectory that decided convergence."""
    #: per-round ``SearchResult``s, in execution order
    rounds: list[SearchResult]
    #: Pareto frontier over the union of all rounds' candidates
    frontier: list[Candidate]
    #: merged-frontier hypervolume after each round (monotone by
    #: construction: the merged frontier only ever gains points)
    hypervolumes: list[float]
    #: the fixed reference point the hypervolumes are measured against
    #: (established from round 1's feasible candidates)
    ref: tuple[float, float, float] | None
    #: True when the relative hypervolume improvement fell below ``tol``
    #: before the round budget ran out
    converged: bool
    #: total ``simulate_batch`` calls across all rounds (the baseline is
    #: simulated once, in round 1, and reused)
    sim_calls: int
    #: total configurations evaluated (across rounds, anchors re-counted)
    points_evaluated: int
    #: the floorplan memoization shared by every round
    cache: FloorplanCache
    #: the round-proposal strategy that drove the search
    proposer: str = "uniform"
    #: worker processes used for the cold floorplan solves
    jobs: int = 1
    #: aggregated worker-pool activity across rounds (None when ``jobs=1``)
    pool: PoolStats | None = None
    #: completed rounds restored from a checkpoint instead of re-run
    #: (0 for a fresh, un-checkpointed or from-scratch search)
    resumed_rounds: int = 0
    #: the checkpoint directory this search journals to (None = volatile)
    checkpoint_dir: str | None = None

    @property
    def rounds_run(self) -> int:
        return len(self.rounds)

    @property
    def best(self) -> Candidate:
        """Highest-fmax routable candidate on the merged frontier."""
        return best_candidate(self.frontier)


def search_until_converged(graph: TaskGraph, grid: SlotGrid, *,
                           space: SearchSpace | None = None,
                           rounds: int = 4,
                           tol: float = 0.02,
                           points_per_round: int = 24,
                           sim_firings: int | None = 200,
                           sample_seed: int = 0,
                           initial_points: Sequence[SearchPoint] | None = None,
                           model: PhysicalModel | None = None,
                           cache: FloorplanCache | None = None,
                           jobs: int = 1,
                           proposer="uniform",
                           static_check: bool = True,
                           sim_backend: str = "auto",
                           checkpoint: str | os.PathLike | None = None,
                           **ab_kwargs) -> ConvergedSearch:
    """Converging design-space search: iterate refine -> search until the
    Pareto frontier's hypervolume stops improving.

    Round 1 samples ``points_per_round`` configurations from ``space``
    (continuous ``Interval`` axes draw uniformly; ``initial_points``, when
    given, anchor the round — e.g. the discrete sweep a converged run must
    never lose to).  Every later round re-anchors on the incumbent
    frontier's points and *compounds* the zoom: the working space is
    re-narrowed around the frontier each round (``SearchSpace.refined``:
    discrete axes halve their grid pitch, continuous axes shrink their
    range geometrically) and the round's draws come from that ever-tighter
    space.  After each round the frontier is merged across *all* evaluated
    candidates and its hypervolume w.r.t. a fixed reference point (set from
    round 1) is appended to the trajectory; the loop stops when the
    relative improvement falls below ``tol`` or ``rounds`` are exhausted.

    ``jobs=N`` fans each round's cold ILP floorplan solves over a process
    pool and replays against the merged cache — the returned frontier is
    bit-identical to ``jobs=1`` (same draws, same deterministic solves),
    only the sequential ILP wall time goes away.  ``proposer="surrogate"``
    replaces the uniform per-round draws with response-surface-guided
    proposals (``repro.search.surrogate``); the uniform path is untouched
    and remains the default.

    Cost controls built in: the unpipelined baseline is simulated once, in
    round 1, and reused by every later round (``DeferredSearch.base_sim``);
    all rounds share one ``FloorplanCache``, so re-anchored frontier points
    and revisited knob values skip the ILP solve entirely —
    ``floorplan_counts()`` proves it (solves < points evaluated, hits > 0).

    ``checkpoint=dir`` makes the whole search crash-safe: floorplan solves
    persist to a ``DiskFloorplanStore`` under ``dir/store`` (unless an
    explicit ``cache`` is passed) and the end-of-round loop state is
    journaled to ``dir`` (``SearchJournal``), so a process killed at any
    point — even mid-write — resumes from the last completed round and
    reproduces the uninterrupted run's frontier *bit for bit*.  Resuming
    with different search arguments is refused (config fingerprint); a
    search that already ran to completion replays instantly from its final
    checkpoint, with ``resumed_rounds`` saying how much was restored.  See
    ``docs/robustness-guide.md``.

    >>> from repro.core import (Interval, SearchSpace, SlotGrid,
    ...                         TaskGraphBuilder, search_until_converged)
    >>> b = TaskGraphBuilder("chain")
    >>> _ = b.stream("s0", width=64)
    >>> _ = b.invoke("P", area={"LUT": 100}, outs=["s0"])
    >>> _ = b.invoke("C", area={"LUT": 100}, ins=["s0"])
    >>> grid = SlotGrid("g", rows=1, cols=2, base_capacity={"LUT": 150},
    ...                 max_util=1.0)
    >>> res = search_until_converged(
    ...     b.build(), grid, space=SearchSpace(utils=Interval(0.8, 1.0)),
    ...     rounds=3, points_per_round=4, sim_firings=50)
    >>> res.rounds_run <= 3 and len(res.frontier) >= 1
    True
    >>> res.hypervolumes == sorted(res.hypervolumes)   # monotone
    True
    >>> res.cache.hits > 0            # refine rounds reuse floorplans
    True
    """
    model = model or PhysicalModel()
    space = space or SearchSpace()
    cur_space = space
    prop = make_proposer(proposer)

    journal: SearchJournal | None = None
    if checkpoint is not None:
        if cache is None:
            cache = DiskFloorplanStore(os.path.join(checkpoint, "store"))
        # everything that shapes the produced frontier must match for a
        # resume to reproduce the uninterrupted run (jobs / sim_backend /
        # cache are excluded on purpose: bit-identity is their contract)
        config = {
            "graph": key_digest(_graph_signature(graph)),
            "grid": key_digest(_grid_signature(grid)),
            "space": repr(space), "rounds": rounds, "tol": tol,
            "points_per_round": points_per_round,
            "sim_firings": sim_firings, "sample_seed": sample_seed,
            "initial_points": repr(tuple(initial_points or ())),
            "proposer": getattr(prop, "name", type(prop).__name__),
            "static_check": static_check,
            "ab_kwargs": repr(tuple(sorted(ab_kwargs.items()))),
        }
        journal = SearchJournal(checkpoint, config=config)
    cache = cache if cache is not None else FloorplanCache()
    total_pool = PoolStats(jobs=max(jobs, 1)) if jobs > 1 else None
    pts: list[SearchPoint] = list(initial_points or ())
    if len(pts) < points_per_round:
        have = set(pts)
        for p in space.sample(points_per_round, seed=sample_seed):
            if len(pts) >= points_per_round:
                break
            if p not in have:
                have.add(p)
                pts.append(p)

    results: list[SearchResult] = []
    evaluated: list[Candidate] = []     # deduplicated by point
    seen_pts: set[SearchPoint] = set()
    hvs: list[float] = []
    ref: tuple[float, float, float] | None = None
    base_sim: SimResult | None = None
    sim_calls = 0
    points_evaluated = 0
    converged = False
    frontier: list[Candidate] = []
    start_round = 0
    resumed_rounds = 0

    state = journal.load_latest() if journal is not None else None
    if state is not None:
        cur_space = state["cur_space"]
        pts = state["pts"]
        results = state["results"]
        evaluated = state["evaluated"]
        seen_pts = state["seen_pts"]
        hvs = state["hvs"]
        ref = state["ref"]
        base_sim = state["base_sim"]
        sim_calls = state["sim_calls"]
        points_evaluated = state["points_evaluated"]
        converged = state["converged"]
        frontier = pareto_frontier(evaluated)
        start_round = state["round_next"]
        resumed_rounds = state["round"] + 1
        if state.get("pool") is not None:
            if total_pool is not None:
                total_pool.absorb(state["pool"])
            else:
                total_pool = state["pool"]

    def _checkpoint_round(r: int) -> None:
        """Persist the end-of-round state (the commit point resume trusts)
        then visit the ``parent_kill`` fault site — the chaos drill
        SIGKILLs exactly here, after the state is durable."""
        if journal is not None:
            journal.save_round(r, {
                "round_next": r + 1, "cur_space": cur_space, "pts": pts,
                "results": results, "evaluated": evaluated,
                "seen_pts": seen_pts, "hvs": hvs, "ref": ref,
                "base_sim": base_sim, "sim_calls": sim_calls,
                "points_evaluated": points_evaluated,
                "converged": converged, "pool": total_pool,
                "hypervolume": hvs[-1] if hvs else None,
                "frontier_size": len(frontier)})
        faults.fire("parent_kill", str(r))

    for r in range(start_round, max(rounds, 1)):
        if converged:
            break
        with _trace.span("search.round", round=r,
                         points=len(pts)):
            prep = prepare_design_space(graph, grid, points=pts, model=model,
                                        floorplan_cache=cache,
                                        base_sim=base_sim, jobs=jobs,
                                        static_check=static_check,
                                        sim_backend=sim_backend,
                                        **ab_kwargs)
            if total_pool is not None and prep.pool is not None:
                total_pool.absorb(prep.pool)
            round_calls = 0
            if sim_firings:
                prep.apply_static_gate(sim_firings)
                jobs_list = prep.sim_jobs()
                if jobs_list:
                    prep.attach_sim(simulate_batch(jobs_list,
                                                   firings=sim_firings,
                                                   backend=sim_backend))
                    round_calls = 1
            base_sim = prep.base_sim
            sim_calls += round_calls
            points_evaluated += prep.space_size
            res = prep.finish(sim_calls=round_calls)
            results.append(res)
            for c in res.candidates:
                if c.point is None or c.point not in seen_pts:
                    if c.point is not None:
                        seen_pts.add(c.point)
                    evaluated.append(c)
            frontier = pareto_frontier(evaluated)
            if not frontier:
                # nothing feasible yet: re-sample fresh points and try again
                pts = cur_space.sample(points_per_round,
                                       seed=sample_seed + r + 1)
                _checkpoint_round(r)
                continue
            if ref is None:
                vecs = [_objective(c) for c in evaluated if c.plan is not None
                        and c.report and c.report.routed]
                ref = tuple(min(v[i] for v in vecs) - 1.0 for i in range(3))
            hvs.append(hypervolume([_objective(c) for c in frontier], ref))
            if len(hvs) >= 2:
                prev = hvs[-2]
                if hvs[-1] - prev <= tol * max(abs(prev), 1e-12):
                    converged = True
                    _checkpoint_round(r)
                    break
            if r + 1 < max(rounds, 1):
                anchors = [c.point for c in frontier if c.point is not None]
                # compound the zoom: narrow the working space around the
                # incumbent frontier, then draw the round's points from it —
                # uniformly by default, surrogate-ranked with proposer=
                cur_space = cur_space.refined(frontier)
                fresh = prop.propose(cur_space, frontier, evaluated,
                                     points_per_round,
                                     seed=sample_seed + 101 * (r + 1), ref=ref)
                pts, have = [], set()
                for p in anchors + fresh:
                    if p not in have:
                        have.add(p)
                        pts.append(p)
            _checkpoint_round(r)

    return ConvergedSearch(rounds=results, frontier=frontier,
                           hypervolumes=hvs, ref=ref, converged=converged,
                           sim_calls=sim_calls,
                           points_evaluated=points_evaluated, cache=cache,
                           proposer=getattr(prop, "name",
                                            type(prop).__name__),
                           jobs=max(jobs, 1), pool=total_pool,
                           resumed_rounds=resumed_rounds,
                           checkpoint_dir=(os.fspath(checkpoint)
                                           if checkpoint is not None
                                           else None))


# ---------------------------------------------------------------------------
# one-call multi-device sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BackendSweep:
    """Per-device-grid search results whose throughput scoring shared one
    batched simulator call (``sim_calls`` counts that shared call once)."""
    results: dict[str, SearchResult]
    sim_calls: int

    @property
    def best(self) -> tuple[str, Candidate]:
        """(grid name, candidate) of the highest-fmax routable candidate
        across every grid."""
        picks: dict[str, Candidate] = {}
        for name, res in self.results.items():
            try:
                picks[name] = best_candidate(res.candidates)
            except InfeasibleError:
                continue
        if not picks:
            raise InfeasibleError("no routable candidate on any device grid")
        name = max(picks, key=lambda k: picks[k].fmax)
        return name, picks[name]

    def table(self) -> list[dict]:
        """One comparison row per grid (the multi-device sweep summary)."""
        rows = []
        for name, res in self.results.items():
            try:
                c = best_candidate(res.candidates)
            except InfeasibleError:
                rows.append({
                    "grid": name, "routable": False, "fmax_mhz": 0.0,
                    "util": None, "area_overhead_bits": None,
                    "cycles": None, "throughput_preserved": None,
                    "frontier": len(res.frontier),
                })
                continue
            rows.append({
                "grid": name, "routable": True, "fmax_mhz": c.fmax,
                "util": c.point.max_util if c.point else None,
                "area_overhead_bits": c.plan.area_overhead,
                "cycles": c.sim.cycles if c.sim else None,
                "throughput_preserved": c.throughput_preserved,
                "frontier": len(res.frontier),
            })
        return rows


def sweep_backends(graph: TaskGraph,
                   grids: Mapping[str, SlotGrid] | Sequence[SlotGrid], *,
                   space: SearchSpace | None = None,
                   mode: str = "grid",
                   n_samples: int = 64,
                   sample_seed: int = 0,
                   model: PhysicalModel | None = None,
                   sim_firings: int | None = 200,
                   cache: FloorplanCache | None = None,
                   jobs: int = 1,
                   static_check: bool = True,
                   sim_backend: str = "auto",
                   **ab_kwargs) -> BackendSweep:
    """One-call multi-device sweep: the same design searched across several
    device grids (U250/U280/TPU-pod shapes from ``repro.fpga.archs``), with
    *all* grids' candidates plus their shared baselines scored by a single
    ``simulate_batch`` call — the padded backend vectorizes across the
    per-grid candidate sets even when cycle-breaking stream demotions give
    some candidates a different topology.

    ``grids`` is a name -> ``SlotGrid`` mapping, or a sequence of grids
    keyed by their ``.name`` (duplicates get a ``#2``-style suffix).
    Returns a ``BackendSweep``: per-grid ``SearchResult``s, ``best``
    across grids, and a ``table()`` comparison summary.  All grids share
    one ``FloorplanCache`` (pass ``cache=`` to share it wider), so a grid
    appearing twice — or a later converged search on the same grid — skips
    its ILP solves.  ``jobs=N`` fans each grid's cold floorplan solves
    over a process pool (same results, less wall time).

    >>> from repro.core import SearchSpace, SlotGrid, TaskGraphBuilder
    >>> from repro.core import sweep_backends
    >>> b = TaskGraphBuilder("chain")
    >>> _ = b.stream("s0", width=64)
    >>> _ = b.invoke("P", area={"LUT": 100}, outs=["s0"])
    >>> _ = b.invoke("C", area={"LUT": 100}, ins=["s0"])
    >>> small = SlotGrid("small", rows=1, cols=2,
    ...                  base_capacity={"LUT": 150}, max_util=1.0)
    >>> wide = SlotGrid("wide", rows=1, cols=4,
    ...                  base_capacity={"LUT": 300}, max_util=1.0)
    >>> sweep = sweep_backends(b.build(), {"small": small, "wide": wide},
    ...                        space=SearchSpace(utils=(0.9, 1.0)),
    ...                        sim_firings=50)
    >>> sorted(sweep.results), sweep.sim_calls
    (['small', 'wide'], 1)
    >>> name, champ = sweep.best
    >>> champ.plan is not None
    True
    """
    model = model or PhysicalModel()
    if isinstance(grids, Mapping):
        named = dict(grids)
    else:
        named = {}
        for g in grids:
            key = g.name
            i = 2
            while key in named:
                key = f"{g.name}#{i}"
                i += 1
            named[key] = g
    if not named:
        raise ValueError("sweep_backends needs at least one device grid")

    cache = cache or FloorplanCache()
    preps = {name: prepare_design_space(graph, g, space=space, mode=mode,
                                        n_samples=n_samples,
                                        sample_seed=sample_seed, model=model,
                                        floorplan_cache=cache, jobs=jobs,
                                        static_check=static_check,
                                        sim_backend=sim_backend,
                                        **ab_kwargs)
             for name, g in named.items()}
    sim_calls = 0
    if sim_firings and pool_simulations(list(preps.values()),
                                       firings=sim_firings):
        sim_calls = 1
    return BackendSweep(
        results={name: prep.finish(sim_calls=sim_calls)
                 for name, prep in preps.items()},
        sim_calls=sim_calls)


# ---------------------------------------------------------------------------
# single-axis compatibility wrapper (paper §6.3 verbatim)
# ---------------------------------------------------------------------------

def explore_floorplans(graph: TaskGraph, grid: SlotGrid, *,
                       utils: tuple[float, ...] = DEFAULT_UTILS,
                       seed: int = 0,
                       model: PhysicalModel | None = None,
                       score: Callable[[Plan], TimingReport] | None = None,
                       sim_firings: int | None = None,
                       **ab_kwargs) -> list[Candidate]:
    """Single-axis max-util sweep: one candidate per util point, in sweep
    order, infeasible points kept as failed candidates (paper Table 10).
    Thin wrapper over ``explore_design_space`` with every other axis pinned
    to its default."""
    model = model or PhysicalModel()
    space = SearchSpace(seeds=(seed,), utils=tuple(utils))
    res = explore_design_space(graph, grid, space=space, model=model,
                               score=score, sim_firings=sim_firings,
                               **ab_kwargs)
    return res.candidates


def best_candidate(cands: list[Candidate]) -> Candidate:
    ok = [c for c in cands
          if c.plan is not None and c.report and c.report.routed
          and (c.sim is None or not c.sim.deadlocked)]
    if not ok:
        raise InfeasibleError("no routable floorplan candidate")
    return max(ok, key=lambda c: c.report.fmax_mhz)
