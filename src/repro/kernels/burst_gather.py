"""burst_gather — the TPU adaptation of TAPA's async_mmap + runtime burst
detector (paper §3.4, Table 1).

The paper splits a memory port into request/response streams and inserts a
*burst detector* that watches the address stream and merges runs of
consecutive addresses into long burst transactions.  The TPU analogue: a
gather whose index stream is scanned for contiguous runs; a run of length
>= the tile size is serviced by ONE block DMA (HBM -> VMEM dynamic slice)
instead of per-row gathers.  Embedding lookups and KV-page fetches are
mostly-sequential with occasional jumps — exactly the access pattern Table
1 illustrates — so the common case is the burst path.

Implementation: grid over index tiles of size ``IB``.  The index tile is
prefetched to SMEM (PrefetchScalarGridSpec).  If the whole tile is one run
(idx[i] == idx[0] + i — checked on the scalar stream like the paper's
detector), the kernel issues a single dynamic-slice copy of IB consecutive
table rows; otherwise it falls back to IB per-row dynamic-slice copies.
The table stays in ANY/HBM memory space — rows are DMA'd on demand, which
is the whole point (an FPGA would call this "not buffering the burst in
BRAM", Table 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_IB = 8

# jax renamed pltpu.TPUMemorySpace -> pltpu.MemorySpace across releases;
# support both so the kernel works on the baked-in toolchain.
_ANY_MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
_ANY_MEMSPACE = _ANY_MEMSPACE.ANY


def _kernel(idx_ref, table_ref, o_ref, *, ib):
    t = pl.program_id(0)
    base = idx_ref[t * ib]
    # ---- the burst detector: is this tile one consecutive run? -----------
    run = jnp.asarray(True)
    for i in range(1, ib):
        run = jnp.logical_and(run, idx_ref[t * ib + i] == base + i)

    @pl.when(run)
    def _burst():
        # one long transaction: IB consecutive rows in a single DMA
        o_ref[...] = table_ref[pl.dslice(base, ib), :]

    @pl.when(jnp.logical_not(run))
    def _scatter():
        # fall back to per-row transactions
        for i in range(ib):
            o_ref[i, :] = table_ref[pl.dslice(idx_ref[t * ib + i], 1), :][0]


def burst_gather(table: jax.Array, idx: jax.Array, *, ib: int = DEFAULT_IB,
                 interpret: bool = False) -> jax.Array:
    """table: (R, D); idx: (N,) int32 -> (N, D)."""
    R, D = table.shape
    N = idx.shape[0]
    Np = -(-N // ib) * ib
    idxp = jnp.pad(idx.astype(jnp.int32), (0, Np - N))
    Dp = max(128, -(-D // 128) * 128)
    tablep = jnp.pad(table, ((0, 0), (0, Dp - D)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Np // ib,),
        in_specs=[pl.BlockSpec(memory_space=_ANY_MEMSPACE)],
        out_specs=pl.BlockSpec((ib, Dp), lambda t, idx_ref: (t, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ib=ib),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Np, Dp), table.dtype),
        interpret=interpret,
    )(idxp, tablep)
    return out[:N, :D]
