"""Compute hot-spot kernels.

Two families live here:

* Pallas/``jax`` model kernels (``flash_attention``, ``mamba2_scan``,
  ``rwkv6_scan``, ``moe_gmm``, ``burst_gather``) routed through
  ``repro.kernels.ops`` with reference implementations in
  ``repro.kernels.ref`` — import those submodules directly.
* The padded batch simulator sweep: ``repro.kernels.padded_batch``
  builds the canonical padded (V, T*, S*) layout both ``simulate_batch``
  array backends consume, and ``repro.kernels.sim_sweep`` is the
  ``jax.jit``-compiled sweep behind ``simulate_batch(backend="jax")``.

Exports resolve lazily (PEP 562): importing ``repro.kernels`` — or
``repro.core``, which pulls it in for ``simulate_batch`` — never imports
jax; only touching a ``sim_sweep`` name does, and even that degrades to
``HAVE_JAX = False`` instead of raising when jax is absent.
"""
from __future__ import annotations

_PADDED_EXPORTS = ("PaddedBatch", "PaddedGroup", "build_padded_batch")
_SWEEP_EXPORTS = ("HAVE_JAX", "fits_int32", "reset_sweep_cache_stats",
                  "simulate_padded_jax", "sweep_cache_stats")

__all__ = [*_PADDED_EXPORTS, *_SWEEP_EXPORTS]


def __getattr__(name):
    if name in _PADDED_EXPORTS:
        from repro.kernels import padded_batch

        return getattr(padded_batch, name)
    if name in _SWEEP_EXPORTS:
        from repro.kernels import sim_sweep

        return getattr(sim_sweep, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
