"""Canonical padded batch layout shared by the array simulator backends.

``simulate_batch``'s padded ragged-batch engines (NumPy and JAX) both
consume the layout built here: jobs grouped by topology signature, every
group padded to the batch-max (T*, S*) task/stream shape, with explicit
masks that keep the padding inert.

Phantom-mask invariants (property-tested in ``tests/test_padded_batch.py``):

* **phantom tasks never fire** — columns ``>= group.T`` have
  ``task_active`` False, so the firing rule masks them out, and ``counted``
  False, so they are vacuously done in the termination/deadlock checks;
* **phantom streams never stall a real task** — columns ``>= group.S``
  are attached to no real task: their ``cons``/``prod`` entries point at
  the sentinel task index ``T*`` (one past the last real column), their
  per-group incidence matrices carry no row for them, and their capacity
  is zero only for *themselves* (nothing reads it).

Both backends therefore produce exactly the per-job results of an
unpadded event simulation; only the array shapes are shared.

The builder lives in ``repro.kernels`` because the padded sweep is the
repo's simulation hot path: the JAX backend (``repro.kernels.sim_sweep``)
jit-compiles one sweep per padded shape and reuses it across heterogeneous
search rounds.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PaddedGroup:
    """One topology group's index structures and padded-row placement.

    Rows ``[r0, r1)`` of the batch arrays belong to this group; its real
    tasks/streams occupy the first ``T``/``S`` columns and the remaining
    columns up to the batch-max (T*, S*) are phantom padding."""

    r0: int
    r1: int
    #: task names, in column order
    names: list[str]
    #: data-stream names, in column order
    snames: list[str]
    T: int
    S: int
    #: producer/consumer task column per real stream, shape (S,)
    prod: np.ndarray
    cons: np.ndarray
    #: incidence matrices stream -> task (real streams only), shape (S, T)
    a_in: np.ndarray
    a_out: np.ndarray
    #: per-task real input/output stream counts, shape (T,)
    indeg: np.ndarray
    outdeg: np.ndarray


@dataclasses.dataclass
class PaddedBatch:
    """The canonical padded layout of one ``simulate_batch`` call."""

    #: batch size and padded dims: jobs, batch-max tasks/streams, ring depth
    V: int
    T: int
    S: int
    H: int
    #: padded row -> original job index (row v's results go to perm[v])
    perm: list[int]
    groups: list[PaddedGroup]
    #: per-job knob arrays, phantom columns zeroed (ii: ones), (V, S)/(V, T)
    lat: np.ndarray
    cap: np.ndarray
    ii: np.ndarray
    #: real-task mask / real-and-non-detached mask, (V, T) bool
    task_active: np.ndarray
    counted: np.ndarray
    #: real-stream mask, (V, S) bool
    stream_active: np.ndarray
    #: flat per-job consumer/producer task columns, (V, S); phantom streams
    #: carry the sentinel index ``T`` (one past the last real task column)
    cons: np.ndarray
    prod: np.ndarray

    def unpack(self, cycles, dead, fired, steps: int, engine: str) -> list:
        """Distribute padded per-row results back into ``SimResult``s in
        the original job order (inverse of the grouping permutation)."""
        from repro.core.simulate import SimResult

        out = [None] * self.V
        for g in self.groups:
            for v in range(g.r0, g.r1):
                out[self.perm[v]] = SimResult(
                    cycles=int(cycles[v]),
                    fired={n: int(fired[v, i]) for i, n in enumerate(g.names)},
                    deadlocked=bool(dead[v]),
                    steps=int(steps),
                    engine=engine,
                )
        return out


def build_padded_batch(jobs) -> PaddedBatch:
    """Group ``SimJob``s by topology signature and build the canonical
    padded (V, T*, S*) layout both array backends consume."""
    # imported here: repro.core.simulate imports this module lazily, so a
    # top-level import back into it would be circular at load time
    from repro.core.simulate import _Model, _topology_signature

    sig_cache: dict[int, tuple] = {}
    members: dict[tuple, list[int]] = {}
    for v, j in enumerate(jobs):
        sig = sig_cache.get(id(j.graph))
        if sig is None:
            sig = _topology_signature(j.graph)
            sig_cache[id(j.graph)] = sig
        members.setdefault(sig, []).append(v)
    perm = [v for mem in members.values() for v in mem]
    models = [
        _Model(jobs[v].graph, jobs[v].latency, jobs[v].extra_capacity, jobs[v].ii)
        for v in perm
    ]

    groups: list[PaddedGroup] = []
    r0 = 0
    for mem in members.values():
        m0 = models[r0]
        names = m0.names
        snames = [s.name for s in m0.data]
        T, S = len(names), len(snames)
        tidx = {n: i for i, n in enumerate(names)}
        prod = np.array([tidx[m0.producer[s]] for s in snames], dtype=np.int64)
        cons = np.array([tidx[m0.consumer[s]] for s in snames], dtype=np.int64)
        a_in = np.zeros((S, T), dtype=np.int64)
        a_out = np.zeros((S, T), dtype=np.int64)
        for si in range(S):
            a_in[si, cons[si]] = 1
            a_out[si, prod[si]] = 1
        groups.append(
            PaddedGroup(
                r0=r0,
                r1=r0 + len(mem),
                names=names,
                snames=snames,
                T=T,
                S=S,
                prod=prod,
                cons=cons,
                a_in=a_in,
                a_out=a_out,
                indeg=a_in.sum(axis=0),
                outdeg=a_out.sum(axis=0),
            )
        )
        r0 += len(mem)

    V = len(jobs)
    T = max((g.T for g in groups), default=0)
    S = max((g.S for g in groups), default=0)

    lat = np.zeros((V, S), dtype=np.int64)
    cap = np.zeros((V, S), dtype=np.int64)
    ii = np.ones((V, T), dtype=np.int64)
    task_active = np.zeros((V, T), dtype=bool)
    counted = np.zeros((V, T), dtype=bool)
    stream_active = np.zeros((V, S), dtype=bool)
    # phantom streams attach to the sentinel task column T: gathers through
    # them read the all-zero sentinel, so they can never gate or be gated
    cons = np.full((V, S), T, dtype=np.int64)
    prod = np.full((V, S), T, dtype=np.int64)
    for g in groups:
        r0, r1, gT, gS = g.r0, g.r1, g.T, g.S
        for v in range(r0, r1):
            m = models[v]
            if gS:
                lat[v, :gS] = [m.lat[s] for s in g.snames]
                cap[v, :gS] = [m.cap[s] for s in g.snames]
            if gT:
                ii[v, :gT] = [m.ii[n] for n in g.names]
                counted[v, :gT] = [not m.detached[n] for n in g.names]
        task_active[r0:r1, :gT] = True
        stream_active[r0:r1, :gS] = True
        cons[r0:r1, :gS] = g.cons
        prod[r0:r1, :gS] = g.prod

    H = int(lat.max(initial=0)) + 2
    return PaddedBatch(
        V=V,
        T=T,
        S=S,
        H=H,
        perm=perm,
        groups=groups,
        lat=lat,
        cap=cap,
        ii=ii,
        task_active=task_active,
        counted=counted,
        stream_active=stream_active,
        cons=cons,
        prod=prod,
    )
