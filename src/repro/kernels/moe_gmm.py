"""Grouped (per-expert) matmul for MoE FFNs.

Tokens are pre-sorted by expert (standard MoE dispatch); the kernel tiles
the token stream (Tb x K) and sweeps experts on the trailing sequential
grid axis, accumulating ``mask(token in expert e) * (x_tile @ w[e])`` into
the output tile.  Because group ids are sorted, each token tile overlaps
O(1) experts — every other (tile, expert) pair is skipped via ``pl.when``
on a per-tile expert-range check before any compute or weight DMA, so the
effective work is O(T/Tb + E) tiles, the megablocks bound.

Tiling: x (Tb=128, K), w (K, N) per expert, out (Tb, N) revisited across
the expert axis (TPU grids are sequential, so accumulation in the output
block is safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TB = 128


def _kernel(gid_ref, x_ref, w_ref, o_ref, *, tb, n_exp):
    t = pl.program_id(0)
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # expert range present in this token tile (sorted ids: check endpoints)
    lo = gid_ref[t * tb]
    hi = gid_ref[t * tb + tb - 1]

    @pl.when(jnp.logical_and(lo <= e, e <= hi))
    def _body():
        x = x_ref[...].astype(jnp.float32)                  # (Tb, K)
        w = w_ref[0].astype(jnp.float32)                    # (K, N)
        mask = jnp.zeros((tb, 1), jnp.float32)
        # gid lookup from SMEM (scalar stream)
        rows = jnp.stack([gid_ref[t * tb + i] for i in range(tb)])
        mask = (rows == e).astype(jnp.float32)[:, None]
        o_ref[...] += (mask * jax.lax.dot(x, w)).astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, group_ids: jax.Array, *,
            tb: int = DEFAULT_TB, interpret: bool = False) -> jax.Array:
    """x: (T, K); w: (E, K, N); group_ids: (T,) sorted -> (T, N)."""
    T, K = x.shape
    E, _, N = w.shape
    tb = min(tb, max(8, 1 << max(T - 1, 1).bit_length()))
    Tp = -(-T // tb) * tb
    Kp = max(128, -(-K // 128) * 128)
    Np = max(128, -(-N // 128) * 128)
    xp = jnp.pad(x, ((0, Tp - T), (0, Kp - K)))
    wp = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
    # padded tokens route to a sentinel expert id that never matches
    gids = jnp.pad(group_ids.astype(jnp.int32), (0, Tp - T),
                   constant_values=E + 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Tp // tb, E),
        in_specs=[
            pl.BlockSpec((tb, Kp), lambda t, e, g: (t, 0)),
            pl.BlockSpec((1, Kp, Np), lambda t, e, g: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, Np), lambda t, e, g: (t, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, tb=tb, n_exp=E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, Np), x.dtype),
        interpret=interpret,
    )(gids, xp, wp)
    return out[:T, :N]
