"""Blocked flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Layout: grid (B, Hq, nQ, nK) — the trailing KV dimension is sequential on
TPU, so the online-softmax running state (m, l, acc) lives in VMEM scratch
that persists across KV iterations.  GQA is free: the K/V index map sends
query head h to KV head h // group.  Causal masking, sliding windows and
gemma logit soft-caps are fused; fully-maskable KV blocks are skipped via
``pl.when``.

Tiling: Qb x D and Kb x D blocks, 128-aligned for the MXU; head dims that
are not multiples of 128 are zero-padded by the wrapper.  VMEM per program:
q/k/v blocks (3 x 32 KiB bf16) + f32 scratch (m, l: 1 KiB; acc: 64 KiB) —
far under the ~16 MiB budget, leaving room for double buffering of the K/V
streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_QB = 128
DEFAULT_KB = 128
NEG_INF = -1e30


def _kernel(klen_ref, qoff_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            causal, window, softcap, scale, nk, qb, kb, use_klen):
    j = pl.program_id(3)
    i = pl.program_id(2)
    q_offset = qoff_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = q_offset + i * qb + jax.lax.broadcasted_iota(
        jnp.int32, (qb, kb), 0)
    kpos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)

    # block-level skip: causal blocks entirely in the future, window blocks
    # entirely in the past
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, j * kb <= q_offset + (i + 1) * qb - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (j + 1) * kb - 1 > q_offset + i * qb - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qb, kb)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones((qb, kb), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if use_klen:
            mask &= kpos < klen_ref[0, 0]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(jnp.float32), v_ref[0, 0].astype(jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _fin():
        lsum = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(lsum > 0, lsum, 1.0)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, q_offset=0, kv_len=None,
                    qb=DEFAULT_QB, kb=DEFAULT_KB, interpret=False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qb = min(qb, max(8, 1 << max(Sq - 1, 1).bit_length()))
    kb = min(kb, max(128, 1 << max(Skv - 1, 1).bit_length()))
    Sq_p = -(-Sq // qb) * qb
    Skv_p = -(-Skv // kb) * kb
    Dp = max(128, -(-D // 128) * 128)
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, Dp - D)))
    kp = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, Dp - D)))
    vp = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, Dp - D)))
    qp = qp.transpose(0, 2, 1, 3)      # (B, H, S, D)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    nq, nk = Sq_p // qb, Skv_p // kb

    if kv_len is None:
        klen = jnp.full((B, 1), Skv, jnp.int32)
        use_klen = Skv_p != Skv
    else:
        klen = jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(-1, 1), (B, 1))
        use_klen = True
    qoff = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1, 1), (B, 1))

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap, scale=scale,
        nk=nk, qb=qb, kb=kb, use_klen=use_klen)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0)),       # klen
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0)),       # qoff
            pl.BlockSpec((1, 1, qb, Dp), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, Dp), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, kb, Dp), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, Dp),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, Dp), jnp.float32),
        ],
        interpret=interpret,
    )(klen, qoff, qp, kp, vp)
    return out.transpose(0, 2, 1, 3)[:, :Sq, :, :D]


def decode_attention(q, k, v, *, softcap=None, scale=None, q_offset=0,
                     kv_len=None, window=None, interpret=False):
    """Single-token decode: q (B, 1, Hq, D) against a (possibly ring-
    buffered) KV cache.  Reuses the flash kernel with a padded query tile;
    causality is enforced through ``kv_len`` (every cached key is valid)."""
    B, Sq, Hq, D = q.shape
    assert Sq == 1
    qp = jnp.pad(q, ((0, 0), (0, 7), (0, 0), (0, 0)))
    out = flash_attention(qp, k, v, causal=False, window=None,
                          softcap=softcap, scale=scale, q_offset=q_offset,
                          kv_len=kv_len, qb=8, interpret=interpret)
    return out[:, :1]
