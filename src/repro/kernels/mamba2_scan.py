"""Chunked SSD (Mamba-2) scan for TPU.

The sequential recurrence  h_t = a_t h_{t-1} + dt_t x_t B_t^T,
y_t = h_t C_t  (a_t = exp(dt_t A)) is reformulated per chunk of length T as
three MXU-friendly matmuls (the SSD "chunked dual form"):

  intra:  y = (mask(C B^T) * decay(t, tau)) @ (dt * x)
  inter:  y += decay(t, 0) * (C @ state^T)
  state': state * decay(T, 0) + ((dt * x) * decay(T, tau))^T @ B

Grid: (B, H, n_chunks) — the chunk axis is sequential on TPU, so the
(P, N) state is carried in f32 VMEM scratch across chunk iterations.
Tiling: chunk T=128, P (head dim) and N (state dim) padded to 128.  VMEM
per program: x/B/C chunks (3 x 64 KiB f32) + decay tables + state
(64 KiB) — well under budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
            y_ref, hout_ref, state_ref, *, nc, T):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (T, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (1, T) row
    A = a_ref[0, 0, 0, 0]                        # scalar (f32)
    Bm = b_ref[0].astype(jnp.float32)            # (T, N)
    Cm = c_ref[0].astype(jnp.float32)            # (T, N)
    h = state_ref[...]                           # (P, N)

    seg = dt[0] * A                              # (T,) log-decay increments
    cum = jnp.cumsum(seg)                        # s_t = sum_{tau<=t} seg
    # decay(t, tau) = exp(s_t - s_tau) for tau <= t (strictly before within
    # the recurrence the input at tau is included from step tau itself)
    st = cum[:, None]                            # (T, 1)
    stau = cum[None, :]                          # (1, T)
    tri = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    decay = jnp.where(tri, jnp.exp(st - stau), 0.0)

    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (T, T)
    dx = x * dt[0][:, None]                                     # (T, P)
    y = jax.lax.dot((G * decay).astype(jnp.float32), dx)        # (T, P)
    # inter-chunk: h carries state BEFORE this chunk; contribution at step t
    # is C_t . (h * exp(s_t))
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())))                        # (T, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    total = cum[-1]
    w = jnp.exp(total - cum)[:, None]                           # (T, 1)
    new_h = h * jnp.exp(total) + jax.lax.dot_general(
        dx * w, Bm, (((0,), (0,)), ((), ())))                   # (P, N)
    state_ref[...] = new_h
    hout_ref[0, 0] = new_h


def mamba2_scan(x, dt, A, B_, C, state=None, *, chunk=DEFAULT_CHUNK,
                interpret=False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); B_,C: (B,S,N);
    state: (B,H,P,N) or None.  Returns (y (B,S,H,P), state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    T = min(chunk, max(8, 1 << max(S - 1, 1).bit_length()))
    Sp = -(-S // T) * T
    Pp = max(128, -(-P // 128) * 128)
    Np = max(128, -(-N // 128) * 128)
    nc = Sp // T

    xp = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0), (0, Pp - P)))
    xp = xp.transpose(0, 2, 1, 3)                       # (B,H,S,P)
    # padded steps must be identity: dt = 0 there
    dtp = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
    dtp = dtp.transpose(0, 2, 1)[:, :, None, :]         # (B,H,1,S)
    Ar = A.astype(jnp.float32).reshape(1, H, 1, 1)
    Ar = jnp.broadcast_to(Ar, (Bsz, H, 1, 1))
    Bp = jnp.pad(B_, ((0, 0), (0, Sp - S), (0, Np - N)))
    Cp = jnp.pad(C, ((0, 0), (0, Sp - S), (0, Np - N)))
    h0 = (jnp.zeros((Bsz, H, Pp, Np), jnp.float32) if state is None else
          jnp.pad(state.astype(jnp.float32),
                  ((0, 0), (0, 0), (0, Pp - P), (0, Np - N))))

    kernel = functools.partial(_kernel, nc=nc, T=T)
    y, hout = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, T, Pp), lambda b, h, c: (b, h, c, 0)),   # x
            pl.BlockSpec((1, 1, 1, T), lambda b, h, c: (b, h, 0, c)),    # dt
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (b, h, 0, 0)),    # A
            pl.BlockSpec((1, T, Np), lambda b, h, c: (b, c, 0)),         # B
            pl.BlockSpec((1, T, Np), lambda b, h, c: (b, c, 0)),         # C
            pl.BlockSpec((1, 1, Pp, Np), lambda b, h, c: (b, h, 0, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, Pp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Pp, Np), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Sp, Pp), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, Pp, Np), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pp, Np), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, Ar, Bp, Cp, h0)
    y = y.transpose(0, 2, 1, 3)[:, :S, :, :P]
    return y, hout[:, :, :P, :N]
