"""Pure-jnp reference oracles for every Pallas kernel.

These are the semantics contract: ``kernels/<name>.py`` (Pallas) must match
these bit-for-bit (up to dtype tolerance) across the shape/dtype sweeps in
``tests/test_kernels_*.py``.  The model layer calls ``kernels.ops`` which
dispatches to either implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# attention (GQA, causal, sliding window, logit softcap)
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int | None = None,
                  softcap: float | None = None,
                  scale: float | None = None,
                  q_offset: int = 0,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """Multi-head attention with grouped KV heads.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: optional (B,) valid KV lengths (ragged decode batches).
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # (B, Hkv, g, Sq, Skv)
    logits = jnp.einsum("bqhd,bkhd->bhqk",
                        qf.reshape(B, Sq, Hkv, g, D).reshape(B, Sq, Hkv * g, D),
                        jnp.repeat(kf, g, axis=2))
    logits = logits.reshape(B, Hkv * g, Sq, Skv)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap

    qpos = q_offset + jnp.arange(Sq)[:, None]          # (Sq, 1)
    kpos = jnp.arange(Skv)[None, :]                    # (1, Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    mask = mask[None, None]
    if kv_len is not None:
        mask = mask & (kpos[None, None] < kv_len[:, None, None, None])
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with no valid key (fully masked) produce NaN-free zeros:
    p = jnp.where(mask.any(-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, jnp.repeat(vf, g, axis=2))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# mamba2 (SSD) chunked scan
# ---------------------------------------------------------------------------

def mamba2_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                    B_: jax.Array, C: jax.Array,
                    state: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """SSD recurrence (Mamba-2), sequential reference.

    x:  (B, S, H, P)   — input heads (P = head dim)
    dt: (B, S, H)      — positive step sizes (post-softplus)
    A:  (H,)           — negative decay rates
    B_: (B, S, N)      — input projection (shared across heads)
    C:  (B, S, N)      — output projection
    state: (B, H, P, N) initial state (None = zeros)
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t
    """
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(h, t):
        decay = jnp.exp(dtf[:, t] * Af[None, :])           # (B, H)
        dx = dtf[:, t][..., None] * xf[:, t]               # (B, H, P)
        upd = dx[..., None] * Bf[:, t][:, None, None, :]   # (B, H, P, N)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                              # (B, S, H, P)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# rwkv6 (Finch) recurrence
# ---------------------------------------------------------------------------

def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array,
                   w: jax.Array, u: jax.Array,
                   state: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 WKV recurrence with data-dependent decay, sequential ref.

    r, k, v: (B, S, H, D); w: (B, S, H, D) decay in (0,1) (= exp(-exp(w_raw)));
    u: (H, D) bonus.  state: (B, H, D, D) (None = zeros).
    Returns (y: (B, S, H, D), final state).

      y_t = r_t . (S + u * k_t^T v_t);   S = diag(w_t) S + k_t^T v_t
    """
    B, S, H, D = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    s0 = (jnp.zeros((B, H, D, D), jnp.float32) if state is None
          else state.astype(jnp.float32))

    def step(s, t):
        kv = kf[:, t][..., :, None] * vf[:, t][..., None, :]   # (B,H,D,D)
        y = jnp.einsum("bhd,bhde->bhe", rf[:, t], s + uf[None, :, :, None] * kv)
        s = wf[:, t][..., :, None] * s + kv
        return s, y

    s, ys = jax.lax.scan(step, s0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(r.dtype), s


# ---------------------------------------------------------------------------
# burst gather (the paper's async_mmap + burst detector, §3.4)
# ---------------------------------------------------------------------------

def burst_gather_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather rows of ``table`` at ``idx``.

    table: (R, D); idx: (N,) int32 -> (N, D).  The Pallas kernel streams the
    index vector through a run-length burst detector and issues one block
    DMA per run of consecutive indices (the TPU analogue of merging
    sequential AXI reads into burst transactions).  Semantics are a plain
    gather.
    """
    return jnp.take(table, idx, axis=0)


# ---------------------------------------------------------------------------
# MoE grouped matmul (expert FFN applied per routed token)
# ---------------------------------------------------------------------------

def moe_gmm_ref(x: jax.Array, w: jax.Array, group_ids: jax.Array) -> jax.Array:
    """Grouped matmul: x[i] @ w[group_ids[i]].

    x: (T, K); w: (E, K, N); group_ids: (T,) in [0, E) -> (T, N).
    The Pallas kernel assumes ``group_ids`` is sorted (tokens grouped by
    expert, standard MoE dispatch) and tiles over experts; the reference is
    a one-hot einsum.
    """
    T, K = x.shape
    E = w.shape[0]
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    onehot = jax.nn.one_hot(group_ids, E, dtype=jnp.float32)   # (T, E)
    # (T, E) x (E, K, N) x (T, K) -> (T, N)
    y = jnp.einsum("te,tk,ekn->tn", onehot, xf, wf)
    return y.astype(x.dtype)
