"""Chunked RWKV-6 (Finch) WKV recurrence for TPU.

Recurrence (per head, state S in R^{DxD}):
    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (w_t in (0,1), per-channel)

Chunked dual form over a chunk of length T with per-channel log-decay
cumsum  c_t = sum_{j<=t} log w_j  (c in R^{T x D}):

    intra:  y_t = sum_{tau<t} (r_t * exp(c_{t-1} - c_tau)) . k_tau v_tau
                  + (r_t * u) . k_t v_t
            => masked (T x T) matmul with rescaled r~ = r * exp(c_prev),
               k~ = k * exp(-c)
    inter:  y_t += (r_t * exp(c_{t-1})) . S_in
    state:  S_out = diag(exp(c_T)) S_in + sum_tau (k_tau * exp(c_T - c_tau))^T v_tau

Chunk-local cumsums keep exp(+/-c) bounded (T <= 64 by default), the
standard numerical treatment for data-dependent decay.

Grid: (B, H, n_chunks), chunk axis sequential, state carried in VMEM
scratch (f32, D x D padded to 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            y_ref, sout_ref, state_ref, *, T):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)            # (T, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)          # (T, D) log decay (<0)
    u = u_ref[0, 0].astype(jnp.float32)            # (1, D)
    S = state_ref[...]                             # (D, D)

    c = jnp.cumsum(lw, axis=0)                     # (T, D) inclusive
    c_prev = c - lw                                # exclusive cumsum
    r_t = r * jnp.exp(c_prev)                      # (T, D)
    k_t = k * jnp.exp(-c)                          # (T, D)

    # intra-chunk, strictly-lower-triangular attention-like matmul
    att = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())))  # (T, T)
    tri = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    att = jnp.where(tri, att, 0.0)
    y = jax.lax.dot(att, v)                                         # (T, D)
    # diagonal bonus term: (r_t * u) . k_t v_t
    diag = ((r * u) * k).sum(-1, keepdims=True)                     # (T, 1)
    y = y + diag * v
    # inter-chunk
    y = y + jax.lax.dot(r_t, S)                                     # (T, D)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    cT = c[-1]                                                      # (D,)
    k_out = k * jnp.exp(cT[None, :] - c)                            # (T, D)
    S_new = S * jnp.exp(cT)[:, None] + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())))                         # (D, D)
    state_ref[...] = S_new
    sout_ref[0, 0] = S_new


def rwkv6_scan(r, k, v, w, u, state=None, *, chunk=DEFAULT_CHUNK,
               interpret=False):
    """r,k,v,w: (B,S,H,D) (w = decay in (0,1)); u: (H,D);
    state: (B,H,D,D) or None -> (y (B,S,H,D), state (B,H,D,D))."""
    B, S, H, D = r.shape
    T = min(chunk, max(8, 1 << max(S - 1, 1).bit_length()))
    Sp = -(-S // T) * T
    Dp = max(128, -(-D // 128) * 128)
    nc = Sp // T

    def prep(a, pad_value=0.0):
        a = jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0), (0, Dp - D)),
                    constant_values=pad_value)
        return a.transpose(0, 2, 1, 3)             # (B,H,S,D)

    rp, kp, vp = prep(r), prep(k), prep(v)
    # padded steps: w=1 (log w = 0) keeps the state unchanged; padded
    # channels also decay at 1 to avoid exp overflow in the +/- cumsums
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, 0), (0, 0)),
                 constant_values=1.0)
    wp = jnp.pad(wp, ((0, 0), (0, 0), (0, 0), (0, Dp - D)),
                 constant_values=1.0)
    lwp = jnp.log(jnp.maximum(wp, 1e-30)).transpose(0, 2, 1, 3)
    up = jnp.pad(u.astype(jnp.float32), ((0, 0), (0, Dp - D)))[:, None, :]
    up = jnp.broadcast_to(up[None], (B, H, 1, Dp))
    s0 = (jnp.zeros((B, H, Dp, Dp), jnp.float32) if state is None else
          jnp.pad(state.astype(jnp.float32),
                  ((0, 0), (0, 0), (0, Dp - D), (0, Dp - D))))

    kernel = functools.partial(_kernel, T=T)
    y, sout = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, T, Dp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, T, Dp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, T, Dp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, T, Dp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Dp), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Dp, Dp), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, Dp), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Dp, Dp), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, Dp), r.dtype),
            jax.ShapeDtypeStruct((B, H, Dp, Dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dp, Dp), jnp.float32)],
        interpret=interpret,
    )(rp, kp, vp, lwp, up, s0)
    y = y.transpose(0, 2, 1, 3)[:, :S, :, :D]
    return y, sout[:, :, :D, :D]
