"""JAX-jitted padded batch simulator sweep (``simulate_batch(backend="jax")``).

A ``jax.jit``-compiled port of the NumPy padded array-sweep
(``repro.core.simulate._simulate_batch_numpy``): one ``lax.while_loop``
advances every job of a padded (V, T*, S*) batch by one synchronous cycle
per iteration, with the mutable state buffers (push-history ring,
pop/push counts, firing counters, II windows) donated to the compiled
computation.  The semantics are a statement-for-statement transcription
of the NumPy engine — the property harness in
``tests/test_simulate_event.py`` asserts bit-identical ``SimResult``s
(cycles, fired, deadlocked, steps) on randomized mixed batches — so the
NumPy backend remains the bit-exact oracle, exactly as the event engine
is the oracle for NumPy.

Compilation caching
-------------------
The sweep's shapes are *bucketed*: V, T*, S* and the ring depth H are
rounded up to the next power of two before tracing, and the extra rows,
columns and ring slots are inert phantom padding (the same masking
discipline ``repro.kernels.padded_batch`` already applies to ragged
groups).  Heterogeneous search rounds whose padded layouts land in the
same bucket therefore reuse one compiled sweep instead of re-tracing per
exact shape; ``firings`` and ``max_cycles`` are traced scalars, so they
never fragment the cache.  ``sweep_cache_stats()`` exposes the
bucket-key hit/compile counters (the BENCH JSON records them under
``sim.jit_cache``).

Incidence is expressed with gathers/scatters instead of the NumPy
backend's per-group matmuls — per-job ``cons``/``prod`` index arrays
(phantom streams pointing at a sentinel task column) make the whole
update shape-generic, which is what lets one compiled sweep cover any
group structure of the same bucket.

Everything runs in int32: the public entry refuses knobs that could
overflow (``fits_int32``), and ``simulate_batch`` degrades such calls to
the NumPy backend with a counted fallback.
"""

from __future__ import annotations

import warnings

try:
    import jax
    import jax.numpy as jnp
    from jax import lax

    HAVE_JAX = True
except Exception:  # pragma: no cover - exercised by the no-jax CI leg
    jax = None
    jnp = None
    lax = None
    HAVE_JAX = False

import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .padded_batch import PaddedBatch

# int32-safety threshold: keeping every knob below 2**30 leaves headroom
# for the sums the sweep forms (t + ii, pushes - pops) inside int32.
_SAFE_MAX = 1 << 30

#: compile-cache bookkeeping, keyed by the bucketed (V, T*, S*, H) shape.
#: jax's own jit cache does the actual reuse; these counters make it
#: observable to tests and the BENCH ``sim.jit_cache`` metadata.
_SEEN_SHAPES: set[tuple[int, int, int, int]] = set()
_CACHE_STATS = _metrics.group(
    "sim.jit_cache", {"compiles": 0, "hits": 0}, on_reset=_SEEN_SHAPES.clear
)


def sweep_cache_stats() -> dict[str, int]:
    """Snapshot of the jitted-sweep compile cache: ``compiles`` counts
    distinct bucketed (V, T*, S*, H) shapes traced, ``hits`` counts calls
    that reused an already-compiled sweep."""
    return dict(_CACHE_STATS)


def reset_sweep_cache_stats() -> None:
    """Zero the compile-cache counters and forget seen shapes (jax's own
    jit cache is untouched — a 're-compile' after this reset is a cache
    hit inside jax, but counts as a compile here)."""
    _CACHE_STATS.reset()


def _bucket(n: int) -> int:
    """Next power of two >= max(n, 1): the shape-bucketing that lets
    heterogeneous rounds share one compiled sweep."""
    return 1 << max(n - 1, 0).bit_length()


def fits_int32(jobs, firings: int, max_cycles: int) -> bool:
    """True when every quantity the sweep computes stays inside int32:
    cycle indices, firing counts, FIFO capacities and latencies."""
    if firings >= _SAFE_MAX or max_cycles >= _SAFE_MAX:
        return False
    for j in jobs:
        for d in (j.latency, j.extra_capacity, j.ii):
            if d and any(abs(int(x)) >= _SAFE_MAX for x in d.values()):
                return False
        if any(int(s.depth) >= _SAFE_MAX for s in j.graph.streams):
            return False
    return True


def _sweep(
    lat,
    cap,
    ii,
    task_active,
    counted,
    cons,
    prod,
    hist,
    pops,
    pushes,
    fired,
    next_free,
    firings,
    max_cycles,
):
    """One padded batch to completion.  All arrays are int32/bool; the
    state buffers (hist..next_free) are donated by the jit wrapper."""
    V, S, H = hist.shape
    T = task_active.shape[1]
    rows = jnp.arange(V, dtype=jnp.int32)[:, None]
    sent = jnp.zeros((V, 1), dtype=jnp.int32)  # sentinel gather column

    def all_done(fired):
        # phantom and detached tasks are vacuously done
        return ((fired >= firings) | ~counted).all(axis=1)

    def cond(state):
        t, active = state[0], state[2]
        return active.any() & (t < max_cycles)

    def body(state):
        t, steps, active, out_cycles, out_dead = state[:5]
        hist, pops, pushes, fired, next_free = state[5:]
        newly = active & all_done(fired)
        out_cycles = jnp.where(newly, t, out_cycles)
        out_dead = jnp.where(newly, False, out_dead)
        active = active & ~newly
        steps = steps + active.any().astype(jnp.int32)

        # firing rule against the state produced by cycles < t
        look = (t - 1 - lat) % H
        vis = jnp.take_along_axis(hist, look[:, :, None], axis=2)[:, :, 0]
        if S:
            tok_ok = vis > pops
            space_ok = (pushes - pops) < cap
            in_bad = jnp.zeros((V, T + 1), jnp.int32).at[rows, cons].add(
                (~tok_ok).astype(jnp.int32)
            )
            out_bad = jnp.zeros((V, T + 1), jnp.int32).at[rows, prod].add(
                (~space_ok).astype(jnp.int32)
            )
            in_ok = in_bad[:, :T] == 0
            out_ok = out_bad[:, :T] == 0
        else:
            in_ok = out_ok = jnp.ones((V, T), dtype=bool)

        can = (
            active[:, None]
            & task_active
            & (fired < firings)
            & (next_free <= t)
            & in_ok
            & out_ok
        )
        can_i = can.astype(jnp.int32)
        fired = fired + can_i
        next_free = jnp.where(can, t + ii, next_free)
        if S:
            can_pad = jnp.concatenate([can_i, sent], axis=1)
            pops = pops + jnp.take_along_axis(can_pad, cons, axis=1)
            pushes = pushes + jnp.take_along_axis(can_pad, prod, axis=1)
            hist = hist.at[:, :, t % H].set(pushes)

        progressed = can.any(axis=1)
        # post-update in-flight check at cycle t (matches the reference
        # engine: vis from the cycle start, pops/pushes post-update)
        if S:
            tok_missing = (pops < pushes) & (vis <= pops)
            tok_flight = tok_missing.any(axis=1)
        else:
            tok_flight = jnp.zeros(V, dtype=bool)
        ii_flight = (next_free > t).any(axis=1)
        quiet = active & ~progressed & ~tok_flight & ~ii_flight
        out_cycles = jnp.where(quiet, t + 1, out_cycles)
        out_dead = jnp.where(quiet, ~all_done(fired), out_dead)
        active = active & ~quiet
        return (
            t + 1,
            steps,
            active,
            out_cycles,
            out_dead,
            hist,
            pops,
            pushes,
            fired,
            next_free,
        )

    init = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.ones(V, dtype=bool),
        jnp.zeros(V, jnp.int32) + max_cycles,
        jnp.zeros(V, dtype=bool),
        hist,
        pops,
        pushes,
        fired,
        next_free,
    )
    state = lax.while_loop(cond, body, init)
    steps, active = state[1], state[2]
    out_cycles, out_dead, fired = state[3], state[4], state[8]
    # jobs still active at the horizon: truncated (or done exactly there)
    out_cycles = jnp.where(active, max_cycles, out_cycles)
    out_dead = jnp.where(active, ~all_done(fired), out_dead)
    return out_cycles, out_dead, fired, steps


if HAVE_JAX:
    _jit_sweep = jax.jit(_sweep, donate_argnums=(7, 8, 9, 10, 11))
else:  # pragma: no cover - exercised by the no-jax CI leg
    _jit_sweep = None


def _pad2(a: np.ndarray, shape: tuple[int, ...], fill) -> np.ndarray:
    out = np.full(shape, fill, dtype=a.dtype)
    out[tuple(slice(0, n) for n in a.shape)] = a
    return out


def simulate_padded_jax(pb: PaddedBatch, *, firings: int, max_cycles: int):
    """Run one canonical padded batch through the jitted sweep.

    Returns ``(cycles, dead, fired, steps)`` as host arrays/ints, sliced
    back to the batch's real (V, T*) shape — feed them to
    ``PaddedBatch.unpack``."""
    if not HAVE_JAX:  # pragma: no cover - callers gate on HAVE_JAX
        raise RuntimeError("repro.kernels.sim_sweep requires jax")
    V = pb.V
    V2, T2 = _bucket(V), _bucket(pb.T)
    S2, H2 = _bucket(pb.S), _bucket(pb.H)
    key = (V2, T2, S2, H2)
    if key in _SEEN_SHAPES:
        _CACHE_STATS["hits"] += 1
        stage = "jit.execute"
    else:
        _SEEN_SHAPES.add(key)
        _CACHE_STATS["compiles"] += 1
        stage = "jit.compile"

    i32 = np.int32
    lat = _pad2(pb.lat.astype(i32), (V2, S2), 0)
    cap = _pad2(pb.cap.astype(i32), (V2, S2), 0)
    ii = _pad2(pb.ii.astype(i32), (V2, T2), 1)
    task_active = _pad2(pb.task_active, (V2, T2), False)
    counted = _pad2(pb.counted, (V2, T2), False)
    # remap the layout's sentinel task column (pb.T) to the bucketed
    # sentinel (T2), then pad the extra stream columns with it too
    cons = np.where(pb.stream_active, pb.cons, T2).astype(i32)
    prod = np.where(pb.stream_active, pb.prod, T2).astype(i32)
    cons = _pad2(cons, (V2, S2), T2)
    prod = _pad2(prod, (V2, S2), T2)

    with _trace.span(stage, shape=str(key), batch=V), warnings.catch_warnings():
        # donation is for accelerator backends; on CPU jax ignores it and
        # warns, which would otherwise spam every sweep
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        out_cycles, out_dead, fired, steps = _jit_sweep(
            jnp.asarray(lat),
            jnp.asarray(cap),
            jnp.asarray(ii),
            jnp.asarray(task_active),
            jnp.asarray(counted),
            jnp.asarray(cons),
            jnp.asarray(prod),
            jnp.zeros((V2, S2, H2), jnp.int32),
            jnp.zeros((V2, S2), jnp.int32),
            jnp.zeros((V2, S2), jnp.int32),
            jnp.zeros((V2, T2), jnp.int32),
            jnp.zeros((V2, T2), jnp.int32),
            jnp.int32(firings),
            jnp.int32(max_cycles),
        )
        # host transfer inside the span: jax dispatch is async, so the
        # sweep's real wall time lands in these asarray calls
        out = (
            np.asarray(out_cycles)[:V],
            np.asarray(out_dead)[:V],
            np.asarray(fired)[:V],
            int(steps),
        )
    return out
