"""Jitted dispatch wrappers over the Pallas kernels and their references.

The model layer calls these; ``impl`` selects the backend:

  * "ref"     — pure-jnp oracle (XLA-compiled; used on CPU and for the
                dry-run lowering, where XLA's fusion already does well)
  * "pallas"  — the TPU Pallas kernel (interpret=True on CPU for tests)

Default comes from ``repro.kernels.DEFAULT_IMPL`` (env ``REPRO_KERNEL_IMPL``).
"""
from __future__ import annotations

import os

import jax

from . import ref as _ref

DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "ref")
_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "auto")


def _interpret() -> bool:
    if _INTERPRET == "auto":
        return jax.default_backend() != "tpu"
    return _INTERPRET == "1"


def attention(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
              q_offset=0, kv_len=None, impl=None):
    impl = impl or DEFAULT_IMPL
    if impl == "pallas" and q.shape[1] > 1:
        from . import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  q_offset=q_offset, kv_len=kv_len,
                                  interpret=_interpret())
    if impl == "pallas":  # single-token decode
        from . import flash_attention as fa
        return fa.decode_attention(q, k, v, softcap=softcap, scale=scale,
                                   q_offset=q_offset, kv_len=kv_len,
                                   window=window, interpret=_interpret())
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale,
                              q_offset=q_offset, kv_len=kv_len)


def mamba2_scan(x, dt, A, B, C, state=None, *, impl=None):
    impl = impl or DEFAULT_IMPL
    if impl == "pallas":
        from . import mamba2_scan as m2
        return m2.mamba2_scan(x, dt, A, B, C, state, interpret=_interpret())
    return _ref.mamba2_scan_ref(x, dt, A, B, C, state)


def rwkv6_scan(r, k, v, w, u, state=None, *, impl=None):
    impl = impl or DEFAULT_IMPL
    if impl == "pallas":
        from . import rwkv6_scan as r6
        return r6.rwkv6_scan(r, k, v, w, u, state, interpret=_interpret())
    return _ref.rwkv6_scan_ref(r, k, v, w, u, state)


def burst_gather(table, idx, *, impl=None):
    impl = impl or DEFAULT_IMPL
    if impl == "pallas":
        from . import burst_gather as bg
        return bg.burst_gather(table, idx, interpret=_interpret())
    return _ref.burst_gather_ref(table, idx)


def moe_gmm(x, w, group_ids, *, impl=None):
    impl = impl or DEFAULT_IMPL
    if impl == "pallas":
        from . import moe_gmm as gmm
        return gmm.moe_gmm(x, w, group_ids, interpret=_interpret())
    return _ref.moe_gmm_ref(x, w, group_ids)
