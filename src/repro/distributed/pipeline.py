"""Floorplanned pipeline runtime: GPipe-style scan-over-ticks via
shard_map + ppermute, with per-boundary buffer depths from the latency
balancer (the TPU realization of "pipeline every cross-slot stream, then
balance", paper §5).

Mechanics
  * refined mesh (stage, data, tp); only "stage" is a manual axis —
    data/tp sharding stays with GSPMD (the TP all-reduces happen *within*
    a slot, the whole point of the floorplan);
  * stage s holds groups [s*Gs, (s+1)*Gs) as locally-scanned params;
  * one microbatch advances one stage per tick; a boundary with buffer
    depth d contributes d skew ticks (deep cross-pod edges overlap their
    DCN transfer with compute — the register analogue);
  * zamba2's x0 skip stream and the (vlm/audio) memory stream travel with
    the activation through every boundary, with depths equalized by the
    balancer (throughput preservation);
  * the last stage computes chunked CE immediately — full logits are
    never shipped backwards;
  * autodiff through ppermute yields the reverse schedule for backward.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.model import lm
from repro.model.layers import PDTYPE
from .sharding import TpuPlan

def _stage_shard_map(f, mesh, in_specs, out_specs):
    """shard_map with "stage" manual, across jax versions: new releases
    expose ``jax.shard_map(axis_names={"stage"})`` (data/tp stay with
    GSPMD).  0.4.x only has ``jax.experimental.shard_map``, whose partial-
    manual mode (``auto=``) lowers axis_index to a PartitionId op the SPMD
    partitioner rejects — so there we make *every* axis manual: the inner
    function sees identical values (stage-local slices, replicated along
    the other axes), trading only the GSPMD overlap along data/tp."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names={"stage"})
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# parameter-name -> which matmul dim shards over tp
_COL = ("wq", "wk", "wv", "w_up", "w_gate", "w_in", "wr", "wg", "w_A",
        "w_shared_in")
_ROW = ("wo", "w_down", "w_out", "w_B", "w_shared_out")


def _leaf_spec(path: tuple[str, ...], leaf, *, tp_axis: str, tp_size: int,
               stage_axis: str | None, group_leaf: bool) -> P:
    """PartitionSpec for one parameter leaf from its tree path.

    Group-stacked leaves carry leading stack dims: (G, ...) in baseline
    layout, (S, Gs, ...) in pipeline layout."""
    name = path[-1]
    if group_leaf:
        pre = (stage_axis, None) if stage_axis else (None,)
    else:
        pre = ()
    nd = leaf.ndim - len(pre)
    if name == "embed":
        return P(*pre, tp_axis, None)
    if name == "lm_head":
        return P(*pre, None, tp_axis)
    if name in ("router",):
        return P(*(pre + (None,) * nd))
    # MoE expert stacks: (E, d, f) — expert-parallel over tp when E
    # divides (the HBM channel-binding analogue: experts bound to the
    # slot's chips); otherwise fall back to sharding the FFN dim
    if name in ("w_up", "w_down", "w_gate") and nd == 3:
        E = leaf.shape[len(pre)]
        if E % max(tp_size, 1) == 0:
            return P(*pre, tp_axis, None, None)
        if name == "w_down":                # (E, f, d): shard f
            return P(*pre, None, tp_axis, None)
        return P(*pre, None, None, tp_axis)  # (E, d, f): shard f
    if name in _COL and nd >= 2:
        return P(*(pre + (None,) * (nd - 1) + (tp_axis,)))
    if name in _ROW and nd >= 2:
        return P(*(pre + (tp_axis,) + (None,) * (nd - 1)))
    return P(*(pre + (None,) * nd))


def param_specs(cfg: ArchConfig, params, *, tp_axis: str = "model",
                tp_size: int = 16, stage_axis: str | None = None):
    """Pytree of PartitionSpecs.  With ``stage_axis`` set, 'groups' leaves
    get a leading (stage, group) stack spec; otherwise a (group,) stack."""
    def walk(tree, path, group_leaf):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,), group_leaf or k == "groups")
                    for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path, group_leaf) for v in tree]
        return _leaf_spec(path, tree, tp_axis=tp_axis, tp_size=tp_size,
                          stage_axis=stage_axis, group_leaf=group_leaf)
    return walk(params, (), False)


def to_pipeline_params(params: dict, n_stages: int) -> dict:
    """Reshape group-stacked leaves (G, ...) -> (S, G/S, ...)."""
    out = dict(params)
    out["groups"] = jax.tree.map(
        lambda t: t.reshape((n_stages, t.shape[0] // n_stages) + t.shape[1:]),
        params["groups"])
    return out


def from_pipeline_params(params: dict) -> dict:
    out = dict(params)
    out["groups"] = jax.tree.map(
        lambda t: t.reshape((t.shape[0] * t.shape[1],) + t.shape[2:]),
        params["groups"])
    return out


# ---------------------------------------------------------------------------

def build_train_loss(cfg: ArchConfig, plan: TpuPlan, rmesh: Mesh, *,
                     n_micro: int, remat: bool = True,
                     unroll: bool = False):
    if os.environ.get("REPRO_PIPE_REMAT") == "0":
        remat = False
    """Returns loss_fn(params_pipeline, batch) running the floorplanned
    pipeline.  batch: {"tokens": (n_micro, mb, S+1), optional "extra"}."""
    S_stages = plan.n_stages
    Gs = plan.groups_per_stage
    specs = lm.build_specs(cfg)
    # cumulative skew offsets from the balanced boundary depths
    depths = plan.boundary_depth or [1] * (S_stages - 1)
    total_skew = int(sum(depths))
    perm = [(i, i + 1) for i in range(S_stages - 1)]

    # per-stage entry offsets (cumulative boundary depths)
    offs = [0]
    for d in depths:
        offs.append(offs[-1] + int(d))

    def loss_fn(params, batch):
        tokens = batch["tokens"]              # (n_micro, mb, S+1)
        extra = batch.get("extra") or {}

        def inner(groups_local, rest_local, tokens, extra):
            extra = extra or None
            stage = jax.lax.axis_index("stage")
            gp = jax.tree.map(lambda t: t[0], groups_local)   # (Gs, ...)
            rest = jax.tree.map(lambda t: t[0], rest_local)
            params_local = dict(rest, groups=gp)
            memory = lm._memory(params_local, cfg, extra)
            shared = params_local.get("shared")
            mb, seqp1 = tokens.shape[1], tokens.shape[2]
            seq = seqp1 - 1
            positions = jnp.arange(seq)

            def stage_compute(x, x0):
                def body(carry, g):
                    x, aux = carry
                    x, a, _ = lm.apply_group(
                        g, cfg, specs, x, positions=positions, x0=x0,
                        memory=memory, shared=shared)
                    return (x, aux + a), None
                if remat:
                    body = jax.checkpoint(body)
                (x, aux), _ = jax.lax.scan(
                    body, (x, jnp.zeros((), jnp.float32)), gp,
                    unroll=Gs if unroll else 1)
                return x, aux

            def tick(t, carry):
                buf_x, buf_x0, loss_acc, aux_acc, count = carry
                midx = jnp.clip(t, 0, n_micro - 1)
                toks = tokens[midx][:, :-1]
                x_in0 = lm._embed(params_local, cfg, toks)
                x = jnp.where(stage == 0, x_in0, buf_x[0])
                x0 = jnp.where(stage == 0, x_in0, buf_x0[0])
                x, aux = stage_compute(x, x0)
                # loss on the last stage, for the microbatch that entered
                # total_skew ticks ago
                out_idx = t - total_skew
                tgt_out = tokens[jnp.clip(out_idx, 0, n_micro - 1)][:, 1:]
                is_out = (stage == S_stages - 1) & (out_idx >= 0) & \
                    (out_idx < n_micro)
                if os.environ.get("REPRO_PIPE_CE", "where") == "cond":
                    # §Perf iteration: gate the (vocab x d) head matmul so
                    # only the last stage pays for it (non-last stages take
                    # the zero branch)
                    ce = jax.lax.cond(
                        is_out,
                        lambda: lm.chunked_ce(params_local, cfg, x, tgt_out),
                        lambda: jnp.zeros((), jnp.float32))
                    loss_acc = loss_acc + ce
                else:
                    ce = lm.chunked_ce(params_local, cfg, x, tgt_out)
                    loss_acc = loss_acc + jnp.where(is_out, ce, 0.0)
                # a stage's compute at tick t belongs to microbatch
                # t - offs[stage]; mask fill/drain garbage
                my_off = jnp.asarray(offs, jnp.int32)[
                    jnp.clip(stage, 0, len(offs) - 1)]
                my_mb = t - my_off
                aux_acc = aux_acc + jnp.where(
                    (my_mb >= 0) & (my_mb < n_micro), aux, 0.0)
                count = count + jnp.where(is_out, 1.0, 0.0)
                # advance the boundary FIFOs (depth-1 modeled as the
                # carry slot itself; deeper boundaries shift through
                # their extra slots = skew ticks)
                send_x = jax.lax.ppermute(x, "stage", perm)
                send_x0 = jax.lax.ppermute(x0, "stage", perm)
                buf_x = jnp.concatenate(
                    [buf_x[1:], jnp.zeros_like(buf_x[:1])], 0)
                buf_x0 = jnp.concatenate(
                    [buf_x0[1:], jnp.zeros_like(buf_x0[:1])], 0)
                my_depth = _my_depth(stage, depths)
                buf_x = _push(buf_x, send_x, my_depth)
                buf_x0 = _push(buf_x0, send_x0, my_depth)
                return buf_x, buf_x0, loss_acc, aux_acc, count

            dmax = max(depths) if depths else 1
            buf_x = jnp.zeros((dmax, mb, seq, cfg.d_model), PDTYPE)
            buf_x0 = jnp.zeros_like(buf_x)
            # accumulators are rank-1, not rank-0: jax 0.4.x's shard_map
            # transpose names dim 0 of every residual, which is ill-formed
            # for scalar residuals (the division keeps the psum'd count as
            # a residual); a (1,) shape sidesteps it at zero cost.
            z = jnp.zeros((1,), jnp.float32)
            carry = (buf_x, buf_x0, z, z, z)
            n_ticks = n_micro + total_skew
            if unroll:
                for t in range(n_ticks):
                    carry = tick(t, carry)
            else:
                carry = jax.lax.fori_loop(0, n_ticks, tick, carry)
            _, _, loss_acc, aux_acc, count = carry
            loss = jax.lax.psum(loss_acc, "stage") / \
                jnp.maximum(jax.lax.psum(count, "stage"), 1.0)
            aux = jax.lax.psum(aux_acc, "stage") / (n_micro * S_stages)
            return (loss + 0.01 * aux)[0]

        rest = {k: v for k, v in params.items() if k != "groups"}
        # Stage-stack the stage-shared params instead of passing them
        # replicated: their cotangent then arrives as a per-stage slice and
        # is summed by the broadcast_to transpose OUTSIDE the shard_map.
        # (Replicated-in params would need a cotangent psum inside the
        # manual region, whose transpose-built reduction computation has a
        # `copy` root that crashes XLA:CPU's all-reduce promotion pass.)
        rest_b = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (S_stages,) + t.shape), rest)
        fn = _stage_shard_map(
            inner, rmesh,
            in_specs=(P("stage"), P("stage"), P(), P()),
            out_specs=P())
        return fn(params["groups"], rest_b, tokens, extra)

    return loss_fn


def _my_depth(stage, depths):
    """Buffer depth of the INCOMING boundary of this stage (stage-1 ->
    stage); stage 0 has none."""
    if not depths:
        return jnp.ones((), jnp.int32)
    arr = jnp.asarray([1] + list(depths), jnp.int32)   # stage 0 unused
    return arr[jnp.clip(stage, 0, len(depths))]


def _push(buf, val, depth):
    """Insert ``val`` at FIFO position depth-1 (arrives after `depth`
    ticks).  buf: (dmax, ...)."""
    dmax = buf.shape[0]
    slot = jnp.clip(depth - 1, 0, dmax - 1)
    onehot = (jnp.arange(dmax) == slot).astype(buf.dtype)
    shape = (dmax,) + (1,) * (buf.ndim - 1)
    return buf + onehot.reshape(shape) * val[None]
