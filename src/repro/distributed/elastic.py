"""Elastic re-meshing and fault tolerance.

Failure path = the paper's own feedback loop reused: when a slot (pod
slice or chip group) is lost, rebuild the slot grid with the surviving
slots and *re-run the floorplanner* — the task graph does not change, only
the device model.  The new plan compiles into new shardings; checkpoint
restore follows the new shardings (ckpt.restore_checkpoint takes target
shardings), so restart-on-smaller-mesh is just plan + restore.

Straggler mitigation: a persistently slow stage bounds throughput in a
synchronous pipeline.  The floorplanner's compute-balance constraint (the
per-slot flops capacity, §4.2's utilization limit) keeps stages even by
construction; at runtime we detect skew from per-stage step-time telemetry
and trigger a re-floorplan with that slot's flops capacity derated —
mitigation by re-placement rather than by asynchrony, keeping the
deterministic schedule (the approach is tested in
tests/test_elastic.py::test_straggler_derate).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.core import InfeasibleError, autobridge
from .sharding import TpuPlan, tpu_slotgrid
from .taskgraph import SHAPES, arch_taskgraph


@dataclasses.dataclass
class ClusterState:
    pods: int
    data: int
    model: int
    #: slots (row, col) currently marked failed
    failed_slots: frozenset = frozenset()
    #: per-slot compute derating (1.0 = healthy), from straggler telemetry
    derate: dict | None = None


def replan(cfg: ArchConfig, cell_name: str, state: ClusterState, *,
           col_slots: int = 4, n_micro: int = 8, seed: int = 0) -> TpuPlan:
    """Re-run the co-optimization against the degraded device model."""
    cell = SHAPES[cell_name]
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    micro_tokens = max(cell.global_batch // n_micro, 1) * \
        (cell.seq_len if cell.kind != "decode" else 1)
    graph = arch_taskgraph(cfg, cell, micro_tokens=micro_tokens)
    grid = tpu_slotgrid(state.pods, state.data, state.model,
                        col_slots=col_slots)
    # failed slots lose all capacity; stragglers lose flops headroom
    for slot in state.failed_slots:
        grid.slot_caps.setdefault(slot, {}).update(
            {k: 0.0 for k in grid.base_capacity})
    total_flops = sum(t.area.get("flops", 0.0) for t in graph.tasks.values())
    n_ok = state.pods * col_slots - len(state.failed_slots)
    if n_ok <= 0:
        raise InfeasibleError("no surviving slots")
    grid.base_capacity["flops"] = total_flops / n_ok / 0.72
    for slot, frac in (state.derate or {}).items():
        caps = grid.slot_caps.setdefault(slot, {})
        caps["flops"] = grid.base_capacity["flops"] * frac

    plan = None
    err = None
    for util in (0.9, 0.95, 1.0):
        try:
            plan = autobridge(graph, grid, max_util=util, seed=seed,
                              n_starts=6)
            break
        except InfeasibleError as e:
            err = e
            grid.base_capacity["flops"] *= 1.4
    if plan is None:
        raise err
    order = []
    for i in range(n_groups):
        slot = plan.floorplan.placement[f"group{i}"]
        if not order or order[-1] != slot:
            order.append(slot)
    n_stages = len(order)
    while n_groups % n_stages:
        n_stages -= 1
    order = order[:n_stages]
    depths = [max(grid.crossing_depth(order[i], order[i + 1]), 1)
              for i in range(n_stages - 1)]
    return TpuPlan(mode="tapa", n_stages=n_stages,
                   groups_per_stage=n_groups // n_stages, stage_slots=order,
                   boundary_depth=depths,
                   tp=state.model // col_slots,
                   crossing_cost=plan.floorplan.cost,
                   plan_summary=plan.summary())
