"""Distributed-optimization tricks: int8 error-feedback gradient
compression for the DP all-reduce, and compute/comm overlap notes.

Compression: before the data-parallel gradient reduction, quantize each
leaf to int8 with a per-leaf scale; the quantization residual is carried
in an error-feedback buffer and added back next step (Karimireddy et al.,
the standard trick that keeps SGD/Adam convergence).  On the wire this
cuts the DP all-reduce bytes 4x vs f32 / 2x vs bf16 — directly shrinking
the collective roofline term of DP-bound steps.

Overlap: XLA already overlaps the (async) all-reduce with the backward
compute when the reduction is emitted per-layer (scan-over-groups does
this naturally — one gradient segment per group finishes early).  The
latency-hiding an FPGA gets from registering a long wire, a TPU gets from
double-buffered collectives: same TAPA story, different substrate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_buf):
    """Quantize grads+residual to int8; returns (q_tree, scales, new_err)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return (q, s), gf - deq
    qs = jax.tree.map(one, grads, error_buf)
    q_tree = jax.tree.map(lambda t: t[0][0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[0][1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    e_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return q_tree, s_tree, e_tree


def decompress_grads(q_tree, s_tree):
    return jax.tree.map(dequantize_int8, q_tree, s_tree)


def init_error_buf(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
