"""Baseline GSPMD execution (the "default tool flow" of the paper's
comparison): no floorplan — every layer sharded over the FULL model axis,
data parallelism over (pod, data) with ZeRO-1 optimizer sharding.

This is the TPU analogue of Vivado packing all logic together: local
latency is minimal but every layer's TP collectives span the whole model
axis (and, multi-pod, would span DCN if the model axis crossed pods).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.model import lm
from .pipeline import param_specs

DATA_AXES = ("pod", "data")


def data_axes(mesh: Mesh):
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def make_shardings(cfg: ArchConfig, params, mesh: Mesh):
    specs = param_specs(cfg, params, tp_axis="model")
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(mesh: Mesh):
    return NamedSharding(mesh, P(data_axes(mesh)))


def build_loss(cfg: ArchConfig, *, remat: bool = True,
               unroll: bool = False):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        x_tokens, targets = tokens[:, :-1], tokens[:, 1:]
        # full forward without materializing logits: reuse group scan then
        # chunked CE
        specs = lm.build_specs(cfg)
        x = lm._embed(params, cfg, x_tokens)
        positions = jnp.arange(x_tokens.shape[1])
        memory = lm._memory(params, cfg, batch.get("extra"))
        shared = params.get("shared")
        x0 = x

        def group_fn(carry, gp):
            x, aux = carry
            x, a, _ = lm.apply_group(gp, cfg, specs, x, positions=positions,
                                     x0=x0, memory=memory, shared=shared)
            return (x, aux + a), None

        body = jax.checkpoint(group_fn) if remat else group_fn
        n_groups = cfg.n_layers // len(cfg.layer_pattern)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["groups"],
                                   unroll=n_groups if unroll else 1)
        ce = lm.chunked_ce(params, cfg, x, targets)
        return ce + 0.01 * aux

    return loss_fn


def build_serve_step(cfg: ArchConfig):
    """One serving step: prefill (S > 1) or decode (S = 1)."""
    def serve_step(params, cache, tokens):
        return lm.step(params, cfg, cache, tokens)
    return serve_step


def cache_shardings(cfg: ArchConfig, cache, mesh: Mesh):
    """KV caches: batch over (pod, data); heads over model when the KV-head
    count divides, otherwise the cache LENGTH is sharded over model
    (context parallelism — each chip holds a context slice); SSM states:
    batch over data axes, heads over model."""
    daxes = data_axes(mesh)
    tp = mesh.shape["model"]

    def cut(spec, nd):
        return P(*(tuple(spec)[:nd] + (None,) * max(0, nd - len(spec))))

    def axsize(entry):
        if entry is None:
            return 1
        names = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in names:
            n *= mesh.shape[a]
        return n

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        name = path[-1] if path else ""
        if name in ("k", "v"):            # (G, B, W, Hkv, D)
            prefer = os.environ.get("REPRO_KV_SHARD", "heads")
            if prefer == "context" and leaf.shape[2] % tp == 0:
                return cut(P(None, daxes, "model", None, None), leaf.ndim)
            if leaf.shape[3] % tp == 0:
                return cut(P(None, daxes, None, "model", None), leaf.ndim)
            if leaf.shape[2] % tp == 0:   # context parallelism fallback
                return cut(P(None, daxes, "model", None, None), leaf.ndim)
            return cut(P(None, daxes, None, None, None), leaf.ndim)
        if name in ("ssd", "wkv"):        # (G, B, H, P, N) / (G, B, H, D, D)
            if leaf.shape[2] % tp == 0:
                return cut(P(None, daxes, "model", None, None), leaf.ndim)
            return cut(P(None, daxes, None, None, None), leaf.ndim)
        if name == "conv":                # (G, B, K-1, C)
            if leaf.shape[3] % tp == 0:
                return cut(P(None, daxes, None, "model"), leaf.ndim)
            return cut(P(None, daxes, None, None), leaf.ndim)
        if name in ("tm_shift", "cm_shift"):
            return cut(P(None, daxes, None, None), leaf.ndim)
        if name == "memory":
            return cut(P(daxes), leaf.ndim)
        return P(*([None] * leaf.ndim))

    def fit(sp, leaf):
        """Drop spec entries that do not divide the dim (e.g. batch=1)."""
        parts = list(tuple(sp)) + [None] * (leaf.ndim - len(tuple(sp)))
        parts = [None if (p is not None and
                          leaf.shape[i] % axsize(p) != 0) else p
                 for i, p in enumerate(parts)]
        return P(*parts)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path) for v in tree]
        if tree is None:
            return None
        return NamedSharding(mesh, fit(spec(path, tree), tree))

    return walk(cache, ())
