"""Floorplan -> TPU execution plan.

The production mesh is viewed as a TAPA slot grid (DESIGN.md §2):
rows = pods (DCN boundaries, expensive), cols = model-axis subgroups (ICI
boundaries).  The same autobridge co-optimization that floorplans FPGA
designs assigns layer-group tasks to slots; the result compiles into

  * a *refined mesh* (stage, data, tp) whose device order follows the
    floorplan (stage i occupies slot pi(i), so cross-stage ppermutes cross
    a pod boundary exactly where the floorplan says), and
  * per-stage-boundary buffer depths (pipelining + latency balancing) that
    become skew slots in the pipeline schedule.

Baseline plan (= the "default Vivado flow"): no floorplan — every layer
sharded over the full model axis (max-TP "packed" GSPMD) with ZeRO-1 DP.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core import Boundary, InfeasibleError, SlotGrid, autobridge
from .taskgraph import SHAPES, ShapeCell, arch_taskgraph

HBM_PER_CHIP = 16e9          # v5e
DCN_WEIGHT = 4.0             # pod-boundary crossing cost vs 1 ICI hop


def tpu_slotgrid(pods: int, data: int, model: int, *, col_slots: int = 4,
                 max_util: float = 0.9) -> SlotGrid:
    """Slot grid over the mesh: (pods) x (col_slots) slots, each owning
    data * (model/col_slots) chips."""
    chips_per_slot = data * (model // col_slots)
    cap = {
        "hbm_bytes": chips_per_slot * HBM_PER_CHIP,
        "flops": float("inf"),      # replaced per-graph (balance knob)
        "io_channels": 4.0,
    }
    return SlotGrid(
        f"tpu_{pods}x{data}x{model}", rows=pods, cols=col_slots,
        base_capacity=cap,
        row_boundaries=[Boundary(weight=DCN_WEIGHT, pipeline_depth=2,
                                 delay_ns=0.0) for _ in range(pods - 1)],
        col_boundaries=[Boundary(weight=1.0, pipeline_depth=1, delay_ns=0.0)
                        for _ in range(col_slots - 1)],
        max_util=max_util)


@dataclasses.dataclass
class TpuPlan:
    mode: str                          # "tapa" | "baseline"
    n_stages: int
    groups_per_stage: int
    #: slot (row, col) occupied by each stage, in chain order
    stage_slots: list[tuple[int, int]]
    #: skew (buffer depth) of each stage boundary, len n_stages-1
    boundary_depth: list[int]
    tp: int                            # chips on the model axis per stage
    crossing_cost: float
    plan_summary: dict | None = None


def plan_arch(cfg: ArchConfig, cell: ShapeCell, *, pods: int, data: int,
              model: int, col_slots: int = 4, n_micro: int = 8,
              seed: int = 0) -> TpuPlan:
    """Run the TAPA co-optimization for (arch x shape x mesh)."""
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    micro_tokens = max(cell.global_batch // n_micro, 1) * \
        (cell.seq_len if cell.kind != "decode" else 1)
    graph = arch_taskgraph(cfg, cell, micro_tokens=micro_tokens)
    grid = tpu_slotgrid(pods, data, model, col_slots=col_slots)
    # compute-balance knob: per-slot flops capacity (paper's max_util)
    total_flops = sum(t.area.get("flops", 0.0)
                      for t in graph.tasks.values())
    n_slots = pods * col_slots
    grid.base_capacity["flops"] = total_flops / n_slots / 0.72

    plan = None
    for util in (0.9, 0.95, 1.0):
        try:
            plan = autobridge(graph, grid, max_util=util, seed=seed,
                              n_starts=6)
            break
        except InfeasibleError:
            # loosen compute balance before giving up
            grid.base_capacity["flops"] *= 1.5
    if plan is None:
        plan = autobridge(graph, grid, max_util=1.0, seed=seed, n_starts=6)

    # stages = slots visited by the chain, in group order
    order: list[tuple[int, int]] = []
    for i in range(n_groups):
        slot = plan.floorplan.placement[f"group{i}"]
        if not order or order[-1] != slot:
            order.append(slot)
    # regularize to uniform stage sizes (stacked-scan pipeline needs it)
    n_stages = len(order)
    while n_groups % n_stages:
        n_stages -= 1
    order = order[:n_stages]
    depths = []
    for i in range(n_stages - 1):
        a, b = order[i], order[i + 1]
        d = grid.crossing_depth(a, b)
        depths.append(max(d, 1))
    return TpuPlan(mode="tapa", n_stages=n_stages,
                   groups_per_stage=n_groups // n_stages,
                   stage_slots=order, boundary_depth=depths,
                   tp=model // col_slots, crossing_cost=plan.floorplan.cost,
                   plan_summary=plan.summary())


def baseline_plan(cfg: ArchConfig, *, pods: int, data: int,
                  model: int) -> TpuPlan:
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    return TpuPlan(mode="baseline", n_stages=1, groups_per_stage=n_groups,
                   stage_slots=[(0, 0)], boundary_depth=[], tp=model,
                   crossing_cost=0.0)


def refined_mesh(mesh: Mesh, plan: TpuPlan, *, col_slots: int = 4) -> Mesh:
    """Reshape the production mesh's devices into (stage, data, tp)
    following the floorplan's slot order.  For the baseline plan the mesh
    is returned with axes (data, model) merged appropriately."""
    devs = mesh.devices
    if devs.ndim == 2:                         # (data, model)
        pods, data, model = 1, devs.shape[0], devs.shape[1]
        devs = devs[None]
    else:                                      # (pod, data, model)
        pods, data, model = devs.shape
    if plan.mode == "baseline":
        return Mesh(devs.reshape(pods * data, model), ("data", "model"))
    if plan.tp:
        col_slots = max(model // plan.tp, 1)
    tp = model // col_slots
    # slot (r, c) -> devices (data, tp)
    slot_devs = {(r, c): devs[r, :, c * tp:(c + 1) * tp]
                 for r in range(pods) for c in range(col_slots)}
    used = list(plan.stage_slots)
    # unused slots are appended to the data axis of their column's stage?
    # No — every stage must own disjoint devices, and all devices must be
    # used.  Unused slots join the nearest used stage, widening its tp.
    # For uniformity we instead require the plan to use all slots or fold
    # unused slots into extra data-parallel replicas of existing stages.
    stage_arrays = [slot_devs[s] for s in used]
    free = [s for s in slot_devs if s not in used]
    # distribute free slots round-robin as extra data-parallel rows
    for i, s in enumerate(free):
        tgt = i % len(stage_arrays)
        stage_arrays[tgt] = np.concatenate(
            [stage_arrays[tgt], slot_devs[s]], axis=0)
    if len({a.shape for a in stage_arrays}) != 1:
        # fall back to uniform slabs in stage order (keeps lowering valid;
        # placement cost already captured in the roofline model)
        n = plan.n_stages
        flat = devs.reshape(-1)
        per = flat.size // n
        stage_arrays = [flat[i * per:(i + 1) * per].reshape(data, -1)
                        for i in range(n)]
    devarr = np.stack(stage_arrays)            # (S, data', tp')
    return Mesh(devarr, ("stage", "data", "tp"))


def plan_cell(cfg: ArchConfig, cell_name: str, mesh_shape: tuple[int, ...],
              *, seed: int = 0, mode: str = "tapa") -> TpuPlan:
    cell = SHAPES[cell_name]
    if len(mesh_shape) == 2:
        pods, (data, model) = 1, mesh_shape
    else:
        pods, data, model = mesh_shape
    if mode == "baseline":
        return baseline_plan(cfg, pods=pods, data=data, model=model)
    return plan_arch(cfg, cell, pods=pods, data=data, model=model, seed=seed)
