"""Distributed runtime: floorplan-driven sharding + pipeline execution."""
