"""Architecture -> TAPA task graph (the TPU side of the paper's front-end).

A model is a task-parallel dataflow program: layer groups are tasks
communicating through activation streams; zamba2's shared attention block
and arctic's dense-residual-beside-MoE create the reconvergent paths the
latency balancer exists for; embedding/data-in and loss/readout tasks pin
to the ingest/egress ends of the mesh like HBM IO modules.

Resource model (per task):
  hbm_bytes — parameters + optimizer state (AdamW 10 B/param, Adafactor
              2.6 B/param) + activation working set per microbatch
  flops     — 6 * active params (per-token compute proxy; keeps stages
              compute-balanced, the paper's per-slot utilization limit)
Stream widths are activation bytes per microbatch crossing between groups.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.core import Stream, Task, TaskGraph

OPT_BYTES = {"adamw": 10.0, "adafactor": 2.6}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def group_param_bytes(cfg: ArchConfig) -> tuple[float, float]:
    """(total_bytes, active_bytes) of ONE layer-group's params (bf16)."""
    per_layer_total = (cfg.param_count() - cfg.vocab * cfg.d_model *
                       (1 if cfg.tie_embeddings else 2)) / cfg.n_layers
    per_layer_active = (cfg.active_param_count() - cfg.vocab * cfg.d_model *
                        (1 if cfg.tie_embeddings else 2)) / cfg.n_layers
    g = len(cfg.layer_pattern)
    return per_layer_total * g * 2.0, per_layer_active * g * 2.0


def arch_taskgraph(cfg: ArchConfig, cell: ShapeCell, *,
                   micro_tokens: int) -> TaskGraph:
    """Build the flattened task graph: data_in -> embed -> group_0 ... ->
    head -> loss_out, plus skip/side streams per family."""
    g = TaskGraph(f"{cfg.name}:{cell.name}")
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    act_w = float(micro_tokens * cfg.d_model * 2)     # bytes per microbatch
    opt_mult = OPT_BYTES[cfg.optimizer] / 2.0 if cell.kind == "train" else 1.0

    emb_bytes = cfg.vocab * cfg.d_model * 2.0 * opt_mult
    gp_total, gp_active = group_param_bytes(cfg)
    act_bytes = micro_tokens * cfg.d_model * 2.0 * len(cfg.layer_pattern) \
        * (4 if cell.kind == "train" else 1)

    g.add_task(Task("data_in", area={"io_channels": 1.0}))
    g.add_task(Task("embed", area={"hbm_bytes": emb_bytes,
                                   "flops": 0.0}))
    for i in range(n_groups):
        g.add_task(Task(f"group{i}", area={
            "hbm_bytes": gp_total * opt_mult + act_bytes,
            "flops": 6.0 * gp_active / 2.0,
        }))
    g.add_task(Task("head", area={
        "hbm_bytes": 0.0 if cfg.tie_embeddings else emb_bytes,
        "flops": 2.0 * cfg.vocab * cfg.d_model}))
    g.add_task(Task("loss_out", area={"io_channels": 1.0}))

    g.add_stream(Stream("tokens", "data_in", "embed", width=micro_tokens * 4))
    prev = "embed"
    for i in range(n_groups):
        g.add_stream(Stream(f"act{i}", prev, f"group{i}", width=act_w))
        prev = f"group{i}"
    g.add_stream(Stream(f"act{n_groups}", prev, "head", width=act_w))
    g.add_stream(Stream("loss", "head", "loss_out", width=4))

    # family-specific side streams (reconvergent paths)
    if "H" in cfg.layer_pattern:
        # zamba2: embeddings broadcast into every H group (skip stream)
        for i in range(n_groups):
            g.add_stream(Stream(f"x0_{i}", "embed", f"group{i}",
                                width=act_w))
    if cfg.family in ("vlm", "audio"):
        g.add_task(Task("frontend", area={
            "hbm_bytes": cfg.frontend_dim * cfg.d_model * 2.0 * opt_mult,
            "io_channels": 1.0}))
        # memory feeds every cross-attention group
        for i in range(n_groups):
            if "X" in cfg.layer_pattern:
                g.add_stream(Stream(
                    f"mem_{i}", "frontend", f"group{i}",
                    width=float(cfg.frontend_tokens * cfg.d_model * 2)))
    return g
