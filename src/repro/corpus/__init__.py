"""Generated design corpus: seeded parametric task graphs + the
differential fuzz harness over the full search stack.

The paper's evidence is 43 hand-written designs; the corpus closes the
scenario-diversity gap (ROADMAP) with *families* of generated graphs —
layered DAGs with reconvergence, control-closed cycles, SDF-rate
annotated streams, wide crossbar-ish fan-outs, and HBM-bound IO designs
whose channel demands exercise the ``hbm_splits`` search axis — each
design a deterministic function of ``(family, seed)`` with a sha256
content fingerprint.  ``run_differential`` pushes a corpus through
analysis -> autobridge -> all simulator backends -> parallel search and
cross-checks every stage against an independent oracle (see
``docs/corpus-guide.md`` for the full oracle table).

>>> from repro.corpus import FAMILIES, generate_design, sample_corpus
>>> d = generate_design(7, FAMILIES["dag"])
>>> d.name, len(d.fingerprint)
('dag-00007', 16)
>>> d.fingerprint == generate_design(7, FAMILIES["dag"]).fingerprint
True
>>> batch = sample_corpus("hbm", 4, seed=100)
>>> [b.seed for b in batch]
[100, 101, 102, 103]
>>> any("hbm_channels" in t.area for t in batch[0].graph.tasks.values())
True

Fingerprints track content, not seeds — different seeds, different
graphs:

>>> generate_design(1, FAMILIES["sdf"]).fingerprint != d.fingerprint
True

The fuzz family (and only it) generates broken graphs on purpose; the
differential harness cross-checks the static verdicts against the event
engine on exactly those:

>>> from repro.corpus import run_differential
>>> rep = run_differential(sample_corpus("fuzz", 6), floorplan_limit=0)
>>> rep.ok, rep.verdicts_checked, rep.sims_checked
(True, 6, 6)
"""
from .spec import CLEAN_FAMILIES, FAMILIES, CorpusSpec
from .generator import (CorpusDesign, generate_design, generate_graph,
                        graph_fingerprint, random_graph, sample_corpus)
from .differential import DifferentialReport, run_differential

__all__ = [
    "CLEAN_FAMILIES", "FAMILIES", "CorpusSpec", "CorpusDesign",
    "generate_design", "generate_graph", "graph_fingerprint",
    "random_graph", "sample_corpus", "DifferentialReport",
    "run_differential",
]
