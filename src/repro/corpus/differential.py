"""Differential fuzz driver: every generated design through the full
pipeline, every stage cross-checked against an independent oracle.

The oracle table (``docs/corpus-guide.md`` renders the same table):

=====================  ===========================  ======================
stage                  oracle                       checked property
=====================  ===========================  ======================
``analysis.analyze``   event-engine simulation      deadlock verdict exact
                                                    (both directions),
                                                    ``min_cycles`` and
                                                    firing bounds hold
``simulate_batch``     per-job event engine         numpy padded batch ==
                                                    event on (cycles,
                                                    fired, deadlocked)
jax backend            numpy padded batch           bit-identical incl.
                                                    ``steps``
``autobridge``         static pre-flight + solver   feasible designs plan,
                                                    broken designs raise
                                                    ``InfeasibleError``
                                                    (both paths taken)
search (``jobs=N``)    the sequential ``jobs=1``    frontier bit-identical
                       run
search (surrogate)     the uniform proposer         converges in <= rounds
                                                    at >= hypervolume
=====================  ===========================  ======================

``run_differential`` executes the table over a design list and returns a
``DifferentialReport`` whose counters the bench suite serializes into
``BENCH_corpus.json``; any mismatch is a recorded string, and ``ok`` is
the corpus gate's pass/fail bit.
"""
from __future__ import annotations

import dataclasses

from repro.core import InfeasibleError, simulate, simulate_batch
from repro.core.autobridge import FloorplanCache, autobridge
from repro.core.devicegrid import SlotGrid
from repro.core.simulate import _jax_ready
from repro.analysis import analyze
from repro.search.engine import explore_design_space, search_until_converged
from repro.search.pareto import objective_vector
from repro.search.space import SearchSpace

from .generator import CorpusDesign

#: event-engine budget per design (generated waves are small; a run that
#: needs more cycles than this is a bug, not a slow design)
_MAX_CYCLES = 500_000


def _default_grid() -> SlotGrid:
    from repro.fpga import u280_grid
    return u280_grid()


@dataclasses.dataclass
class DifferentialReport:
    """Counters + mismatch strings of one differential run."""
    designs: int = 0
    families: dict[str, int] = dataclasses.field(default_factory=dict)
    #: stage 1 — analysis verdicts vs the event engine
    verdicts_checked: int = 0
    #: stage 2 — padded numpy batch vs per-job event
    sims_checked: int = 0
    #: stage 2b — jax vs numpy (0 when jax is unavailable)
    jax_checked: int = 0
    #: stage 3 — autobridge outcomes
    feasible: int = 0
    infeasible: int = 0
    #: stage 4 — parallel-search frontier identity
    searches_checked: int = 0
    #: stage 5 — surrogate-vs-uniform convergence
    surrogate_checked: int = 0
    mismatches: list[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.mismatches

    def counters(self) -> dict:
        """JSON-able summary (what ``BENCH_corpus.json`` embeds)."""
        out = dataclasses.asdict(self)
        out["ok"] = self.ok
        return out

    def _flag(self, design: CorpusDesign, stage: str, msg: str) -> None:
        self.mismatches.append(
            f"[{stage}] {design.name} fp={design.fingerprint}: {msg}")


def _check_verdicts(designs, rep: DifferentialReport) -> list:
    """Stage 1: exact analysis verdicts per design, at the design's own
    wave size.  Returns each design's event result for reuse."""
    results = []
    for d in designs:
        a = analyze(d.graph, latency=d.latency,
                    extra_capacity=d.extra_capacity, ii=d.ii,
                    firings=d.firings)
        ev = simulate(d.graph, engine="event", firings=d.firings,
                      latency=d.latency, extra_capacity=d.extra_capacity,
                      ii=d.ii, max_cycles=_MAX_CYCLES)
        rep.verdicts_checked += 1
        if a.deadlock != ev.deadlocked:
            rep._flag(d, "analysis",
                      f"static deadlock={a.deadlock} vs engine "
                      f"{ev.deadlocked} ({[str(x) for x in a.diagnostics]})")
        if not ev.deadlocked and a.min_cycles is not None \
                and ev.cycles < a.min_cycles:
            rep._flag(d, "analysis",
                      f"engine ran {ev.cycles} cycles under static bound "
                      f"{a.min_cycles}")
        for n, bound in a.max_firings.items():
            if bound is not None and ev.fired[n] > bound:
                rep._flag(d, "analysis",
                          f"task {n} fired {ev.fired[n]} > bound {bound}")
        results.append(ev)
    return results


def _check_backends(designs, rep: DifferentialReport, *,
                    firings: int) -> None:
    """Stage 2: one padded numpy sweep over ALL designs vs per-job event,
    plus (when available) the jitted jax sweep vs numpy, bit-identical."""
    jobs = [d.sim_job() for d in designs]
    np_res = simulate_batch(jobs, firings=firings, backend="numpy")
    ev_res = simulate_batch(jobs, firings=firings, backend="event")
    for d, a, b in zip(designs, np_res, ev_res):
        rep.sims_checked += 1
        if (a.cycles, a.fired, a.deadlocked) != \
                (b.cycles, b.fired, b.deadlocked):
            rep._flag(d, "sim", f"numpy {a.cycles}/{a.deadlocked} vs "
                                f"event {b.cycles}/{b.deadlocked}")
    if _jax_ready():
        jx_res = simulate_batch(jobs, firings=firings, backend="jax")
        for d, a, b in zip(designs, jx_res, np_res):
            rep.jax_checked += 1
            if (a.cycles, a.fired, a.deadlocked, a.steps) != \
                    (b.cycles, b.fired, b.deadlocked, b.steps):
                rep._flag(d, "jax", f"jax {a.cycles}/{a.deadlocked}/"
                                    f"{a.steps} vs numpy {b.cycles}/"
                                    f"{b.deadlocked}/{b.steps}")


def _check_floorplans(designs, rep: DifferentialReport, *,
                      grid: SlotGrid, limit: int) -> None:
    """Stage 3: autobridge with the static pre-flight on.  Clean designs
    must produce a plan; broken ones must raise — never crash.  The
    budget is spent round-robin across families so both the feasible and
    the infeasible (fuzz: zero-capacity FIFOs, data cycles) paths run."""
    by_family: dict[str, list] = {}
    for d in designs:
        by_family.setdefault(d.family, []).append(d)
    picked: list = []
    rank = 0
    while len(picked) < min(limit, len(designs)):
        layer = [ds[rank] for ds in by_family.values() if rank < len(ds)]
        if not layer:
            break
        picked.extend(layer)
        rank += 1
    cache = FloorplanCache()
    for d in picked[:limit]:
        try:
            plan = autobridge(d.graph, grid, check=True, cache=cache)
        except InfeasibleError:
            rep.infeasible += 1
            continue
        rep.feasible += 1
        if plan.floorplan is None:
            rep._flag(d, "autobridge", "feasible but no floorplan")


def _check_search_identity(designs, rep: DifferentialReport, *,
                           grid: SlotGrid, jobs: int) -> None:
    """Stage 4: parallel explore == sequential explore, frontier
    bit-identical (points and objective vectors)."""
    space = SearchSpace(seeds=(0,), utils=(0.6, 0.8), depth_scales=(1.0, 2.0))
    for d in designs:
        seq = explore_design_space(d.graph, grid, space=space,
                                   sim_firings=d.firings, jobs=1)
        par = explore_design_space(d.graph, grid, space=space,
                                   sim_firings=d.firings, jobs=jobs)
        rep.searches_checked += 1
        fp_seq = [(dataclasses.astuple(c.point), objective_vector(c))
                  for c in seq.frontier]
        fp_par = [(dataclasses.astuple(c.point), objective_vector(c))
                  for c in par.frontier]
        if fp_seq != fp_par:
            rep._flag(d, "search",
                      f"jobs={jobs} frontier differs from jobs=1: "
                      f"{fp_par} vs {fp_seq}")


def _check_surrogate(design, rep: DifferentialReport, *,
                     grid: SlotGrid) -> None:
    """Stage 5: the surrogate proposer must not converge slower or lower
    than the uniform one on the same budget."""
    kw = dict(space=SearchSpace(utils=(0.55, 0.65, 0.75, 0.85)),
              rounds=3, points_per_round=8, sim_firings=design.firings)
    uni = search_until_converged(design.graph, grid, **kw)
    sur = search_until_converged(design.graph, grid, proposer="surrogate",
                                 **kw)
    rep.surrogate_checked += 1
    if sur.rounds_run > uni.rounds_run:
        rep._flag(design, "surrogate",
                  f"{sur.rounds_run} rounds > uniform {uni.rounds_run}")
    hv_uni = uni.hypervolumes[-1] if uni.hypervolumes else 0.0
    hv_sur = sur.hypervolumes[-1] if sur.hypervolumes else 0.0
    if hv_sur < hv_uni - 1e-9:
        rep._flag(design, "surrogate",
                  f"hypervolume {hv_sur} < uniform {hv_uni}")


def run_differential(designs: list[CorpusDesign], *,
                     grid: SlotGrid | None = None,
                     sim_firings: int = 25,
                     floorplan_limit: int = 24,
                     search_designs: int = 0,
                     search_jobs: int = 2,
                     check_surrogate: bool = False) -> DifferentialReport:
    """The full differential table over ``designs``.

    Stages 1-2 (analysis verdicts, backend equivalence) run over every
    design; stage 3 (autobridge) over the first ``floorplan_limit``;
    stage 4 (parallel-search identity) over the first ``search_designs``
    *feasible-family* designs (those with non-empty areas); stage 5
    (surrogate convergence) over the first such design when
    ``check_surrogate`` is set.  ILP-heavy stages are opt-in by budget so
    tier-1 tests stay fast while the bench suite runs the whole table.
    """
    grid = grid or _default_grid()
    rep = DifferentialReport(designs=len(designs))
    for d in designs:
        rep.families[d.family] = rep.families.get(d.family, 0) + 1

    _check_verdicts(designs, rep)
    _check_backends(designs, rep, firings=sim_firings)
    _check_floorplans(designs, rep, grid=grid, limit=floorplan_limit)

    searchable = [d for d in designs
                  if all(t.area for t in d.graph.tasks.values())]
    if search_designs:
        _check_search_identity(searchable[:search_designs], rep,
                               grid=grid, jobs=search_jobs)
    if check_surrogate and searchable:
        _check_surrogate(searchable[0], rep, grid=grid)
    return rep
