"""Seeded task-graph generator and corpus sampling.

One design = one ``(family, seed)`` pair: ``generate_design`` derives a
private ``random.Random(f"corpus:{family}:{seed}")`` (string seeding is
stable across processes and Python hash randomization), draws a graph
from the family's ``CorpusSpec`` distributions plus the per-design
simulation knobs (latency / extra capacity / II / wave size), and stamps
the result with a content fingerprint — a sha256 digest over the graph's
canonical serialization, so any change to tasks, streams, widths, depths,
or ``meta`` annotations shows up as a new identity in bench reports and
cache keys.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import random

from repro.core import SimJob
from repro.core.graph import Stream, Task, TaskGraph

from .spec import FAMILIES, CorpusSpec


def graph_fingerprint(graph: TaskGraph) -> str:
    """Stable 16-hex-digit content identity of a task graph.

    sha256 over the canonical JSON serialization of every task (name,
    sorted area vector, kind, detached, pin, sorted meta) and every stream
    (name, endpoints, width, depth, control, sorted meta) — independent of
    Python hash randomization and of construction order for tasks (streams
    are order-significant: the list is part of the graph's identity).
    """
    payload = {
        "name": graph.name,
        "tasks": sorted(
            [t.name, sorted(t.area.items()), t.kind, t.detached,
             list(t.pinned) if t.pinned else None, sorted(t.meta.items())]
            for t in graph.tasks.values()),
        "streams": [
            [s.name, s.src, s.dst, s.width, s.depth, s.control,
             sorted(s.meta.items())]
            for s in graph.streams],
    }
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()
    return digest[:16]


@dataclasses.dataclass
class CorpusDesign:
    """One generated design: the graph plus its simulation knobs."""
    graph: TaskGraph
    family: str
    seed: int
    fingerprint: str
    latency: dict[str, int]
    extra_capacity: dict[str, int]
    ii: dict[str, int]
    firings: int

    @property
    def name(self) -> str:
        return f"{self.family}-{self.seed:05d}"

    def sim_job(self) -> SimJob:
        return SimJob(self.graph, latency=dict(self.latency),
                      extra_capacity=dict(self.extra_capacity),
                      ii=dict(self.ii))


def generate_graph(rng: random.Random, spec: CorpusSpec) -> TaskGraph:
    """One task graph drawn from ``spec``'s distributions.

    Layered construction: every layer-N task draws a uniform fan-in from
    layer N-1 (reconvergence), plus the spec's skip / feedback edges and
    appended HBM-bound IO tasks.  Streams are added with
    ``validate=False`` so specs whose ``depth_choices`` include 0 can
    generate the zero-capacity FIFOs the broken-graph tests need (clean
    families keep depths >= 1 and stay free of structure errors).
    """
    g = TaskGraph(f"{spec.family}")
    layers: list[list[str]] = []
    nid = 0
    for li in range(rng.randint(*spec.layers)):
        layer = []
        for _ in range(rng.randint(*spec.tasks_per_layer)):
            name = f"t{nid}"
            nid += 1
            area: dict[str, float] = {}
            if spec.lut_range[1] > 0:
                area["LUT"] = float(rng.randint(*spec.lut_range))
            g.add_task(Task(name=name, area=area,
                            detached=(li > 0 and
                                      rng.random() < spec.detached_prob)))
            layer.append(name)
        layers.append(layer)

    sid = 0

    def stream(src: str, dst: str, depth: int, *,
               control: bool = False) -> None:
        nonlocal sid
        width = rng.choice(spec.width_choices)
        meta: dict = {}
        if (not control and spec.rate_prob
                and rng.random() < spec.rate_prob):
            # equal producer/consumer tokens-per-firing: multi-rate intent
            # annotated, balance equations consistent by construction
            rate = width * rng.choice(spec.rate_choices)
            meta = {"rate_src": rate, "rate_dst": rate}
        g.add_stream(Stream(name=f"e{sid}", src=src, dst=dst, width=width,
                            depth=depth, control=control, meta=meta),
                     validate=False)
        sid += 1

    for li in range(1, len(layers)):
        for dst in layers[li]:
            for src in rng.sample(layers[li - 1],
                                  rng.randint(1, len(layers[li - 1]))):
                stream(src, dst, rng.choice(spec.depth_choices),
                       control=(rng.random() < spec.control_prob))
    if len(layers) >= 3 and rng.random() < spec.skip_prob:
        # reconvergent skip edge across the whole graph
        stream(layers[0][0], layers[-1][0], rng.choice(spec.depth_choices))
    if rng.random() < spec.cycle_prob:
        # feedback edge: a *data* feedback closes a tokenless dependency
        # cycle (deadlock fodder for the differential); a *control* one
        # models the phase-handshake closure real designs use
        stream(layers[-1][0], layers[0][0], rng.choice(spec.cycle_depths),
               control=(rng.random() < spec.cycle_control_prob))

    for i in range(rng.randint(*spec.hbm_io_tasks)):
        # HBM-bound IO task: demands hbm_channels (a hard slot resource on
        # U280-like grids), alternating reader / writer
        name = f"io{i}"
        area = {"hbm_channels": rng.choice(spec.hbm_channel_choices)}
        if spec.lut_range[1] > 0:
            area["LUT"] = float(rng.randint(*spec.lut_range))
        g.add_task(Task(name=name, area=area, meta={"hbm_io": True}))
        depth = max(spec.depth_choices)
        if i % 2 == 0:
            stream(name, rng.choice(layers[0]), depth)
        else:
            stream(rng.choice(layers[-1]), name, depth)
    return g


def generate_design(seed: int, spec: CorpusSpec) -> CorpusDesign:
    """The design of one ``(family, seed)`` pair — fully deterministic,
    independent of generation order and of the process's hash seed."""
    rng = random.Random(f"corpus:{spec.family}:{seed}")
    g = generate_graph(rng, spec)
    lat = {s.name: rng.randint(*spec.latency_range) for s in g.streams}
    extra = {}
    for s in g.streams:
        e = rng.choice(spec.extra_choices)
        extra[s.name] = 2 * lat[s.name] if e < 0 else e
    ii = {n: rng.randint(*spec.ii_range) for n in g.tasks}
    firings = rng.randint(*spec.firings_range)
    return CorpusDesign(graph=g, family=spec.family, seed=seed,
                        fingerprint=graph_fingerprint(g), latency=lat,
                        extra_capacity=extra, ii=ii, firings=firings)


def sample_corpus(spec: CorpusSpec | str, n: int, *,
                  seed: int = 0) -> list[CorpusDesign]:
    """``n`` designs of one family, seeds ``seed .. seed + n - 1``.

    Accepts a spec or a ``FAMILIES`` name.  Sampling is embarrassingly
    indexable — design ``i`` only depends on ``(family, seed + i)`` — so
    CI's pinned seed set and the nightly's larger one overlap exactly on
    the shared prefix.
    """
    if isinstance(spec, str):
        spec = FAMILIES[spec]
    return [generate_design(seed + i, spec) for i in range(n)]


def random_graph(rng: random.Random, allow_cycle: bool = False,
                 spec: CorpusSpec | None = None) -> TaskGraph:
    """Drop-in replacement for the tests' historical ``_random_graph``
    helpers: a ``fuzz``-family graph drawn from ``rng`` (layered DAG,
    zero-depth FIFOs, control streams, detached sinks, skip edges, and —
    with ``allow_cycle`` — an occasional feedback edge that may close a
    tokenless dependency cycle)."""
    if spec is None:
        spec = FAMILIES["fuzz"]
    if not allow_cycle:
        spec = dataclasses.replace(spec, cycle_prob=0.0)
    return generate_graph(rng, spec)
