"""Corpus specification: the knobs of the seeded task-graph generator.

A ``CorpusSpec`` is a frozen bundle of distribution knobs — topology
(layer/task counts, fan-in, skip/feedback edges), stream properties
(depth, width, control probability, SDF rate annotations), task
properties (detached probability, LUT area, HBM-bound IO tasks) and the
per-design simulation knob ranges (latency, headroom, II, wave size).
``FAMILIES`` names the presets the benchmark suite and CI sweep; the
``fuzz`` family keeps the deliberately-broken coverage (zero-capacity
FIFOs, tokenless data cycles, detached sinks) that the simulator and
analysis property tests rely on, while every other family generates
lint-clean designs (zero ``repro.analysis`` structure errors).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    """Distribution knobs of one corpus family.

    All ``*_range`` fields are inclusive ``(lo, hi)`` integer ranges; all
    ``*_choices`` fields are uniform-choice tuples (repeat a value to
    weight it).  Probabilities are per-opportunity.
    """
    #: family tag (also the graph-name prefix and part of the design seed)
    family: str = "dag"

    # -- topology ----------------------------------------------------------
    layers: tuple[int, int] = (2, 4)
    tasks_per_layer: tuple[int, int] = (1, 3)
    #: each layer-N task draws its fan-in uniformly from 1..len(layer N-1)
    #: (full reconvergence possible); these knobs add the non-layered edges
    skip_prob: float = 0.7          # reconvergent first->last skip edge
    cycle_prob: float = 0.0         # feedback edge closing a cycle
    cycle_depths: tuple[int, ...] = (0, 1, 2)
    cycle_control_prob: float = 0.0  # feedback edge demoted to control

    # -- streams -----------------------------------------------------------
    depth_choices: tuple[int, ...] = (1, 2, 3, 4)
    width_choices: tuple[float, ...] = (32.0,)
    control_prob: float = 0.1
    #: probability a data stream carries SDF ``meta`` rate annotations
    #: (``rate_src`` / ``rate_dst``); rates are drawn per-stream with equal
    #: producer/consumer tokens-per-firing, so the balance equations stay
    #: consistent by construction (no R001 diagnostics)
    rate_prob: float = 0.0
    rate_choices: tuple[int, ...] = (1, 2, 4)

    # -- tasks -------------------------------------------------------------
    detached_prob: float = 0.1      # non-source layers only
    #: per-task LUT area range; (0, 0) means empty area vectors (the fuzz
    #: family — floorplan-trivial, simulator-focused)
    lut_range: tuple[int, int] = (0, 0)
    #: number of HBM-bound IO tasks appended to the graph; each demands
    #: ``hbm_channels`` area and alternates reader (feeds the first layer)
    #: / writer (drains the last layer)
    hbm_io_tasks: tuple[int, int] = (0, 0)
    hbm_channel_choices: tuple[float, ...] = (1.0, 2.0)

    # -- per-design simulation knobs --------------------------------------
    latency_range: tuple[int, int] = (0, 4)
    #: extra-capacity choices; the ``-1`` sentinel means "full pipeline
    #: headroom", i.e. ``2 * latency`` of that stream
    extra_choices: tuple[int, ...] = (0, 0, 2, -1)
    ii_range: tuple[int, int] = (1, 4)
    firings_range: tuple[int, int] = (10, 30)


#: the named corpus families.  ``fuzz`` mirrors the historical ad-hoc
#: ``_random_graph`` test helpers (zero-depth FIFOs, data-cycle deadlocks,
#: detached sinks — broken on purpose); the rest are lint-clean and carry
#: areas so the floorplanner has real work.
FAMILIES: dict[str, CorpusSpec] = {
    "fuzz": CorpusSpec(
        family="fuzz",
        depth_choices=(0, 1, 2, 3),
        cycle_prob=0.5,
        cycle_control_prob=0.2,
    ),
    "dag": CorpusSpec(
        family="dag",
        layers=(3, 5),
        tasks_per_layer=(1, 3),
        width_choices=(16.0, 32.0, 64.0),
        lut_range=(50, 400),
        detached_prob=0.0,
    ),
    "cyclic": CorpusSpec(
        family="cyclic",
        layers=(3, 4),
        cycle_prob=1.0,
        cycle_depths=(2, 3, 4),
        cycle_control_prob=1.0,     # control-closed: cycles, no deadlock
        lut_range=(50, 300),
        detached_prob=0.0,
    ),
    "sdf": CorpusSpec(
        family="sdf",
        layers=(2, 4),
        rate_prob=1.0,
        width_choices=(8.0, 32.0),
        lut_range=(50, 300),
    ),
    "wide": CorpusSpec(
        family="wide",
        layers=(2, 3),
        tasks_per_layer=(3, 6),
        skip_prob=0.9,
        width_choices=(64.0, 128.0, 256.0),
        lut_range=(100, 600),
        detached_prob=0.0,
    ),
    "hbm": CorpusSpec(
        family="hbm",
        layers=(2, 3),
        tasks_per_layer=(1, 3),
        lut_range=(50, 300),
        hbm_io_tasks=(2, 6),
        hbm_channel_choices=(1.0, 2.0, 4.0),
        detached_prob=0.0,
    ),
}

#: the lint-clean families (what the CI corpus gate sweeps); ``fuzz`` is
#: excluded on purpose — it generates broken graphs for the simulator and
#: analysis differential, not floorplannable designs.
CLEAN_FAMILIES: tuple[str, ...] = ("dag", "cyclic", "sdf", "wide", "hbm")
