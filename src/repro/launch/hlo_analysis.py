"""Collective extraction from compiled HLO text (for §Roofline).

cost_analysis() has no collective-bytes entry, so we parse the HLO:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes ring-model wire bytes per participating
device, classified ICI vs DCN by whether its replica groups (or permute
pairs) cross a pod boundary (device id // pod_size).
"""
from __future__ import annotations

import re

import numpy as np

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _result_bytes(line: str) -> int:
    """Bytes of the instruction's RESULT shape (before '= op(...)')"""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    return _shape_bytes(lhs)


def _parse_groups(line: str) -> list[list[int]] | None:
    m = re.search(r"replica_groups=\{([^}]*(?:\},\{[^}]*)*)\}\}", line)
    if m:
        txt = m.group(0)[len("replica_groups={"):-1]
        groups = []
        for g in re.findall(r"\{([\d, ]*)\}", "{" + txt + "}"):
            if g.strip():
                groups.append([int(x) for x in g.replace(" ", "").split(",")])
        return groups or None
    # compact iota form: replica_groups=[G,n]<=[d0,d1,...]T(p...)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                  r"(?:T\(([\d,]+)\))?", line)
    if m:
        G, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(G, n).tolist()
    return None


def _permute_pairs(line: str) -> list[tuple[int, int]]:
    m = re.search(r"source_target_pairs=\{([^}]*)\}", line)
    if not m:
        return []
    return [tuple(int(x) for x in p.split(","))
            for p in re.findall(r"\{(\d+,\d+)\}", "{" + m.group(1) + "}")]


def collective_summary(hlo: str, *, pod_size: int) -> dict:
    """Ring-model wire bytes per device, ICI vs DCN classified."""
    out = {"ici_bytes": 0.0, "dcn_bytes": 0.0, "ops": {},
           "count": 0}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w\.\-]+ = .*?(all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start)?\(", ls)
        if not m or "-done" in ls.split("(")[0]:
            continue
        op = m.group(1)
        size = _result_bytes(ls)
        if op == "collective-permute":
            pairs = _permute_pairs(ls)
            crosses = any(a // pod_size != b // pod_size for a, b in pairs)
            wire = float(size)
            n = 2
        else:
            groups = _parse_groups(ls)
            n = len(groups[0]) if groups else 1
            if n <= 1:
                continue
            crosses = bool(groups) and any(
                len({d // pod_size for d in g}) > 1 for g in groups)
            if op == "all-reduce":
                wire = 2.0 * size * (n - 1) / n
            elif op == "all-gather":
                wire = float(size) * (n - 1) / n   # size = gathered result
            else:  # reduce-scatter (result is the scattered piece), a2a
                wire = float(size) * (n - 1)
        key = "dcn_bytes" if crosses else "ici_bytes"
        out[key] += wire
        out["ops"][op] = out["ops"].get(op, 0) + 1
        out["count"] += 1
    return out
