import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh x mode)
cell on the production mesh (16x16 single-pod / 2x16x16 multi-pod) with
ShapeDtypeStruct inputs — no allocation.  Prints memory_analysis (fits) and
cost_analysis (FLOPs/bytes) and extracts the collective schedule from the
compiled HLO for the roofline (benchmarks/roofline.py reads the JSON this
writes).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k \
      --mesh pod --mode baseline [--out artifacts/dryrun]
  python -m repro.launch.dryrun --all --mesh multipod   # every cell
"""
import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.distributed.taskgraph import SHAPES
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod

# long_500k needs sub-quadratic attention: run for SSM/hybrid and the
# sliding-window-dominant gemmas; skip pure full-attention archs +
# whisper (DESIGN.md §4)
LONG_OK = {"zamba2-7b", "rwkv6-1.6b", "gemma2-27b", "gemma3-12b"}


def cells_for(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_OK:
        out.append("long_500k")
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, mode: str,
             out_dir: str | None = None, seed: int = 0,
             unroll: bool = False) -> dict:
    cfg = configs.get(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "mode": mode,
           "chips": int(mesh.devices.size), "unroll": unroll}
    with mesh:
        if cell.kind == "train":
            if mode == "tapa":
                step, args, ins, outs, plan = steps_mod.build_tapa_train(
                    cfg, mesh, cell, seed=seed, unroll=unroll)
                rec["plan"] = {
                    "n_stages": plan.n_stages,
                    "stage_slots": plan.stage_slots,
                    "boundary_depth": plan.boundary_depth,
                    "crossing_cost": plan.crossing_cost,
                }
            else:
                step, args, ins, outs = steps_mod.build_baseline_train(
                    cfg, mesh, cell, unroll=unroll)
        else:
            step, args, ins, outs = steps_mod.build_baseline_serve(
                cfg, mesh, cell, unroll=unroll)
            rec["mode"] = mode = "baseline"   # serving lowers GSPMD path
        donate = (0, 1) if cell.kind == "train" else ()
        lowered = jax.jit(step, in_shardings=ins, out_shardings=outs,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    rec.update(
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        arg_bytes=int(mem.argument_size_in_bytes),
        out_bytes=int(mem.output_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
        peak_bytes_per_device=int(mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
    )
    coll = hlo_analysis.collective_summary(
        compiled.as_text(), pod_size=256 if mesh_kind == "multipod" else 1 << 30)
    rec["collectives"] = coll
    print(f"dryrun,{arch},{shape},{mesh_kind},{mode},"
          f"flops={rec['flops']:.3e},"
          f"peakGB={rec['peak_bytes_per_device']/1e9:.2f},"
          f"collMB_ici={coll['ici_bytes']/1e6:.1f},"
          f"collMB_dcn={coll['dcn_bytes']/1e6:.1f},"
          f"compile={t_compile:.0f}s", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}__{mode}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    del compiled, lowered
    jax.clear_caches()   # compiled executables would accumulate across cells
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--mode", default="baseline", choices=["baseline", "tapa"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scans so cost_analysis counts every layer")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        ok = fail = 0
        for arch in configs.ARCHS:
            for shape in cells_for(arch):
                fn = os.path.join(args.out,
                                  f"{arch}__{shape}__{args.mesh}__{args.mode}"
                                  ".json")
                if args.skip_existing and os.path.exists(fn):
                    ok += 1
                    continue
                try:
                    run_cell(arch, shape, args.mesh, args.mode, args.out,
                             args.seed, unroll=args.unroll)
                    ok += 1
                except Exception:
                    traceback.print_exc()
                    print(f"dryrun,{arch},{shape},{args.mesh},{args.mode},"
                          f"FAILED", flush=True)
                    fail += 1
        print(f"dryrun,SUMMARY,{args.mesh},{args.mode},ok={ok},fail={fail}")
        raise SystemExit(1 if fail else 0)
    run_cell(args.arch, args.shape, args.mesh, args.mode, args.out,
             args.seed, unroll=args.unroll)


if __name__ == "__main__":
    main()
