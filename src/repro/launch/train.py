"""End-to-end training driver (runs REAL steps — CPU-sized configs for the
offline container; the same code path drives a pod through the dry-run's
builders).

Features: baseline GSPMD or TAPA floorplanned-pipeline execution, synthetic
or memmap data, checkpoint/restart (auto-resume from the latest step),
simulated failure injection (--fail-at) to exercise the restart path, and
optional int8 error-feedback gradient compression on the DP reduction.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.data import ShardedLoader, SyntheticTokens
from repro.model import lm
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash at this step (exit 42)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    print(f"train: {cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    start = 0
    if args.ckpt_dir:
        step0 = latest_step(args.ckpt_dir)
        if step0 is not None:
            print(f"restoring from step {step0}")
            tree = restore_checkpoint(args.ckpt_dir, step0,
                                      {"params": params, "opt": opt_state})
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            start = step0

    source = SyntheticTokens(cfg.vocab, seed=args.seed)
    loader = ShardedLoader(source, shard=0, batch=args.batch, seq=args.seq)

    @jax.jit
    def train_step(params, opt_state, tokens, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, {"tokens": tokens}))(params)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        return params, opt_state, loss, gn

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        if args.fail_at is not None and step == args.fail_at:
            print(f"simulated failure at step {step}")
            raise SystemExit(42)
        tokens = jnp.asarray(next(loader))
        lr = cosine_schedule(step, peak=args.lr, warmup=20,
                             total=args.steps)
        params, opt_state, loss, gn = train_step(params, opt_state, tokens,
                                                 lr)
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gn):.2f} lr {float(lr):.2e} "
                  f"({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            asynchronous=True)
    loader.close()
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt_state})
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"done: loss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.05 else 'flat'})")


if __name__ == "__main__":
    main()
