"""Production mesh construction (spec-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
