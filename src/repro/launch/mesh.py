"""Production mesh construction (spec-mandated shapes).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: newer releases expose
    ``jax.sharding.AxisType`` and accept ``axis_types``; older ones (e.g.
    0.4.x) default every axis to Auto and take no such argument.  Both
    paths produce an all-Auto mesh."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except TypeError:  # pragma: no cover - AxisType without the kwarg
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
