"""Batched serving driver: prefill a batch of prompts, then decode with
the KV/state caches (greedy).  Reduced configs run real tokens on CPU; the
full configs drive the same path on a pod.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.model import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))

    B = args.batch
    extra = None
    if cfg.family == "vlm":
        extra = {"vision": jnp.ones((B, cfg.frontend_tokens,
                                     cfg.frontend_dim), jnp.bfloat16) * .01}
    elif cfg.family == "audio":
        extra = {"frames": jnp.ones((B, cfg.frontend_tokens,
                                     cfg.frontend_dim), jnp.bfloat16) * .01}

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, args.prompt_len), 0, cfg.vocab)
    max_seq = args.prompt_len + args.gen
    cache = lm.init_cache(params, cfg, B, max_seq=max_seq, extra=extra)

    step_fn = jax.jit(lambda p, c, t: lm.step(p, cfg, c, t))
    t0 = time.time()
    logits, cache = step_fn(params, cache, prompts)
    print(f"prefill {args.prompt_len} tokens x {B}: "
          f"{time.time()-t0:.2f}s")

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(args.gen):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen} x {B} tokens in {dt:.2f}s "
          f"({args.gen*B/dt:.1f} tok/s)")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
