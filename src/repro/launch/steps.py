"""Step builders shared by dryrun/train/serve: given (arch, shape cell,
mesh, mode) produce the jitted step function, ShapeDtypeStruct input specs
and shardings — no device allocation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import baseline as bl
from repro.distributed import pipeline as pp
from repro.distributed.sharding import TpuPlan, plan_cell, refined_mesh
from repro.distributed.taskgraph import ShapeCell
from repro.model import lm
from repro.model.layers import PDTYPE
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, zero1_specs)

N_MICRO = 8


def n_micro_for(cfg: ArchConfig) -> int:
    """Deeper microbatching for big models: activation footprint scales
    1/n_micro (the 16 GB/chip budget is the binding constraint)."""
    n = cfg.param_count()
    if n >= 100e9:
        return 32
    if n >= 20e9:
        return 16
    return N_MICRO


def input_specs(cfg: ArchConfig, cell: ShapeCell, *, mode: str = "baseline",
                n_micro: int = N_MICRO) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        if mode == "tapa":
            mb = max(B // n_micro, 1)
            toks = jax.ShapeDtypeStruct((n_micro, mb, S + 1), jnp.int32)
        else:
            toks = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
    elif cell.kind == "prefill":
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["extra"] = {"vision": jax.ShapeDtypeStruct(
            (B if mode != "tapa" or cell.kind != "train" else toks.shape[1],
             cfg.frontend_tokens, cfg.frontend_dim), PDTYPE)}
    if cfg.family == "audio":
        batch["extra"] = {"frames": jax.ShapeDtypeStruct(
            (B if mode != "tapa" or cell.kind != "train" else toks.shape[1],
             cfg.frontend_tokens, cfg.frontend_dim), PDTYPE)}
    return batch


def param_structs(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(lm.init_params, cfg),
                          jax.random.PRNGKey(0))


def _opt_fns(cfg: ArchConfig):
    if cfg.optimizer == "adafactor":
        return adafactor_init, adafactor_update
    return adamw_init, adamw_update


# ---------------------------------------------------------------------------
# baseline GSPMD train / serve
# ---------------------------------------------------------------------------

def build_baseline_train(cfg: ArchConfig, mesh: Mesh, cell: ShapeCell, *,
                         unroll: bool = False, n_micro: int | None = None):
    n_micro = n_micro or n_micro_for(cfg)
    opt_init, opt_update = _opt_fns(cfg)
    loss_fn = bl.build_loss(cfg, remat=True, unroll=unroll)

    p_structs0 = param_structs(cfg)
    specs0 = pp.param_specs(cfg, p_structs0, tp_axis="model",
                            tp_size=mesh.shape["model"])
    daxes0 = bl.data_axes(mesh)
    dsize0 = 1
    for a in daxes0:
        dsize0 *= mesh.shape[a]
    zspecs_c = zero1_specs(specs0, p_structs0, data_axes=daxes0,
                           data_size=dsize0)

    def train_step(params, opt_state, batch):
        # gradient accumulation over n_micro microbatches: global batch
        # activations never materialize at once (16 GB/chip budget)
        toks = batch["tokens"]
        B = toks.shape[0]
        mb = max(B // n_micro, 1)
        toks = toks[:mb * n_micro].reshape(n_micro, mb, -1)
        extra = batch.get("extra")
        if extra is not None:
            extra = jax.tree.map(
                lambda t: t[:mb * n_micro].reshape(
                    (n_micro, mb) + t.shape[1:]), extra)

        def mb_step(carry, xs):
            loss_a, grads_a = carry
            b = {"tokens": xs[0]}
            if extra is not None:
                b["extra"] = xs[1]
            loss, grads = jax.value_and_grad(loss_fn)(params, b)
            grads_a = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_a, grads)
            return (loss_a + loss, grads_a), None

        # ZeRO-2-style: the fp32 grad accumulator is replicated across the
        # data axes by construction (grads are post-allreduce), so shard it
        # there — 27B+ models cannot afford a replicated fp32 accumulator
        zero_g = jax.tree.map(
            lambda p, sp: jax.lax.with_sharding_constraint(
                jnp.zeros(p.shape, jnp.float32), NamedSharding(mesh, sp)),
            params, zspecs_c)
        xs = (toks, extra) if extra is not None else (toks, toks)
        (loss, grads), _ = jax.lax.scan(
            mb_step, (jnp.zeros((), jnp.float32), zero_g), xs,
            unroll=n_micro if unroll else 1)
        loss = loss / n_micro
        grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32),
                             grads)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt_update(params, grads, opt_state, lr=3e-4)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    p_structs = param_structs(cfg)
    o_structs = jax.eval_shape(opt_init, p_structs)
    specs = pp.param_specs(cfg, p_structs, tp_axis="model",
                           tp_size=mesh.shape["model"])
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    # optimizer state follows param specs + ZeRO-1 over data axes
    daxes = bl.data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    zspecs = zero1_specs(specs, p_structs, data_axes=daxes, data_size=dsize)
    zspecs_c = zspecs   # used by the grad accumulator inside train_step
    oshard = {
        k: (jax.tree.map(lambda s: NamedSharding(mesh, s), v,
                         is_leaf=lambda x: isinstance(x, P))
            if k != "step" else NamedSharding(mesh, P()))
        for k, v in _opt_spec_tree(o_structs, zspecs).items()}
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          _batch_specs(cfg, cell, mesh, mode="baseline"),
                          is_leaf=lambda x: isinstance(x, P))
    in_shardings = (pshard, oshard, bshard)
    out_shardings = (pshard, oshard,
                     NamedSharding(mesh, P()))
    args = (p_structs, o_structs,
            input_specs(cfg, cell, mode="baseline"))
    return train_step, args, in_shardings, out_shardings


def _opt_spec_tree(o_structs, param_zspecs):
    """Optimizer-state spec tree mirroring its structure."""
    out = {}
    for k, v in o_structs.items():
        if k == "step":
            out[k] = P()
        else:
            # v mirrors params (adamw m/v) or nested dicts (adafactor)
            out[k] = _mirror_specs(v, param_zspecs)
    return out


def _mirror_specs(tree, pspecs):
    if isinstance(tree, dict) and not isinstance(pspecs, dict):
        # adafactor factored leaves {vr, vc} / {v} under a param leaf
        out = {}
        for k, v in tree.items():
            if k == "v":
                out[k] = pspecs
            else:  # vr / vc: drop one trailing dim of the param spec
                parts = tuple(pspecs)
                out[k] = P(*parts[:v.ndim]) if len(parts) >= v.ndim else \
                    P(*(parts + (None,) * (v.ndim - len(parts))))
        return out
    if isinstance(tree, dict):
        return {k: _mirror_specs(v, pspecs[k]) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_mirror_specs(v, pspecs[i]) for i, v in enumerate(tree)]
    return pspecs


def _batch_specs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh, *, mode: str):
    daxes = bl.data_axes(mesh) if mode == "baseline" else ("data",)
    if mode == "tapa" and cell.kind == "train":
        toks = P(None, daxes, None)
    else:
        toks = P(daxes, None)
    out = {"tokens": toks}
    if cfg.family in ("vlm", "audio"):
        key = "vision" if cfg.family == "vlm" else "frames"
        out["extra"] = {key: P(daxes, None, None)}
    return out


def build_baseline_serve(cfg: ArchConfig, mesh: Mesh, cell: ShapeCell, *,
                         unroll: bool = False):
    p_structs = param_structs(cfg)
    specs = pp.param_specs(cfg, p_structs, tp_axis="model",
                           tp_size=mesh.shape["model"])
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    B = cell.global_batch
    extra = None
    if cfg.family in ("vlm", "audio"):
        key = "vision" if cfg.family == "vlm" else "frames"
        extra = {key: jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.frontend_dim), PDTYPE)}
    cache_structs = jax.eval_shape(
        lambda p, e: lm.init_cache(p, cfg, B, max_seq=cell.seq_len,
                                   extra=e), p_structs, extra)
    cshard = bl.cache_shardings(cfg, cache_structs, mesh)
    toks = input_specs(cfg, cell)["tokens"]
    daxes = bl.data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    bspec = daxes if B % dsize == 0 else None
    tshard = NamedSharding(mesh, P(bspec, None))
    logit_shard = NamedSharding(mesh, P(bspec, "model"))

    def serve_step(params, cache, tokens):
        return lm.step(params, cfg, cache, tokens, unroll=unroll)

    args = (p_structs, cache_structs, toks)
    in_shardings = (pshard, cshard, tshard)
    out_shardings = (logit_shard, cshard)
    return serve_step, args, in_shardings, out_shardings


# ---------------------------------------------------------------------------
# TAPA floorplanned pipeline train
# ---------------------------------------------------------------------------

def build_tapa_train(cfg: ArchConfig, mesh: Mesh, cell: ShapeCell, *,
                     plan: TpuPlan | None = None, n_micro: int | None = None,
                     seed: int = 0, unroll: bool = False):
    n_micro = n_micro or n_micro_for(cfg)
    mesh_shape = tuple(mesh.devices.shape)
    if plan is None:
        plan = plan_cell(cfg, cell.name, mesh_shape, seed=seed, mode="tapa")
    rmesh = refined_mesh(mesh, plan)
    opt_init, opt_update = _opt_fns(cfg)
    loss_fn = pp.build_train_loss(cfg, plan, rmesh, n_micro=n_micro,
                                  unroll=unroll)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt_update(params, grads, opt_state, lr=3e-4)
        return params, opt_state, {"loss": loss, "grad_norm": gn}

    p_structs = jax.eval_shape(
        lambda k: pp.to_pipeline_params(lm.init_params(cfg, k),
                                        plan.n_stages),
        jax.random.PRNGKey(0))
    o_structs = jax.eval_shape(opt_init, p_structs)
    specs = pp.param_specs(cfg, p_structs, tp_axis="tp",
                           tp_size=rmesh.shape["tp"],
                           stage_axis="stage")
    pshard = jax.tree.map(lambda s: NamedSharding(rmesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    zspecs = zero1_specs(specs, p_structs, data_axes=("data",),
                         data_size=rmesh.shape["data"])
    oshard = {
        k: (jax.tree.map(lambda s: NamedSharding(rmesh, s),
                         _mirror_specs(v, zspecs),
                         is_leaf=lambda x: isinstance(x, P))
            if k != "step" else NamedSharding(rmesh, P()))
        for k, v in o_structs.items()}
    mb_sz = max(cell.global_batch // n_micro, 1)
    bspecs = _batch_specs(cfg, cell, rmesh, mode="tapa")
    if mb_sz % rmesh.shape["data"] != 0:   # small microbatches: replicate
        bspecs = jax.tree.map(
            lambda sp: P(*[None] * len(tuple(sp))), bspecs,
            is_leaf=lambda x: isinstance(x, P))
    bshard = jax.tree.map(lambda s: NamedSharding(rmesh, s), bspecs,
                          is_leaf=lambda x: isinstance(x, P))
    args = (p_structs, o_structs,
            input_specs(cfg, cell, mode="tapa", n_micro=n_micro))
    in_shardings = (pshard, oshard, bshard)
    out_shardings = (pshard, oshard, NamedSharding(rmesh, P()))
    return train_step, args, in_shardings, out_shardings, plan
