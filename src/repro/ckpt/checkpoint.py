"""Sharded checkpointing with manifest + async save + restart/reshard.

Layout: <dir>/step_<N>/shard_<k>.npz + manifest.json.  Each host writes
the leaves it owns (addressable shards); restore resharsds to the current
mesh via device_put with the target shardings — re-flooplanned (elastic)
restarts therefore Just Work: the floorplan only changes shardings, and
restore follows them.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _to_numpy(v):
    a = np.asarray(v)
    if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
        # npz cannot serialize bf16: store as f32, restore casts back via
        # the template leaf dtype
        a = np.asarray(jax.device_get(v)).astype(np.float32) \
            if hasattr(v, "astype") else a.astype(np.float32)
    return a


def save_checkpoint(directory: str, step: int, tree, *, asynchronous=False,
                    _host_id: int = 0):
    flat = _flatten(tree)
    arrays = {k: _to_numpy(v) for k, v in flat.items() if v is not None}

    def _write():
        d = os.path.join(directory, f"step_{step:08d}")
        os.makedirs(d, exist_ok=True)
        tmp = os.path.join(
            d, f".shard_{_host_id}.{threading.get_ident()}.tmp.npz")
        np.savez(tmp, **{k.replace("/", "|"): v for k, v in arrays.items()})
        os.replace(tmp, os.path.join(d, f"shard_{_host_id}.npz"))
        manifest = {"step": step, "keys": sorted(arrays),
                    "hosts": [_host_id]}
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump(manifest, f)

    if asynchronous:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_") and
             os.path.exists(os.path.join(directory, n, "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like,
                       shardings=None):
    d = os.path.join(directory, f"step_{step:08d}")
    data = {}
    for fn in os.listdir(d):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                for k in z.files:
                    data[k.replace("|", "/")] = z[k]

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(t) if isinstance(tree, tuple) else t
        if tree is None:
            return None
        arr = data[prefix[:-1]]
        tgt = getattr(tree, "dtype", None)
        if tgt is not None and str(tgt) != str(arr.dtype):
            arr = jax.numpy.asarray(arr).astype(tgt)
        return arr

    restored = rebuild(tree_like)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if a is not None else None,
            restored, shardings)
    return restored
