"""Process-wide metrics registry: counters, gauges, histograms, groups.

The repo grew nine independent ``*_counts()`` surfaces (engine, floorplan,
ilp, analysis, pool, store, faults, sweep-cache) — each a module-global
dict with its own ``reset_*`` helper and, for the cross-process paths, a
bespoke merge function.  This module replaces the storage behind all of
them with one :class:`Registry` while keeping every legacy call site
working unchanged:

* Each legacy dict becomes a :class:`CounterGroup`, a ``MutableMapping``
  registered under a dotted name (``"sim.engine"``, ``"floorplan"``, ...).
  Existing ``_COUNTS["x"] += 1`` increments, ``dict(_COUNTS)`` snapshots,
  and ``clear()``/``update()`` save-restore idioms all still work.
* :meth:`Registry.snapshot` / :meth:`Registry.delta` /
  :meth:`Registry.merge` give one generic cross-process merge path:
  a worker snapshots before work, computes the delta after, ships the
  delta home, and the parent merges it — no per-subsystem merge code.
* :meth:`Registry.restore` puts the whole registry back to a snapshot,
  which is what the per-test isolation fixture uses.

Labelled instruments (:class:`Counter`, :class:`Gauge`,
:class:`Histogram`) cover the new profiling hooks (store hit latency,
jit compile/execute split) that have no legacy dict equivalent.

Merge semantics (property-tested in ``tests/test_obs.py``):

* counters and histogram aggregates **add** — merge is associative and
  commutative, and the zero delta is an identity;
* gauges are **last-writer-wins** and excluded from deltas by default
  (a gauge is a process-local reading, not an accumulating total).

>>> from repro.obs import metrics
>>> reg = metrics.Registry()
>>> g = reg.group("demo", {"hits": 0, "misses": 0})
>>> g["hits"] += 2
>>> before = reg.snapshot()
>>> g["misses"] += 1
>>> reg.delta(before)["demo"]["values"]
{'misses': 1}
"""

from __future__ import annotations

import math
import threading
from collections.abc import Iterator, MutableMapping
from typing import Any, Callable

Number = float | int
Snapshot = dict[str, dict[str, Any]]

_SEP = ","


def _label_key(labels: dict[str, Any]) -> str:
    """Canonical string key for a label set (sorted, ``k=v`` pairs)."""
    if not labels:
        return ""
    return _SEP.join(f"{k}={labels[k]}" for k in sorted(labels))


def parse_label_key(key: str) -> dict[str, str]:
    """Inverse of the label-key encoding (values come back as strings).

    >>> parse_label_key("backend=jax,tier=disk")
    {'backend': 'jax', 'tier': 'disk'}
    >>> parse_label_key("")
    {}
    """
    if not key:
        return {}
    return dict(pair.split("=", 1) for pair in key.split(_SEP))


class CounterGroup(MutableMapping):
    """A named dict of integer counters that lives inside a registry.

    Drop-in replacement for the legacy module-global counter dicts:
    supports item assignment/augmented increments, ``clear()`` (which
    zeroes rather than empties, matching the legacy ``reset_*`` helpers
    that preserve the key set), ``update()``, and ``dict(group)``.
    """

    def __init__(self, name: str, fields: dict[str, Number],
                 on_reset: Callable[[], None] | None = None) -> None:
        self.name = name
        self._defaults = dict(fields)
        self._data: dict[str, Number] = dict(fields)
        self._on_reset = on_reset

    # -- MutableMapping protocol ------------------------------------
    def __getitem__(self, key: str) -> Number:
        return self._data[key]

    def __setitem__(self, key: str, value: Number) -> None:
        self._data[key] = value

    def __delitem__(self, key: str) -> None:
        del self._data[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CounterGroup({self.name!r}, {self._data!r})"

    # -- registry hooks ---------------------------------------------
    def clear(self) -> None:
        """Zero every counter (legacy ``reset_*`` semantics).

        Unlike ``dict.clear`` this keeps the key set: the legacy reset
        helpers zeroed values in place, and save/restore call sites do
        ``clear()`` + ``update(saved)``.
        """
        for k in self._data:
            self._data[k] = 0
        if self._on_reset is not None:
            self._on_reset()

    def reset(self) -> None:
        """Restore the group to its registered default values."""
        self._data = dict(self._defaults)
        if self._on_reset is not None:
            self._on_reset()

    def snapshot(self) -> dict[str, Number]:
        return dict(self._data)

    def restore(self, values: dict[str, Number]) -> None:
        self._data = dict(values)
        if self._on_reset is not None:
            self._on_reset()

    def merge(self, values: dict[str, Number]) -> None:
        for k, v in values.items():
            self._data[k] = self._data.get(k, 0) + v


class Counter:
    """A monotonically increasing counter with optional labels.

    >>> c = Counter("requests")
    >>> c.inc()
    >>> c.inc(2, backend="jax")
    >>> c.value()
    1
    >>> c.value(backend="jax")
    2
    """

    kind = "counter"

    def __init__(self, name: str) -> None:
        self.name = name
        self._series: dict[str, Number] = {}

    def inc(self, amount: Number = 1, **labels: Any) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0) + amount

    def value(self, **labels: Any) -> Number:
        return self._series.get(_label_key(labels), 0)

    def snapshot(self) -> dict[str, Number]:
        return dict(self._series)

    def restore(self, series: dict[str, Number]) -> None:
        self._series = dict(series)

    def reset(self) -> None:
        self._series = {}

    def merge(self, series: dict[str, Number]) -> None:
        for k, v in series.items():
            self._series[k] = self._series.get(k, 0) + v


class Gauge:
    """A last-writer-wins instantaneous reading (process-local).

    Gauges are excluded from cross-process deltas by default: a reading
    taken inside a worker describes that worker, not the parent.
    """

    kind = "gauge"

    def __init__(self, name: str) -> None:
        self.name = name
        self._series: dict[str, Number] = {}

    def set(self, value: Number, **labels: Any) -> None:
        self._series[_label_key(labels)] = value

    def value(self, **labels: Any) -> Number | None:
        return self._series.get(_label_key(labels))

    def snapshot(self) -> dict[str, Number]:
        return dict(self._series)

    def restore(self, series: dict[str, Number]) -> None:
        self._series = dict(series)

    def reset(self) -> None:
        self._series = {}

    def merge(self, series: dict[str, Number]) -> None:
        self._series.update(series)


def _zero_agg() -> dict[str, Number]:
    return {"count": 0, "sum": 0.0, "min": math.inf, "max": -math.inf}


class Histogram:
    """Streaming aggregate (count/sum/min/max) per label set.

    Full bucketed histograms are overkill for the BENCH block; the
    aggregates are what the regression gates and the top-N summary
    consume, and they merge exactly (count/sum add, min/max combine).

    >>> h = Histogram("latency_s")
    >>> h.observe(0.2, tier="disk")
    >>> h.observe(0.4, tier="disk")
    >>> agg = h.aggregate(tier="disk")
    >>> agg["count"], round(agg["mean"], 3)
    (2, 0.3)
    """

    kind = "histogram"

    def __init__(self, name: str) -> None:
        self.name = name
        self._series: dict[str, dict[str, Number]] = {}

    def observe(self, value: Number, **labels: Any) -> None:
        agg = self._series.setdefault(_label_key(labels), _zero_agg())
        agg["count"] += 1
        agg["sum"] += value
        agg["min"] = min(agg["min"], value)
        agg["max"] = max(agg["max"], value)

    def aggregate(self, **labels: Any) -> dict[str, Number]:
        agg = self._series.get(_label_key(labels))
        if not agg or not agg["count"]:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return dict(agg) | {"mean": agg["sum"] / agg["count"]}

    def snapshot(self) -> dict[str, dict[str, Number]]:
        return {k: dict(v) for k, v in self._series.items()}

    def restore(self, series: dict[str, dict[str, Number]]) -> None:
        self._series = {k: dict(v) for k, v in series.items()}

    def reset(self) -> None:
        self._series = {}

    def merge(self, series: dict[str, dict[str, Number]]) -> None:
        for k, other in series.items():
            agg = self._series.setdefault(k, _zero_agg())
            agg["count"] += other["count"]
            agg["sum"] += other["sum"]
            agg["min"] = min(agg["min"], other["min"])
            agg["max"] = max(agg["max"], other["max"])


class Registry:
    """Named collection of groups and instruments with generic
    snapshot / delta / merge / restore semantics.

    All mutation is GIL-protected dict arithmetic; a lock guards only
    structural registration so fork-inherited registries stay sane.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: dict[str, Any] = {}

    # -- registration -----------------------------------------------
    def group(self, name: str, fields: dict[str, Number],
              on_reset: Callable[[], None] | None = None) -> CounterGroup:
        """Create (or fetch, if identically shaped) a counter group."""
        with self._lock:
            existing = self._entries.get(name)
            if existing is not None:
                if not isinstance(existing, CounterGroup):
                    raise ValueError(f"{name!r} already registered as "
                                     f"{type(existing).__name__}")
                return existing
            grp = CounterGroup(name, fields, on_reset=on_reset)
            self._entries[name] = grp
            return grp

    def _instrument(self, name: str, cls: type) -> Any:
        with self._lock:
            existing = self._entries.get(name)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(f"{name!r} already registered as "
                                     f"{type(existing).__name__}")
                return existing
            inst = cls(name)
            self._entries[name] = inst
            return inst

    def counter(self, name: str) -> Counter:
        return self._instrument(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._instrument(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._instrument(name, Histogram)

    def names(self) -> list[str]:
        return sorted(self._entries)

    def get(self, name: str) -> Any:
        return self._entries.get(name)

    # -- snapshot / delta / merge / restore -------------------------
    @staticmethod
    def _kind(entry: Any) -> str:
        return "group" if isinstance(entry, CounterGroup) else entry.kind

    def snapshot(self) -> Snapshot:
        """Deep copy of every registered metric, tagged by kind."""
        out: Snapshot = {}
        for name, entry in self._entries.items():
            out[name] = {"kind": self._kind(entry),
                         "values": entry.snapshot()}
        return out

    def delta(self, before: Snapshot, *,
              exclude: tuple[str, ...] = ()) -> Snapshot:
        """Change since ``before``, suitable for :meth:`merge`.

        ``exclude`` drops whole entries by name — used by the worker
        pool to keep fault-injection counters out of worker deltas
        (the parent already counts injections at dispatch, so merging
        a surviving worker's own count would double it).

        Gauges are always excluded: a delta is an additive quantity
        and gauges are readings.
        """
        out: Snapshot = {}
        for name, entry in self._entries.items():
            if name in exclude or isinstance(entry, Gauge):
                continue
            prev = before.get(name, {}).get("values", {})
            cur = entry.snapshot()
            if isinstance(entry, Histogram):
                diff = _hist_delta(prev, cur)
            else:
                diff = {k: v - prev.get(k, 0) for k, v in cur.items()
                        if v != prev.get(k, 0)}
            if diff:
                out[name] = {"kind": self._kind(entry), "values": diff}
        return out

    def merge(self, delta: Snapshot) -> None:
        """Fold a delta (usually from another process) into this registry.

        The one generic merge path: replaces the old per-subsystem
        ``merge_floorplan_counts`` / ``merge_solve_counts`` / cache-stat
        plumbing.  Unknown names are registered on the fly so a worker
        with extra instruments still merges cleanly.
        """
        for name, payload in delta.items():
            values = payload.get("values", {})
            entry = self._entries.get(name)
            if entry is None:
                kind = payload.get("kind", "group")
                if kind == "group":
                    entry = self.group(name, {k: 0 for k in values})
                elif kind == "counter":
                    entry = self.counter(name)
                elif kind == "histogram":
                    entry = self.histogram(name)
                else:
                    entry = self.gauge(name)
            entry.merge(values)

    def reset(self, names: tuple[str, ...] | None = None) -> None:
        for name, entry in self._entries.items():
            if names is None or name in names:
                entry.reset()

    def restore(self, snap: Snapshot) -> None:
        """Put every metric back to ``snap`` (per-test isolation).

        Metrics registered after the snapshot was taken are reset to
        their defaults rather than left dirty.
        """
        for name, entry in self._entries.items():
            payload = snap.get(name)
            if payload is None:
                entry.reset()
            else:
                entry.restore(payload["values"])


def _hist_delta(prev: dict[str, dict[str, Number]],
                cur: dict[str, dict[str, Number]]) -> dict:
    out = {}
    for key, agg in cur.items():
        base = prev.get(key)
        count = agg["count"] - (base["count"] if base else 0)
        if count <= 0:
            continue
        out[key] = {"count": count,
                    "sum": agg["sum"] - (base["sum"] if base else 0.0),
                    # min/max of just the new observations are not
                    # recoverable from aggregates; the merged extrema
                    # stay conservative (the union's true extrema).
                    "min": agg["min"], "max": agg["max"]}
    return out


#: The process-wide default registry every subsystem registers into.
REGISTRY = Registry()

group = REGISTRY.group
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
delta = REGISTRY.delta
merge = REGISTRY.merge
reset = REGISTRY.reset
restore = REGISTRY.restore
