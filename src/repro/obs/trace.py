"""Structured tracing: nestable spans with Chrome/Perfetto export.

Spans record wall-clock intervals into a flat in-process buffer (plain
list appends — atomic under the GIL, no locks on the hot path).  Each
span carries a process-unique id and its parent's id, so the buffer is
a forest that can be re-assembled after worker events are shipped home:

* ``span("search.round", round=3)`` nests via a thread-local stack;
* :func:`current_token` exports the innermost open span's id so a
  ``ProcessPoolExecutor`` worker can :func:`attach` it and have its own
  spans parented under the dispatching round;
* the worker returns :func:`drain` output with its result and the
  parent :func:`absorb`\\ s it — same shape as the registry delta merge.

Tracing is **off by default** (``span`` is then a no-op context
manager); drivers call :func:`enable` around instrumented runs.

Timestamps come from one anchor pair captured at import: epoch µs plus
a ``perf_counter_ns`` origin.  All spans in a process share the anchor,
so intervals nest exactly (no wall-clock steps mid-run), and
fork-started workers inherit it, so cross-process timestamps land on a
common axis.

>>> from repro.obs import trace
>>> trace.enable(clear=True)
>>> with trace.span("demo.outer"):
...     with trace.span("demo.inner", n=1):
...         pass
>>> [e["name"] for e in trace.events()]
['demo.outer', 'demo.inner']
>>> evs = trace.events()
>>> evs[1]["parent"] == evs[0]["id"]
True
>>> trace.disable()
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Iterator

# Shared timebase: epoch anchor + monotonic offset (see module docstring).
_T0_EPOCH_NS = time.time_ns()
_T0_PERF_NS = time.perf_counter_ns()

_ENABLED = False
_EVENTS: list[dict[str, Any]] = []
_IDS = itertools.count(1)
_END_SEQ = itertools.count(1)
_LOCAL = threading.local()


def _now_ns() -> int:
    return _T0_EPOCH_NS + (time.perf_counter_ns() - _T0_PERF_NS)


def _stack() -> list[str]:
    stack = getattr(_LOCAL, "stack", None)
    if stack is None:
        stack = _LOCAL.stack = []
    return stack


# -- lifecycle -------------------------------------------------------

def enable(clear: bool = False) -> None:
    """Turn span recording on (optionally clearing the buffer first)."""
    global _ENABLED
    if clear:
        _EVENTS.clear()
        _stack().clear()
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def clear() -> None:
    _EVENTS.clear()
    _stack().clear()


def events() -> list[dict[str, Any]]:
    """Copy of the span buffer (list of span record dicts)."""
    return [dict(e) for e in _EVENTS]


def drain() -> list[dict[str, Any]]:
    """Return and clear the buffer — what a worker ships to its parent."""
    out = [dict(e) for e in _EVENTS]
    _EVENTS.clear()
    return out


def absorb(worker_events: list[dict[str, Any]]) -> None:
    """Fold spans shipped from a worker into this process's buffer."""
    _EVENTS.extend(worker_events)


# -- span recording --------------------------------------------------

def begin(name: str, **args: Any) -> dict[str, Any] | None:
    """Open a span; returns the record (close with :func:`end`)."""
    if not _ENABLED:
        return None
    stack = _stack()
    parent = stack[-1] if stack else getattr(_LOCAL, "base", None)
    rec = {
        "id": f"{os.getpid():x}-{next(_IDS)}",
        "parent": parent,
        "name": name,
        "pid": os.getpid(),
        "tid": threading.get_ident() % 0xFFFFFFFF,
        "t_ns": _now_ns(),
        "dur_ns": None,
        "end_seq": None,
        "args": {k: v for k, v in args.items() if v is not None},
    }
    _EVENTS.append(rec)
    stack.append(rec["id"])
    return rec


def end(rec: dict[str, Any] | None) -> None:
    if rec is None:
        return
    rec["dur_ns"] = _now_ns() - rec["t_ns"]
    rec["end_seq"] = next(_END_SEQ)
    stack = _stack()
    if stack and stack[-1] == rec["id"]:
        stack.pop()
    elif rec["id"] in stack:  # closed out of order: unwind to it
        del stack[stack.index(rec["id"]):]


@contextmanager
def span(name: str, **args: Any) -> Iterator[dict[str, Any] | None]:
    """Record a nested span around the ``with`` body.

    Extra keyword arguments become Perfetto ``args`` on the span;
    ``None`` values are dropped.  Yields the (mutable) span record so
    callers can attach result args before the span closes.
    """
    rec = begin(name, **args)
    try:
        yield rec
    finally:
        end(rec)


# -- cross-process propagation ---------------------------------------

def current_token() -> str:
    """Id of the innermost open span ("" when none) — ship to workers."""
    stack = _stack()
    if stack:
        return stack[-1]
    return getattr(_LOCAL, "base", None) or ""


def attach(token: str) -> None:
    """Adopt ``token`` as the parent for this thread's top-level spans.

    Called at worker entry with the dispatching process's
    :func:`current_token`, so worker spans hang under the dispatching
    round once the parent absorbs them.
    """
    _LOCAL.base = token or None


def begin_worker(token: str, *, enable_tracing: bool) -> None:
    """Reset inherited trace state at worker entry (fork-safe)."""
    global _ENABLED
    _EVENTS.clear()
    _stack().clear()
    attach(token)
    _ENABLED = enable_tracing


# -- Chrome/Perfetto export ------------------------------------------

def to_chrome(span_events: list[dict[str, Any]] | None = None,
              *, process_names: dict[int, str] | None = None) -> dict:
    """Render span records as a Chrome ``trace_event`` document.

    Each closed span becomes a matched B/E pair (the explicit form the
    regression gate validates); unclosed spans are skipped, and the
    :func:`bench_block` ``unclosed`` count is how they surface.  A
    metadata ("M") ``process_name`` event labels each pid.
    """
    spans = _EVENTS if span_events is None else span_events
    my_pid = os.getpid()
    names = dict(process_names or {})
    out: list[tuple] = []
    for i, rec in enumerate(spans):
        if rec.get("dur_ns") is None:
            continue
        pid, tid = rec["pid"], rec["tid"]
        names.setdefault(pid, "repro" if pid == my_pid else "repro-worker")
        args = dict(rec.get("args") or {})
        args["span_id"] = rec["id"]
        if rec.get("parent"):
            args["parent_id"] = rec["parent"]
        t0, t1 = rec["t_ns"], rec["t_ns"] + rec["dur_ns"]
        # Sort key: ns timestamp, then E before B on exact ties (a
        # sibling's end precedes the next begin), then begin/end order.
        out.append(((t0, 1, i),
                    {"name": rec["name"], "cat": rec["name"].split(".")[0],
                     "ph": "B", "ts": t0 / 1000.0, "pid": pid, "tid": tid,
                     "args": args}))
        out.append(((t1, 0, rec.get("end_seq") or i),
                    {"name": rec["name"], "ph": "E", "ts": t1 / 1000.0,
                     "pid": pid, "tid": tid}))
    meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": label}} for pid, label in sorted(names.items())]
    return {"traceEvents": meta + [ev for _, ev in sorted(out,
                                                          key=lambda p: p[0])],
            "displayTimeUnit": "ms"}


def write_chrome(path: str,
                 span_events: list[dict[str, Any]] | None = None) -> dict:
    doc = to_chrome(span_events)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return doc


def validate_chrome(doc: dict) -> list[str]:
    """Schema-check a Chrome trace document; returns error strings.

    Checks the properties the CI gate cares about: a non-empty
    ``traceEvents`` list, pid/tid/ts on every event, per-(pid, tid)
    monotonic non-decreasing timestamps, and strictly matched B/E
    pairs under stack discipline.
    """
    errors: list[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    tracks: dict[tuple, list[dict]] = {}
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("B", "E", "M", "X", "i", "C"):
            errors.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"event {i}: missing pid/tid")
            continue
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event {i}: missing ts")
            continue
        tracks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    n_spans = 0
    for (pid, tid), track in tracks.items():
        last_ts = None
        stack: list[dict] = []
        for ev in track:  # file order; exporter pre-sorts
            if last_ts is not None and ev["ts"] < last_ts:
                errors.append(f"pid {pid} tid {tid}: ts not monotonic "
                              f"({ev['ts']} < {last_ts})")
            last_ts = ev["ts"]
            if ev["ph"] == "B":
                stack.append(ev)
            elif ev["ph"] == "E":
                if not stack:
                    errors.append(f"pid {pid} tid {tid}: E without B "
                                  f"({ev.get('name')})")
                    continue
                top = stack.pop()
                n_spans += 1
                if top.get("name") != ev.get("name"):
                    errors.append(
                        f"pid {pid} tid {tid}: mismatched B/E "
                        f"({top.get('name')!r} closed by {ev.get('name')!r})")
        for ev in stack:
            errors.append(f"pid {pid} tid {tid}: unclosed B "
                          f"({ev.get('name')})")
    if not n_spans and not errors:
        errors.append("no complete spans in trace")
    return errors


# -- BENCH block and summaries ---------------------------------------

def bench_block(total_wall_s: float,
                span_events: list[dict[str, Any]] | None = None) -> dict:
    """The ``sim.obs`` BENCH payload the regression gate inspects.

    ``stage_coverage`` is the fraction of ``total_wall_s`` accounted
    for by *stage* spans — the depth-1 children of root spans (or the
    roots themselves in a flat trace).  Worker spans are parented
    under parent-process spans after :func:`absorb`, so they never
    double-count into coverage.
    """
    spans = _EVENTS if span_events is None else span_events
    closed = [e for e in spans if e.get("dur_ns") is not None]
    ids = {e["id"] for e in spans}
    unclosed = len(spans) - len(closed)
    orphans = sum(1 for e in spans
                  if e.get("parent") and e["parent"] not in ids)
    by_name: dict[str, dict[str, float]] = {}
    for e in closed:
        agg = by_name.setdefault(e["name"], {"count": 0, "wall_s": 0.0})
        agg["count"] += 1
        agg["wall_s"] += e["dur_ns"] / 1e9
    roots = [e for e in closed if not e.get("parent")]
    root_ids = {e["id"] for e in roots}
    stages = [e for e in closed if e.get("parent") in root_ids]
    basis = stages or roots
    covered_s = sum(e["dur_ns"] for e in basis) / 1e9
    coverage = (covered_s / total_wall_s) if total_wall_s > 0 else 0.0
    return {
        "enabled": _ENABLED if span_events is None else True,
        "spans": len(closed),
        "unclosed": unclosed,
        "orphans": orphans,
        "pids": len({e["pid"] for e in spans}) if spans else 0,
        "stage_coverage": round(min(coverage, 1.0), 4),
        "covered_wall_s": round(covered_s, 6),
        "wall_s": round(total_wall_s, 6),
        "by_name": {k: {"count": v["count"],
                        "wall_s": round(v["wall_s"], 6)}
                    for k, v in sorted(by_name.items())},
    }


def summarize(doc: dict, top: int = 15) -> str:
    """Plain-text top-N table (by total wall time) for a Chrome trace."""
    totals: dict[str, dict[str, float]] = {}
    stacks: dict[tuple, list] = {}
    for ev in doc.get("traceEvents", ()):
        key = (ev.get("pid"), ev.get("tid"))
        if ev.get("ph") == "B":
            stacks.setdefault(key, []).append(ev)
        elif ev.get("ph") == "E":
            stack = stacks.get(key)
            if not stack:
                continue
            b = stack.pop()
            agg = totals.setdefault(b.get("name", "?"),
                                    {"count": 0, "wall_us": 0.0})
            agg["count"] += 1
            agg["wall_us"] += ev["ts"] - b["ts"]
    if not totals:
        return "no complete spans"
    rows = sorted(totals.items(), key=lambda kv: -kv[1]["wall_us"])[:top]
    width = max(len(name) for name, _ in rows)
    lines = [f"{'span':<{width}}  {'count':>7}  {'total_ms':>10}  "
             f"{'mean_ms':>9}"]
    for name, agg in rows:
        total_ms = agg["wall_us"] / 1000.0
        lines.append(f"{name:<{width}}  {agg['count']:>7.0f}  "
                     f"{total_ms:>10.2f}  "
                     f"{total_ms / agg['count']:>9.3f}")
    return "\n".join(lines)
