"""CLI for trace files: ``python -m repro.obs {summarize,validate} trace.json``."""

from __future__ import annotations

import argparse
import json
import sys

from .trace import summarize, validate_chrome


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize",
                           help="top-N wall-time table for a trace")
    p_sum.add_argument("trace", help="Chrome trace_event JSON file")
    p_sum.add_argument("-n", "--top", type=int, default=15,
                       help="rows to show (default 15)")
    p_val = sub.add_parser("validate",
                           help="schema-check a trace; exit 1 on errors")
    p_val.add_argument("trace", help="Chrome trace_event JSON file")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)

    if args.cmd == "summarize":
        print(summarize(doc, top=args.top))
        return 0
    errors = validate_chrome(doc)
    for err in errors:
        print(f"trace: {err}", file=sys.stderr)
    if errors:
        return 1
    n = sum(1 for e in doc.get("traceEvents", ()) if e.get("ph") == "E")
    print(f"ok: {n} spans")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
