"""Unified observability layer: metrics registry + structured tracing.

``repro.obs`` is the single place the stack's telemetry lives:

* :mod:`repro.obs.metrics` — a process-wide :class:`~repro.obs.metrics.Registry`
  of counter groups and labelled instruments with generic
  snapshot/delta/merge/restore semantics.  Every legacy ``*_counts()``
  surface (engine, floorplan, ilp, analysis, pool, store, faults,
  sweep-cache) is now a view over this registry, and the worker pool
  ships one registry delta home instead of three bespoke merges.
* :mod:`repro.obs.trace` — nestable spans with cross-process parent
  tokens, Chrome/Perfetto ``trace_event`` export, and the ``sim.obs``
  BENCH block that ``check_regression.py`` gates.

Command line (``python -m repro.obs``)::

    python -m repro.obs summarize trace.json   # top-N wall-time table
    python -m repro.obs validate trace.json    # schema gate, exit 1 on error

Quick tour — count something, trace something, export:

>>> from repro import obs
>>> snap = obs.metrics.snapshot()           # isolate the doctest
>>> misses = obs.metrics.counter("doc.cache")
>>> misses.inc(3, kind="miss")
>>> misses.value(kind="miss")
3
>>> obs.trace.enable(clear=True)
>>> with obs.trace.span("doc.step", n=1):
...     pass
>>> doc = obs.trace.to_chrome()
>>> [e["ph"] for e in doc["traceEvents"] if e["ph"] != "M"]
['B', 'E']
>>> obs.trace.validate_chrome(doc)
[]
>>> obs.trace.disable(); obs.metrics.restore(snap)
"""

import os as _os

from . import metrics, trace

__all__ = ["metrics", "trace", "bench_obs_block"]


def bench_obs_block(total_wall_s: float, trace_path: str | None = None,
                    ) -> dict:
    """The driver-side exit glue: compute the ``sim.obs`` BENCH payload
    and, when a ``--trace`` path was given, export the Perfetto JSON next
    to the BENCH JSON and record its basename as ``trace_file`` (the
    regression gate resolves it relative to the BENCH file)."""
    block = trace.bench_block(total_wall_s)
    if trace_path:
        trace.write_chrome(trace_path)
        block["trace_file"] = _os.path.basename(trace_path)
    return block
