"""AdamW with bf16 params + f32 moments (10 B/param total)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}
