"""Optimizers: AdamW + Adafactor (for >=100B MoE memory budgets), gradient
clipping, schedules, ZeRO-1 sharding specs."""
from .adamw import adamw_init, adamw_update
from .adafactor import adafactor_init, adafactor_update
from .common import clip_by_global_norm, cosine_schedule, zero1_specs

__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "clip_by_global_norm", "cosine_schedule",
           "zero1_specs"]
