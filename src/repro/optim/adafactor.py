"""Adafactor (factored second moment, no first moment): ~2.6 B/param —
the only way a 480B-param MoE trains on a 256-chip v5e pod (DESIGN.md §6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(one, params,
                              is_leaf=lambda x: not isinstance(x, (dict, list))),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0):
    step = state["step"] + 1
    beta = 1.0 - step.astype(jnp.float32) ** (-decay)

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p.shape):
            vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
            vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1)[..., None, None], eps))
            u = gf * jax.lax.rsqrt(denom + eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta * v["v"] + (1 - beta) * g2}
            u = gf * jax.lax.rsqrt(nv["v"] + eps)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_v = tdef.unflatten([o[1] for o in outs])
    return new_p, {"v": new_v, "step": step}
