"""Shared optimizer utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def cosine_schedule(step, *, peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                  (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def zero1_specs(param_specs_tree, param_structs=None, *,
                data_axes=("pod", "data"), data_size: int = 1):
    """ZeRO-1: optimizer-state leaves additionally sharded over the data
    axes, on the largest unsharded dimension divisible by the data size."""
    def shard_one(spec, leaf=None):
        parts = list(tuple(spec))
        if leaf is not None:
            parts += [None] * (leaf.ndim - len(parts))
        best, best_size = None, -1
        for i, p in enumerate(parts):
            if p is not None:
                continue
            if leaf is None:
                best = i
                break
            size = leaf.shape[i]
            if size % max(data_size, 1) == 0 and size > best_size:
                best, best_size = i, size
        if best is None:
            return P(*parts)
        parts[best] = data_axes
        return P(*parts)

    if param_structs is None:
        return jax.tree.map(shard_one, param_specs_tree,
                            is_leaf=lambda x: isinstance(x, P))
    flat_specs, tdef = jax.tree.flatten(
        param_specs_tree, is_leaf=lambda x: isinstance(x, P))
    flat_leaves = tdef.flatten_up_to(param_structs)
    return tdef.unflatten([shard_one(s, leaf)
                           for s, leaf in zip(flat_specs, flat_leaves)])
