"""RWKV-6 (Finch) block: time-mix (WKV recurrence with data-dependent
decay) + channel-mix, attention-free."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from .layers import PDTYPE, _dense_init, norm_init, rmsnorm


def rwkv6_init(cfg: ArchConfig, key):
    d = cfg.d_model
    H = d // cfg.ssm_head_dim
    ks = jax.random.split(key, 12)
    lora = 64
    return {
        "time_mix": {
            # token-shift interpolation weights for r,k,v,w,g
            "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)
                   ).astype(PDTYPE),
            "wr": _dense_init(ks[1], (d, d)),
            "wk": _dense_init(ks[2], (d, d)),
            "wv": _dense_init(ks[3], (d, d)),
            "wg": _dense_init(ks[4], (d, d)),
            # data-dependent decay LoRA: w = base + (tanh(x A) B)
            "w_base": jnp.full((d,), -6.0, jnp.float32),
            "w_A": _dense_init(ks[5], (d, lora)),
            "w_B": _dense_init(ks[6], (lora, d), scale=0.01),
            "u": (jax.random.normal(ks[7], (H, cfg.ssm_head_dim), jnp.float32)
                  * 0.3).astype(jnp.float32),
            "wo": _dense_init(ks[8], (d, d)),
            "ln_x": norm_init(d),
        },
        "chan_mix": {
            "mu": (jax.random.uniform(ks[9], (2, d), jnp.float32)
                   ).astype(PDTYPE),
            "wk": _dense_init(ks[10], (d, cfg.d_ff)),
            "wv": _dense_init(ks[11], (cfg.d_ff, d)),
            "wr": _dense_init(ks[0], (d, d)),
        },
    }


def _token_shift(x, last):
    """shifted = concat(last, x[:-1]); last: (B, 1, d) previous token."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def time_mix_apply(p, cfg: ArchConfig, x, shift, state):
    """x: (B,S,d); shift: (B,1,d) last token of previous chunk;
    state: (B,H,D,D) WKV state.  Returns y, new_shift, new_state."""
    B, S, d = x.shape
    D = cfg.ssm_head_dim
    H = d // D
    xs = _token_shift(x, shift)
    def mix(i):
        return x + (xs - x) * p["mu"][i][None, None]
    r = (mix(0) @ p["wr"]).reshape(B, S, H, D)
    k = (mix(1) @ p["wk"]).reshape(B, S, H, D)
    v = (mix(2) @ p["wv"]).reshape(B, S, H, D)
    g = jax.nn.silu(mix(3) @ p["wg"])
    w_raw = p["w_base"][None, None] + \
        jnp.tanh(mix(4).astype(jnp.float32) @ p["w_A"].astype(jnp.float32)) \
        @ p["w_B"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, S, H, D)         # decay in (0,1)
    y, new_state = ops.rwkv6_scan(r, k, v, w.astype(r.dtype), p["u"], state)
    y = y.reshape(B, S, d)
    y = rmsnorm(y, p["ln_x"]) * g
    return y @ p["wo"], x[:, -1:], new_state


def chan_mix_apply(p, cfg: ArchConfig, x, shift):
    xs = _token_shift(x, shift)
    def mix(i):
        return x + (xs - x) * p["mu"][i][None, None]
    k = jnp.square(jax.nn.relu(mix(0) @ p["wk"]))
    r = jax.nn.sigmoid(mix(1) @ p["wr"])
    return r * (k @ p["wv"]), x[:, -1:]


def rwkv6_cache_init(cfg: ArchConfig, batch, dtype=PDTYPE):
    d = cfg.d_model
    H = d // cfg.ssm_head_dim
    return {
        "tm_shift": jnp.zeros((batch, 1, d), dtype),
        "cm_shift": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_head_dim),
                         jnp.float32),
        "pos": 0,
    }
