"""Mamba-2 (SSD) block for the zamba2 hybrid architecture."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from .layers import PDTYPE, _dense_init, norm_init, rmsnorm


def mamba2_init(cfg: ArchConfig, key):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x, z, B, C, dt]
        "w_in": _dense_init(ks[0], (d, 2 * d_in + 2 * N + H)),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in + 2 * N),
                                     jnp.float32) * 0.2).astype(PDTYPE),
        "A_log": jnp.zeros((H,), jnp.float32) + jnp.log(
            jnp.linspace(1.0, 16.0, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": norm_init(d_in),
        "w_out": _dense_init(ks[2], (d_in, d)),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C);
    state: (B, K-1, C) trailing context or None."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out, new_state


def mamba2_apply(p, cfg: ArchConfig, x, cache=None):
    """x: (B, S, d).  cache: {"conv": (B,K-1,C), "ssd": (B,H,P,N), "pos"}."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    P = cfg.ssm_head_dim
    H = d_in // P
    N = cfg.ssm_state

    zxbcdt = x @ p["w_in"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)       # (B,S,d_in+2N)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, H, P)
    ssd_state = cache["ssd"] if cache is not None else None
    y, new_ssd = ops.mamba2_scan(xh, dtp, A, Bc, Cc, ssd_state)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssd": new_ssd,
                     "pos": cache["pos"] + S}
    return out, new_cache


def mamba2_cache_init(cfg: ArchConfig, batch, dtype=PDTYPE):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * cfg.ssm_state),
                          dtype),
        "ssd": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
        "pos": 0,
    }
