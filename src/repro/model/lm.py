"""Architecture assembly: config -> params / train forward / prefill /
decode, for all 10 assigned families.

Layers are organized as ``n_layers = n_groups * len(layer_pattern)``; the
forward pass scans over groups (keeping HLO size O(pattern), essential for
the 512-device dry-run) and unrolls the pattern within a group.  Pattern
characters:

  G  global attention block        L  sliding-window attention block
  X  attention block + cross-attention (vision memory)
  M  mamba2 block                  H  mamba2 + shared attention (zamba2)
  R  rwkv6 block (time-mix + channel-mix)

Whisper (enc-dec) is assembled from the same blocks but with an explicit
encoder stack and cross-attention decoder.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from . import mamba2 as m2
from . import moe as moe_mod
from . import rwkv6 as r6
from .layers import (AttnSpec, PDTYPE, _dense_init, attn_apply,
                     attn_cache_init, attn_init, mlp_apply, mlp_init,
                     norm_init, rmsnorm)


# ---------------------------------------------------------------------------
# per-position static specs
# ---------------------------------------------------------------------------

def build_specs(cfg: ArchConfig) -> list[AttnSpec]:
    specs = []
    for ch in cfg.layer_pattern:
        if ch == "L":
            specs.append(AttnSpec(window=cfg.sliding_window,
                                  softcap=cfg.attn_logit_softcap,
                                  rope_theta=cfg.rope_theta))
        elif ch in ("G", "X", "H"):
            # gemma3 uses a larger theta for its global layers
            theta = cfg.rope_theta * (50 if cfg.name.startswith("gemma3")
                                      else 1)
            specs.append(AttnSpec(window=None,
                                  softcap=cfg.attn_logit_softcap,
                                  rope_theta=theta))
        else:
            specs.append(AttnSpec())
    return specs


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _block_init(cfg: ArchConfig, kind: str, key):
    ks = jax.random.split(key, 8)
    p = {}
    if kind in ("G", "L", "X", "H"):
        if kind in ("G", "L", "X"):
            p["ln_attn"] = norm_init(cfg.d_model)
            p["attn"] = attn_init(cfg, ks[0])
            p["ln_mlp"] = norm_init(cfg.d_model)
            if cfg.post_norms:
                p["ln_attn_post"] = norm_init(cfg.d_model)
                p["ln_mlp_post"] = norm_init(cfg.d_model)
            if cfg.n_experts:
                p["moe"] = moe_mod.moe_init(cfg, ks[1])
                if cfg.dense_residual:
                    p["mlp"] = mlp_init(cfg, ks[2])
            else:
                p["mlp"] = mlp_init(cfg, ks[2])
        if kind == "X":
            p["ln_xattn"] = norm_init(cfg.d_model)
            p["xattn"] = attn_init(cfg, ks[3])
            p["xattn_gate"] = jnp.zeros((), jnp.float32)
        if kind == "H":
            p["mamba"] = m2.mamba2_init(cfg, ks[4])
            p["ln"] = norm_init(cfg.d_model)
            p["ln_shared_in"] = norm_init(2 * cfg.d_model)
            p["w_shared_in"] = _dense_init(ks[5],
                                           (2 * cfg.d_model, cfg.d_model))
            p["w_shared_out"] = _dense_init(ks[6], (cfg.d_model, cfg.d_model))
    elif kind == "M":
        p["ln"] = norm_init(cfg.d_model)
        p["mamba"] = m2.mamba2_init(cfg, ks[0])
    elif kind == "R":
        p["ln_tm"] = norm_init(cfg.d_model)
        p["ln_cm"] = norm_init(cfg.d_model)
        p["rwkv"] = r6.rwkv6_init(cfg, ks[0])
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    assert n_groups * len(cfg.layer_pattern) == cfg.n_layers, \
        f"{cfg.name}: n_layers {cfg.n_layers} not divisible by pattern"
    params = {
        "embed": _dense_init(ks[0], (cfg.vocab_padded, cfg.d_model),
                             scale=0.02),
        "ln_f": norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[1],
                                        (cfg.d_model, cfg.vocab_padded))

    group_keys = jax.random.split(ks[2], n_groups)

    def one_group(k):
        kk = jax.random.split(k, len(cfg.layer_pattern))
        return [_block_init(cfg, ch, kk[i])
                for i, ch in enumerate(cfg.layer_pattern)]

    groups = [one_group(k) for k in group_keys]
    params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)

    if "H" in cfg.layer_pattern:
        # zamba2: two shared attention+mlp blocks, alternated
        params["shared"] = [
            {"attn": attn_init(cfg, jax.random.fold_in(ks[3], i)),
             "ln_mlp": norm_init(cfg.d_model),
             "mlp": mlp_init(cfg, jax.random.fold_in(ks[4], i))}
            for i in range(2)]
    if cfg.cross_attn_period or cfg.family in ("vlm", "audio"):
        params["frontend_proj"] = _dense_init(
            ks[5], (cfg.frontend_dim, cfg.d_model))
    if cfg.n_enc_layers:
        enc_keys = jax.random.split(ks[6], cfg.n_enc_layers)
        params["encoder"] = [_block_init(cfg, "G", k) for k in enc_keys]
        params["ln_enc"] = norm_init(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _ffn(p, cfg: ArchConfig, h):
    """MLP / MoE / arctic parallel dense+MoE.  Returns (y, aux)."""
    if cfg.n_experts:
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        if cfg.dense_residual:
            y = y + mlp_apply(p["mlp"], cfg, h)
        return y, aux
    return mlp_apply(p["mlp"], cfg, h), 0.0


def _block_apply(p, cfg: ArchConfig, kind: str, spec: AttnSpec, x, *,
                 positions, x0=None, memory=None, cache=None, shared=None,
                 shared_idx=0):
    """One layer.  Returns (x, aux, new_cache)."""
    aux = 0.0
    if kind in ("G", "L", "X"):
        h = rmsnorm(x, p["ln_attn"])
        a, cache = attn_apply(p["attn"], cfg, spec, h, positions=positions,
                              cache=cache)
        if cfg.post_norms:
            a = rmsnorm(a, p["ln_attn_post"])
        x = x + a
        if kind == "X" and memory is not None:
            h = rmsnorm(x, p["ln_xattn"])
            xa, _ = attn_apply(p["xattn"], cfg, spec, h, positions=positions,
                               kv_from=memory)
            x = x + jnp.tanh(p["xattn_gate"]).astype(x.dtype) * xa
        h = rmsnorm(x, p["ln_mlp"])
        f, aux = _ffn(p, cfg, h)
        if cfg.post_norms:
            f = rmsnorm(f, p["ln_mlp_post"])
        x = x + f
    elif kind == "M":
        h = rmsnorm(x, p["ln"])
        y, cache = m2.mamba2_apply(p["mamba"], cfg, h, cache)
        x = x + y
    elif kind == "H":
        h = rmsnorm(x, p["ln"])
        mcache = cache["mamba"] if cache is not None else None
        y, mcache = m2.mamba2_apply(p["mamba"], cfg, h, mcache)
        x = x + y
        # shared attention block over concat(hidden, initial embeddings) —
        # the zamba2 skip stream (a reconvergent path in the task graph)
        sb = shared[shared_idx]
        acache = cache["attn"] if cache is not None else None
        hin = jnp.concatenate([x, x0], axis=-1)
        hin = rmsnorm(hin, p["ln_shared_in"]) @ p["w_shared_in"]
        a, acache = attn_apply(sb["attn"], cfg, spec, hin,
                               positions=positions, cache=acache)
        a = a + mlp_apply(sb["mlp"], cfg, rmsnorm(a, sb["ln_mlp"]))
        x = x + a @ p["w_shared_out"]
        if cache is not None:
            cache = {"mamba": mcache, "attn": acache}
    elif kind == "R":
        tm_shift = cache["tm_shift"] if cache is not None else \
            jnp.zeros_like(x[:, :1])
        cm_shift = cache["cm_shift"] if cache is not None else \
            jnp.zeros_like(x[:, :1])
        wkv = cache["wkv"] if cache is not None else None
        h = rmsnorm(x, p["ln_tm"])
        y, new_tm, wkv = r6.time_mix_apply(p["rwkv"]["time_mix"], cfg, h,
                                           tm_shift, wkv)
        x = x + y
        h = rmsnorm(x, p["ln_cm"])
        y, new_cm = r6.chan_mix_apply(p["rwkv"]["chan_mix"], cfg, h, cm_shift)
        x = x + y
        if cache is not None:
            cache = {"tm_shift": new_tm, "cm_shift": new_cm, "wkv": wkv,
                     "pos": cache["pos"] + x.shape[1]}
    return x, aux, cache


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens):
    B, S = tokens.shape
    x = ops.burst_gather(params["embed"], tokens.reshape(-1))
    x = x.reshape(B, S, cfg.d_model)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _encode(params, cfg: ArchConfig, frames):
    """Whisper encoder over (stub) frame embeddings."""
    x = (frames @ params["frontend_proj"]).astype(PDTYPE)
    spec = AttnSpec(causal=False, rope_theta=cfg.rope_theta)
    positions = jnp.arange(x.shape[1])
    for p in params["encoder"]:
        x, _, _ = _block_apply(p, cfg, "G", spec, x, positions=positions)
    return rmsnorm(x, params["ln_enc"])


def _memory(params, cfg: ArchConfig, extra):
    if cfg.n_enc_layers and extra is not None and "frames" in extra:
        return _encode(params, cfg, extra["frames"])
    if extra is not None and "vision" in extra:
        return (extra["vision"] @ params["frontend_proj"]).astype(PDTYPE)
    return None


def apply_group(gp, cfg: ArchConfig, specs, x, *, positions, x0=None,
                memory=None, shared=None, caches=None):
    """Apply one layer-group (len(cfg.layer_pattern) blocks, unrolled).
    caches: per-position cache list or None.  Returns (x, aux, caches)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None
    h_idx = 0
    for i, ch in enumerate(cfg.layer_pattern):
        ci = caches[i] if caches is not None else None
        x, a, ci = _block_apply(gp[i], cfg, ch, specs[i], x,
                                positions=positions, x0=x0, memory=memory,
                                cache=ci, shared=shared,
                                shared_idx=h_idx % 2)
        if ch == "H":
            h_idx += 1
        aux = aux + a
        if new_caches is not None:
            new_caches.append(ci)
    return x, aux, new_caches


def lm_head(params, cfg: ArchConfig, x):
    """Final norm + (tied) LM head + optional softcap.  Returns logits over
    the PADDED vocab with pad rows masked to -inf (shard-friendly)."""
    x = rmsnorm(x, params["ln_f"])
    logits = x @ (params["embed"].T.astype(x.dtype)
                  if cfg.tie_embeddings else params["lm_head"])
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                           logits)
    return logits


def chunked_ce(params, cfg: ArchConfig, x, targets, mask=None, *,
               n_chunks: int = 8):
    """Memory-bounded cross entropy: the (tokens, vocab) logits tensor is
    materialized one chunk at a time (vital for 256k vocabularies).

    The chunk loop is unrolled (fixed ``n_chunks``) rather than scanned:
    fp32 logits + an unrolled loop keep the TP all-reduces out of while
    bodies, dodging an XLA:CPU AllReducePromotion crash, and give XLA more
    freedom to overlap the head matmuls."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    tf = targets.reshape(T)
    mf = (mask.reshape(T).astype(jnp.float32) if mask is not None
          else jnp.ones((T,), jnp.float32))
    chunk = max(-(-T // n_chunks), 1)
    Tp = chunk * n_chunks
    xf = jnp.pad(xf, ((0, Tp - T), (0, 0)))
    tf = jnp.pad(tf, (0, Tp - T))
    mf = jnp.pad(mf, (0, Tp - T))

    total = jnp.zeros((), jnp.float32)
    for c in range(n_chunks):
        xc = xf[c * chunk:(c + 1) * chunk]
        tc = tf[c * chunk:(c + 1) * chunk]
        mc = mf[c * chunk:(c + 1) * chunk]
        # fp32 logits: better CE numerics, f32 TP all-reduces
        lg = lm_head(params, cfg, xc[None].astype(jnp.float32))[0]
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, tc[:, None], axis=-1)[:, 0]
        total = total + ((logz - ll) * mc).sum()
    return total / jnp.maximum(mf.sum(), 1.0)


def forward(params, cfg: ArchConfig, tokens, *, extra=None,
            remat: bool = False):
    """Training/prefill-style full-sequence forward -> logits (B, S, V)."""
    specs = build_specs(cfg)
    x = _embed(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])
    memory = _memory(params, cfg, extra)
    shared = params.get("shared")
    x0 = x

    def group_fn(carry, gp):
        x, aux = carry
        h_idx = 0
        for i, ch in enumerate(cfg.layer_pattern):
            x, a, _ = _block_apply(
                gp[i], cfg, ch, specs[i], x,
                positions=positions, x0=x0, memory=memory, shared=shared,
                shared_idx=h_idx % 2)
            if ch == "H":
                h_idx += 1
            aux = aux + a
        return (x, aux), None

    if remat:
        group_fn = jax.checkpoint(group_fn)
    (x, aux), _ = jax.lax.scan(group_fn, (x, jnp.zeros((), jnp.float32)),
                               params["groups"])
    logits = lm_head(params, cfg, x)[..., :cfg.vocab]
    return logits, aux


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = False):
    """Next-token CE + MoE aux loss.  batch: {tokens, (extra)}."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens, extra=batch.get("extra"),
                          remat=remat)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - ll).mean()
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ArchConfig, batch, max_seq, extra=None):
    specs = build_specs(cfg)
    n_groups = cfg.n_layers // len(cfg.layer_pattern)

    def one(spec, ch):
        if ch in ("G", "L", "X"):
            return attn_cache_init(cfg, spec, batch, max_seq)
        if ch == "M":
            return m2.mamba2_cache_init(cfg, batch)
        if ch == "H":
            return {"mamba": m2.mamba2_cache_init(cfg, batch),
                    "attn": attn_cache_init(cfg, specs[0], batch, max_seq)}
        if ch == "R":
            return r6.rwkv6_cache_init(cfg, batch)
        raise ValueError(ch)

    group_cache = [one(specs[i], ch)
                   for i, ch in enumerate(cfg.layer_pattern)]
    # lift python-int "pos" fields into arrays, then stack across groups
    group_cache = jax.tree.map(jnp.asarray, group_cache)
    stacked = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n_groups,) + t.shape),
        group_cache)
    mem = {"memory": _memory(params, cfg, extra)} if extra else {}
    return {"groups": stacked, "pos": jnp.zeros((), jnp.int32), **mem}


def step(params, cfg: ArchConfig, cache, tokens, *, unroll: bool = False):
    """Prefill (S>=1) or decode (S=1) step -> (logits_last, new_cache)."""
    specs = build_specs(cfg)
    x = _embed(params, cfg, tokens)
    S = tokens.shape[1]
    pos0 = cache["pos"]
    positions = pos0 + jnp.arange(S)
    memory = cache.get("memory")
    shared = params.get("shared")
    x0 = x

    def group_fn(carry, scanned):
        x, aux = carry
        gp, gc = scanned
        new_gc = []
        h_idx = 0
        for i, ch in enumerate(cfg.layer_pattern):
            ci = _with_pos(gc[i], pos0)
            x, a, ci = _block_apply(gp[i], cfg, ch, specs[i], x,
                                    positions=positions, x0=x0,
                                    memory=memory, cache=ci, shared=shared,
                                    shared_idx=h_idx % 2)
            if ch == "H":
                h_idx += 1
            new_gc.append(ci)
            aux = aux + a
        return (x, aux), new_gc

    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    (x, _), new_groups = jax.lax.scan(
        group_fn, (x, jnp.zeros((), jnp.float32)),
        (params["groups"], cache["groups"]),
        unroll=n_groups if unroll else 1)
    logits = lm_head(params, cfg, x[:, -1:])[:, 0]
    new_cache = dict(cache)
    new_cache["groups"] = new_groups
    new_cache["pos"] = pos0 + S
    return logits, new_cache


def _with_pos(cache_leaf, pos):
    """Replace per-layer 'pos' scalars with the global position counter
    (kept once at top level to avoid per-layer bookkeeping)."""
    def fix(d):
        if isinstance(d, dict):
            out = {k: fix(v) for k, v in d.items()}
            if "pos" in out:
                out["pos"] = pos
            return out
        return d
    return fix(cache_leaf)
