"""Core layers (pure functional JAX; params are plain pytrees).

Everything is bf16 by default with fp32 norms/softmax internals.  The
attention / SSM / MoE hot spots route through ``repro.kernels.ops`` so the
Pallas TPU kernels and the jnp references are interchangeable.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops

PDTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(PDTYPE)


def norm_init(d):
    return {"w": jnp.ones((d,), jnp.float32)}


def rmsnorm(x, p, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * p["w"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_tables(positions, dim, theta):
    """cos/sin tables: positions (...,) -> (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, style="neox"):
    """x: (B, S, H, D); cos/sin: (S, rot_dim//2) or (B, S, rot//2).

    "neox": rotate over the full head dim (half-split layout).
    "partial": chatglm-style 2d RoPE — rotary on the first half of the head
    dim only (interleaved pairs), rest passes through.
    """
    if style == "none" or style == "learned":
        return x
    D = x.shape[-1]
    rot = D if style == "neox" else D // 2
    xr, xp = x[..., :rot], x[..., rot:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]      # (1, S, 1, rot//2)
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    if style == "partial":
        # interleaved pairs (x0,x1), (x2,x3), ...
        x1 = xr[..., 0::2]
        x2 = xr[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        rotated = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    else:
        half = rot // 2
        x1, x2 = xr[..., :half], xr[..., half:]
        rotated = jnp.concatenate([x1 * cos - x2 * sin,
                                   x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1) \
        if rot < D else rotated.astype(x.dtype)


def rope_halfdim(cfg: ArchConfig) -> int:
    rot = cfg.head_dim if cfg.rope_style == "neox" else cfg.head_dim // 2
    return rot // 2


# ---------------------------------------------------------------------------
# attention layer (GQA; optional sliding window / softcap / qk-norm)
# ---------------------------------------------------------------------------

def attn_init(cfg: ArchConfig, key, cross=False):
    ks = jax.random.split(key, 6)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": _dense_init(ks[0], (d, qd)),
        "wk": _dense_init(ks[1], (d, kvd)),
        "wv": _dense_init(ks[2], (d, kvd)),
        "wo": _dense_init(ks[3], (qd, d)),
    }
    return p


@dataclasses.dataclass
class AttnSpec:
    """Static per-layer attention behaviour."""
    window: int | None = None
    softcap: float | None = None
    rope_theta: float = 10_000.0
    causal: bool = True


def attn_apply(p, cfg: ArchConfig, spec: AttnSpec, x, *, positions,
               cache=None, kv_from=None, kv_len=None):
    """x: (B, S, d).  cache: optional dict(k, v, pos) for decode.
    kv_from: cross-attention memory (B, Sm, d) — overrides self-KV."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, D)
    src = x if kv_from is None else kv_from
    Skv = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Skv, Hkv, D)
    v = (src @ p["wv"]).reshape(B, Skv, Hkv, D)

    scale = cfg.query_scale
    if kv_from is None:
        cos, sin = rope_tables(positions, cfg.head_dim if cfg.rope_style ==
                               "neox" else cfg.head_dim // 2, spec.rope_theta)
        q = apply_rope(q, cos, sin, cfg.rope_style)
        k = apply_rope(k, cos, sin, cfg.rope_style)

    if cache is not None and S > 1:
        # prefill from scratch (pos assumed 0): full attention, then store
        # the last W tokens ring-aligned (token t lives at slot t % W)
        out = ops.attention(q, k, v, causal=spec.causal, window=spec.window,
                            softcap=spec.softcap, scale=scale)
        ck, cv = cache["k"], cache["v"]
        W = ck.shape[1]
        if S >= W:
            slots = (jnp.arange(W) + (S - W)) % W
            ck = jnp.zeros_like(ck).at[:, slots].set(
                k[:, S - W:].astype(ck.dtype))
            cv = jnp.zeros_like(cv).at[:, slots].set(
                v[:, S - W:].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, 0, 0, 0))
        cache = {"k": ck, "v": cv, "pos": cache["pos"] + S}
    elif cache is not None:
        # decode: append k/v at cache["pos"] (ring-buffered for local layers)
        ck, cv, pos = cache["k"], cache["v"], cache["pos"]
        W = ck.shape[1]
        slot = pos if spec.window is None else pos % W
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        cache = {"k": ck, "v": cv, "pos": pos + S}
        k, v = ck, cv
        if spec.window is None:
            kv_len = jnp.full((B,), pos + S) if kv_len is None else kv_len
            out = ops.attention(q, k, v, causal=False, softcap=spec.softcap,
                                scale=scale, q_offset=pos, kv_len=kv_len)
        else:
            # ring buffer: valid entries = min(pos + S, W); no causal mask
            # needed (all cached tokens precede the query)
            valid = jnp.minimum(pos + S, W)
            out = ops.attention(q, k, v, causal=False, softcap=spec.softcap,
                                scale=scale,
                                kv_len=jnp.full((B,), valid))
    else:
        out = ops.attention(q, k, v, causal=spec.causal and kv_from is None,
                            window=spec.window, softcap=spec.softcap,
                            scale=scale, kv_len=kv_len)
    y = out.reshape(B, S, H * D) @ p["wo"]
    return y, cache


def attn_cache_init(cfg: ArchConfig, spec: AttnSpec, batch, max_seq,
                    dtype=PDTYPE):
    W = max_seq if spec.window is None else min(spec.window, max_seq)
    shape = (batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": 0}


# ---------------------------------------------------------------------------
# MLP (gated SiLU/GELU)
# ---------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[0], (cfg.d_model, d_ff)),
         "w_down": _dense_init(ks[1], (d_ff, cfg.d_model))}
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[2], (cfg.d_model, d_ff))
    return p


def mlp_apply(p, cfg: ArchConfig, x):
    act = jax.nn.silu if cfg.mlp_act == "silu" else \
        (lambda a: jax.nn.gelu(a, approximate=True))
    up = x @ p["w_up"]
    if cfg.gated_mlp:
        up = act(x @ p["w_gate"]) * up
    else:
        up = act(up)
    return up @ p["w_down"]
