"""Model zoo: layers + assembly for the 10 assigned architectures."""
