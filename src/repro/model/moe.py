"""Mixture-of-Experts FFN: top-k router + expert MLPs (+ arctic's dense
residual branch), with expert-parallel sharding in mind.

Dense-compute formulation: every token computes only its top-k experts via
a dispatch/combine einsum (reference) or the grouped-matmul Pallas kernel.
The dispatch tensors are laid out so GSPMD turns them into all-to-alls on
the expert axis when experts are sharded (EP = the paper's HBM channel
binding analogue: experts are bound to mesh slots by the floorplanner).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import _dense_init


def moe_init(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {
        "router": _dense_init(ks[0], (d, e), scale=0.02).astype(jnp.float32),
        "w_up": _dense_init(ks[1], (e, d, f)),
        "w_down": _dense_init(ks[2], (e, f, d)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[3], (e, d, f))
    return p


def moe_apply(p, cfg: ArchConfig, x):
    """x: (B, S, d) -> (y, aux_loss).

    Dropless top-k routing: probabilities renormalized over the selected
    experts; auxiliary load-balancing loss (Switch-style).
    """
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(B * S, d)

    logits = (xf.astype(jnp.float32) @ p["router"])           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # dispatch one-hot: (T, k, E) -> combine weights (T, E)
    onehot = jax.nn.one_hot(top_i, e, dtype=xf.dtype)          # (T, k, E)
    combine = (onehot * top_p[..., None].astype(xf.dtype)).sum(1)  # (T, E)

    # expert compute (dense dispatch einsum — GSPMD shards over E)
    xe = jnp.einsum("te,td->etd", (combine > 0).astype(xf.dtype), xf)
    up = jnp.einsum("etd,edf->etf", xe, p["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("etd,edf->etf", xe, p["w_gate"])
        up = jax.nn.silu(gate) * up
    else:
        up = jax.nn.silu(up)
    ye = jnp.einsum("etf,efd->etd", up, p["w_down"])           # (E, T, d)
    y = jnp.einsum("etd,te->td", ye, combine)

    # load-balance aux loss: E * sum_e (fraction routed * mean prob)
    frac = (onehot.sum(1)).mean(0)                             # (E,)
    mean_p = probs.mean(0)
    aux = e * jnp.sum(frac.astype(jnp.float32) * mean_p)
    return y.reshape(B, S, d), aux
