"""Stdlib-only markdown link/anchor checker for the docs CI job.

Checks every inline markdown link ``[text](target)`` in the given files:

* relative file targets must exist (resolved against the linking file);
* ``#anchor`` fragments — bare or on a relative target — must match a
  heading in the target file, using GitHub's slugification (lowercase,
  spaces to hyphens, punctuation stripped, ``-N`` suffixes for repeats);
* external ``http(s)``/``mailto`` targets are skipped (no network in CI).

Usage:
    python docs/check_links.py README.md docs/*.md

Exits nonzero listing every broken link.  No dependencies beyond the
standard library, by design: the container and the docs job install
nothing for it.
"""
from __future__ import annotations

import pathlib
import re
import sys

#: inline links; [text](target) with no nesting, images included
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str, seen: dict[str, int]) -> str:
    """GitHub's anchor slug for a heading text (with repeat suffixes)."""
    # drop inline code/emphasis markers, then non-word punctuation
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    slug = text.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def anchors_of(path: pathlib.Path) -> set[str]:
    seen: dict[str, int] = {}
    out: set[str] = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            out.add(github_slug(m.group(2), seen))
    return out


def links_of(path: pathlib.Path) -> list[str]:
    out: list[str] = []
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        out.extend(m.group(1) for m in LINK_RE.finditer(line))
    return out


def check(files: list[str]) -> list[str]:
    errors: list[str] = []
    for name in files:
        src = pathlib.Path(name)
        if not src.is_file():
            errors.append(f"{name}: file not found")
            continue
        for target in links_of(src):
            if re.match(r"^(https?|mailto):", target):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            dest = src if not target else (src.parent / target)
            if not dest.exists():
                errors.append(f"{src}: broken link -> {target}")
                continue
            if frag is not None:
                if dest.is_dir() or dest.suffix.lower() not in (".md", ""):
                    continue
                if frag not in anchors_of(dest):
                    errors.append(
                        f"{src}: missing anchor -> {target or dest.name}"
                        f"#{frag}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]")
        return 2
    errors = check(argv)
    if errors:
        print(f"BROKEN LINKS ({len(errors)}):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"OK: {len(argv)} file(s), all links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
