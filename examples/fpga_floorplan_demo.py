"""Reproduce one paper benchmark end to end: the CNN 13x8 accelerator on
U250 — baseline packed flow vs TAPA co-optimization, with the multi-
floorplan explorer (paper §6.3) on top.

  PYTHONPATH=src python examples/fpga_floorplan_demo.py
"""
from repro.core import (analyze_timing, autobridge, best_candidate,
                        explore_floorplans, packed_placement)
from repro.fpga import benchmarks as B, u250_grid

graph = B.cnn(8)
grid = u250_grid()
print(f"CNN 13x8: {graph.num_tasks} tasks, {graph.num_streams} streams")

base = analyze_timing(graph, grid, packed_placement(graph, grid))
print(f"baseline: "
      f"{'%.0f MHz' % base.fmax_mhz if base.routed else 'UNROUTABLE'}"
      f"{'' if base.routed else ' (' + base.fail_reason[:60] + ')'}")

plan = None
for u in (0.7, 0.75, 0.8):          # the paper's §6.3 utilization knob
    try:
        plan = autobridge(graph, grid, max_util=u)
        break
    except Exception:
        continue
opt = analyze_timing(graph, grid, plan.floorplan.placement, plan.depth)
print(f"TAPA:     {opt.fmax_mhz:.0f} MHz "
      f"(crossing cost {plan.floorplan.cost:.0f}, "
      f"buffer overhead {plan.area_overhead:.0f} bits)")

cands = explore_floorplans(graph, grid, utils=(0.7, 0.75, 0.8))
print("multi-floorplan:", ["%.0f" % c.fmax for c in cands], "MHz ->",
      f"best {best_candidate(cands).fmax:.0f} MHz")
