"""End-to-end training example: a reduced granite-8b (llama-family) LM
trained for a few hundred steps on the synthetic corpus; loss must drop.

  PYTHONPATH=src python examples/train_tinylm.py
"""
import subprocess
import sys

subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "granite-8b", "--reduced", "--steps", "200",
                "--batch", "8", "--seq", "128", "--ckpt-dir",
                "/tmp/repro_tinylm_ckpt"], check=True)
