"""Quickstart: the TAPA co-optimization in 50 lines.

Builds a task-parallel dataflow program with the builder API (paper
Listing 1), floorplans it onto the U280 grid, pipelines + balances the
cross-slot streams, compares modeled frequency against the default packed
flow, and finishes with the joint design-space search (paper §6.3
generalized): seed x max-util x boundary-weight x depth-scale candidates,
throughput-scored in batched simulator calls and Pareto-pruned.

  PYTHONPATH=src python examples/quickstart.py
"""
import multiprocessing

from repro.core import (TaskGraphBuilder, analyze_timing, autobridge,
                        floorplan_counts, packed_placement,
                        reset_floorplan_counts)
from repro.fpga import tpu_pod_grid, u250_grid, u280_grid
# repro.search is the search subsystem's public entry point (repro.core
# re-exports these names too, for backward compatibility)
from repro.search import (Interval, SearchSpace, explore_design_space,
                          search_until_converged, sweep_backends)

# --- VecAdd from the paper's Listing 1: 4 PEs, Load/Add/Store each -------
PE = 4
b = TaskGraphBuilder("VecAdd")
a = b.streams("str_a", n=PE, width=512)
bb = b.streams("str_b", n=PE, width=512)
c = b.streams("str_c", n=PE, width=512)
b.invoke("LoadA", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
         outs=a, count=PE)
b.invoke("LoadB", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
         outs=bb, count=PE)
b.invoke("Add", area={"LUT": 60e3, "DSP": 256}, ins=a + bb, outs=c, count=PE)
b.invoke("Store", area={"LUT": 12e3, "hbm_channels": 1}, ins=c, count=PE)
graph = b.build()

grid = u280_grid()
plan = autobridge(graph, grid)
print("placement:", plan.floorplan.placement)
print("stream depths (pipelining + balancing):", plan.depth)

base = analyze_timing(graph, grid, packed_placement(graph, grid))
opt = analyze_timing(graph, grid, plan.floorplan.placement, plan.depth)
print(f"baseline flow: {base.fmax_mhz:.0f} MHz "
      f"({'routed' if base.routed else 'UNROUTABLE: ' + base.fail_reason})")
print(f"TAPA flow:     {opt.fmax_mhz:.0f} MHz")

# throughput preservation (paper §5): cycle counts with and without depth,
# both variants in one batched (vectorized) simulator call
base_sim, opt_sim = plan.verify_throughput(firings=500)
print(f"cycles: {base_sim.cycles} -> {opt_sim.cycles} "
      f"(+{opt_sim.cycles - base_sim.cycles} fill/drain only)")

# joint design-space search (paper §6.3 "implement all candidates in
# parallel", generalized to seed x util x boundary-weight x depth-scale):
# all feasible candidates are throughput-scored in one simulate_batch call,
# then pruned to the Pareto frontier over (fmax, area, cycles).  With
# fifo_sizing, frontier FIFOs are re-sized from observed occupancy.
space = SearchSpace(seeds=(0, 1), utils=(0.6, 0.7, 0.8),
                    row_weights=(1.0, 2.0), depth_scales=(1.0, 2.0))
result = explore_design_space(graph, grid, space=space, sim_firings=200,
                              fifo_sizing=True)
print(f"search: {result.space_size} joint configs, "
      f"{result.sim_calls} simulate_batch calls, "
      f"frontier {len(result.frontier)}")
best = result.best
print(f"best: {best.fmax:.0f} MHz at util={best.point.max_util} "
      f"depth_scale={best.point.depth_scale} "
      f"(throughput preserved: {best.throughput_preserved}, "
      f"FIFO bits saved by profile-driven sizing: {best.fifo_savings_bits:.0f})")

# converging search, in parallel: continuous knob ranges instead of value
# lists, and the refine -> search loop closed automatically — each round
# re-anchors on the incumbent Pareto frontier and narrows the ranges around
# it, stopping when the frontier's hypervolume stops improving.  The
# baseline simulation runs once (round 1) and every round shares one
# FloorplanCache, so re-anchored configurations skip the ILP solve —
# floorplan_counts() proves it.  jobs=2 fans each round's COLD solves over
# a process pool (repro.search.pool): workers ship their caches and counter
# deltas back, the round replays against the merged cache, and the frontier
# is bit-identical to a sequential run — only the ILP wall time shrinks.
reset_floorplan_counts()
# this script has no __main__ guard, so only fork-capable platforms may use
# worker processes (spawn would re-execute the whole script per worker);
# jobs=1 is the exact same search, just sequential.
jobs = 2 if "fork" in multiprocessing.get_all_start_methods() else 1
conv = search_until_converged(
    graph, grid,
    space=SearchSpace(seeds=(0, 1), utils=Interval(0.6, 0.9),
                      row_weights=Interval(1.0, 2.0),
                      depth_scales=(1.0, 2.0)),
    rounds=4, tol=0.02, points_per_round=16, sim_firings=200, jobs=jobs)
fc = floorplan_counts()
print(f"converged search: {conv.rounds_run} rounds "
      f"({'converged' if conv.converged else 'budget exhausted'}), "
      f"{conv.points_evaluated} points, frontier {len(conv.frontier)}, "
      f"hypervolume {' -> '.join(f'{h:.3g}' for h in conv.hypervolumes)}")
pool_note = (f"{conv.pool.worker_solves} solved by {conv.pool.jobs} pool "
             f"workers, {conv.pool.merged}/{conv.pool.dispatched} merged"
             if conv.pool else "sequential solve path")
print(f"floorplans: {fc['solved']} solved, {fc['cache_hits']} cache hits "
      f"({fc['ilp_bipartitions']} ILP bipartitions total; {pool_note})")
cbest = conv.best
print(f"converged best: {cbest.fmax:.0f} MHz at "
      f"util={cbest.point.max_util:.3f} (>= single-round best: "
      f"{cbest.fmax >= best.fmax})")

# multi-device sweep: the same design searched across U250, U280 and a
# TPU-pod-shaped grid — every grid's candidates are throughput-scored in a
# SINGLE batched simulator call (the padded ragged-batch backend covers the
# grids' heterogeneous candidate sets in one array-sweep).
sweep = sweep_backends(graph, {"u250": u250_grid(), "u280": u280_grid(),
                               "tpu_2x2": tpu_pod_grid(2, 2)},
                       space=SearchSpace(utils=(0.6, 0.7, 0.8)),
                       sim_firings=200)
for row in sweep.table():
    print(f"sweep[{row['grid']}]: "
          + (f"{row['fmax_mhz']:.0f} MHz, cycles={row['cycles']}, "
             f"overhead={row['area_overhead_bits']:.0f} bits"
             if row["routable"] else "UNROUTABLE"))
dev, champ = sweep.best
print(f"best device: {dev} at {champ.fmax:.0f} MHz "
      f"({sweep.sim_calls} batched simulator call(s) for all devices)")
