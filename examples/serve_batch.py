"""Batched serving example: prefill + greedy decode with KV caches on the
reduced gemma3 (sliding-window ring caches exercised).

  PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys

for arch in ("gemma3-12b", "rwkv6-1.6b"):
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "48", "--gen", "12"], check=True)
