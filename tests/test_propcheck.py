"""Fallback-shim shrinking tests (skipped when real hypothesis is
installed — it has its own shrinker and these internals don't exist)."""
import pytest

import _propcheck as pc

pytestmark = pytest.mark.skipif(
    pc.HAVE_HYPOTHESIS, reason="real hypothesis shrinks natively")


def _falsify(fn):
    """Run a @given-wrapped test expected to fail; return the exception."""
    with pytest.raises(AssertionError) as e:
        fn()
    return e.value


def test_integers_shrink_toward_zero():
    seen = []

    @pc.settings(max_examples=20)
    @pc.given(pc.strategies.integers(0, 10_000))
    def prop(n):
        seen.append(n)
        assert n < 137

    _falsify(prop)
    # the minimal falsifying example was actually executed
    assert min(x for x in seen if x >= 137) == 137


def test_integers_shrink_respects_min_value():
    seen = []

    @pc.settings(max_examples=20)
    @pc.given(pc.strategies.integers(50, 10_000))
    def prop(n):
        seen.append(n)
        assert False  # everything fails -> shrink to the range floor

    _falsify(prop)
    assert min(seen) == 50


def test_lists_shrink_by_halving_and_element_shrinks():
    seen = []

    @pc.settings(max_examples=20)
    @pc.given(pc.strategies.lists(pc.strategies.integers(0, 9),
                                  min_size=0, max_size=8))
    def prop(xs):
        seen.append(list(xs))
        assert sum(xs) < 10

    _falsify(prop)
    failing = [xs for xs in seen if sum(xs) >= 10]
    smallest = min(failing, key=lambda xs: (len(xs), sum(xs)))
    # greedy halving + element shrinking reaches a short, barely-failing
    # list — not the long random one that first falsified
    assert len(smallest) <= 3
    assert sum(smallest) < 20


def test_lists_shrink_respects_min_size():
    @pc.settings(max_examples=5)
    @pc.given(pc.strategies.lists(pc.strategies.integers(0, 3),
                                  min_size=2, max_size=6))
    def prop(xs):
        assert len(xs) >= 2  # holds by construction, even while shrinking

    prop()


def test_shrunk_counterexample_is_reported(capsys):
    @pc.settings(max_examples=10)
    @pc.given(pc.strategies.integers(0, 1000), pc.strategies.booleans())
    def prop(n, flag):
        assert n < 500 or not flag

    _falsify(prop)
    out = capsys.readouterr().out
    assert "falsifying example" in out
    assert "shrunk to" in out
    # the shrunk report ends at the greedy minimum: (500, True)
    assert "(500, True)" in out


def test_sampled_from_shrinks_to_earlier_elements():
    seen = []

    @pc.settings(max_examples=10)
    @pc.given(pc.strategies.sampled_from(["a", "b", "c", "d"]))
    def prop(x):
        seen.append(x)
        assert x == "a"

    _falsify(prop)
    assert "b" in seen  # an edge example fails...
    # ...and shrinking never invents values outside the sample set
    assert set(seen) <= {"a", "b", "c", "d"}


def test_passing_property_never_shrinks():
    calls = []

    @pc.settings(max_examples=15)
    @pc.given(pc.strategies.integers(0, 9))
    def prop(n):
        calls.append(n)
        assert 0 <= n <= 9

    prop()
    assert len(calls) == 15


def test_skip_during_shrinking_does_not_mask_failure():
    """A pytest.skip hit on a shrink candidate counts as 'invalid input,
    keep shrinking' — the original falsifying failure must still surface
    as a failure, not a skip.  (A skip on a *detection* example still
    propagates, like real hypothesis.)  The skip band [400, 600] is never
    drawn as an edge example but shrinking from 10000 walks into it."""
    skipped_at = []

    @pc.settings(max_examples=2)  # edges only: 0 passes, 10000 fails
    @pc.given(pc.strategies.integers(0, 10_000))
    def prop(n):
        if 400 <= n <= 600:
            skipped_at.append(n)
            pytest.skip("invalid region")
        assert n <= 900

    _falsify(prop)  # AssertionError, not Skipped
    assert skipped_at  # shrinking really did enter the skip band
