"""Multi-device sweeps, adaptive refinement, the FIFO-sizing area credit,
and the cross-design benchmark batching acceptance.

Covers the new one-call sweep path: ``prepare_design_space`` defers
simulation, ``sweep_backends`` scores several device grids' candidates in
one batched call, ``SearchSpace.refine`` zooms sampling into the frontier
neighborhood, ``analyze_timing(buffer_bits=...)`` charges buffering into
slot utilization (so profile-driven FIFO sizing credits reclaimed bits
back as fmax), and the fmax suite's simulation phase is a single padded
array-sweep across heterogeneous designs.
"""
import importlib.util
import os

import pytest

from repro.core import (PhysicalModel, SearchPoint, SearchSpace,
                        TaskGraphBuilder, analyze_timing,
                        explore_design_space, sweep_backends)
from repro.core import explorer as explorer_mod
from repro.core.simulate import _jax_ready
from repro.fpga import grid_for, tpu_pod_grid, u250_grid, u280_grid

jax_only = pytest.mark.skipif(not _jax_ready(), reason="jax not installed")


def _vecadd(pe=4):
    b = TaskGraphBuilder("VecAdd")
    a = b.streams("str_a", n=pe, width=512)
    bb = b.streams("str_b", n=pe, width=512)
    c = b.streams("str_c", n=pe, width=512)
    b.invoke("LoadA", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=a, count=pe)
    b.invoke("LoadB", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=bb, count=pe)
    b.invoke("Add", area={"LUT": 60e3, "DSP": 256}, ins=a + bb, outs=c,
             count=pe)
    b.invoke("Store", area={"LUT": 12e3, "hbm_channels": 1}, ins=c, count=pe)
    return b.build()


# ---------------------------------------------------------------------------
# sweep_backends: one batched call across device grids
# ---------------------------------------------------------------------------


def test_sweep_backends_single_batched_call(monkeypatch):
    """U250 + U280 + a TPU-pod grid: every grid's baseline + candidates
    scored by exactly one ``simulate_batch`` call; per-grid results match
    a standalone ``explore_design_space`` run."""
    graph = _vecadd()
    space = SearchSpace(utils=(0.7, 0.8))
    calls = []
    real_batch = explorer_mod.simulate_batch

    def counting_batch(jobs, **kw):
        jobs = list(jobs)
        calls.append(len(jobs))
        return real_batch(jobs, **kw)

    monkeypatch.setattr(explorer_mod, "simulate_batch", counting_batch)
    grids = {"u250": u250_grid(), "u280": u280_grid(),
             "tpu": tpu_pod_grid(2, 2)}
    sweep = sweep_backends(graph, grids, space=space, sim_firings=80)
    assert len(calls) == 1 and sweep.sim_calls == 1
    assert set(sweep.results) == set(grids)
    for name, res in sweep.results.items():
        assert res.space_size == space.size
        for c in res.candidates:
            if c.plan is not None:
                assert c.sim is not None and c.base_sim is not None
        # matches a standalone per-grid search (same knobs, own batch call)
        solo = explore_design_space(graph, grids[name], space=space,
                                    sim_firings=80)
        assert [c.fmax for c in res.candidates] == \
            [c.fmax for c in solo.candidates]
        assert [(c.sim.cycles, c.sim.deadlocked)
                for c in res.candidates if c.sim] == \
            [(c.sim.cycles, c.sim.deadlocked)
             for c in solo.candidates if c.sim]
    name, best = sweep.best
    assert name in grids and best.report.routed
    rows = sweep.table()
    assert {r["grid"] for r in rows} == set(grids)
    assert all(r["fmax_mhz"] > 0 for r in rows if r["routable"])


def test_sweep_backends_accepts_grid_sequences():
    graph = _vecadd()
    sweep = sweep_backends(graph, [u280_grid(), u280_grid()],
                           space=SearchSpace(utils=(0.8,)), sim_firings=40)
    assert set(sweep.results) == {"U280", "U280#2"}
    with pytest.raises(ValueError):
        sweep_backends(graph, [], sim_firings=40)


def test_device_grid_registry():
    assert grid_for("u250").name == "U250"
    assert grid_for("tpu_pod_4x2").rows == 4
    with pytest.raises(KeyError):
        grid_for("nonesuch")


# ---------------------------------------------------------------------------
# SearchSpace.refine
# ---------------------------------------------------------------------------


def test_refine_zooms_into_frontier_neighborhood():
    space = SearchSpace(seeds=(0, 1, 2), utils=(0.6, 0.7, 0.8),
                        depth_scales=(1.0, 2.0, 4.0))
    frontier = [SearchPoint(seed=1, max_util=0.7, depth_scale=2.0)]
    pts = space.refine(frontier, 50, seed=9)
    assert pts and len(pts) == len(set(pts))
    # seeds restricted to the frontier's; numeric axes stay within one
    # original-grid step of the frontier values (midpoint halving)
    for p in pts:
        assert p.seed == 1
        assert 0.6 <= p.max_util <= 0.8
        assert 1.0 <= p.depth_scale <= 4.0
    # midpoints toward the adjacent original values are present
    utils = {p.max_util for p in pts}
    for want in (0.65, 0.7, 0.75):
        assert any(abs(u - want) < 1e-9 for u in utils), (want, utils)
    # deterministic and capped by the refined-space size
    assert pts == space.refine(frontier, 50, seed=9)
    # n smaller than the neighborhood samples without replacement
    assert len(space.refine(frontier, 3, seed=0)) == 3
    # empty frontier degrades to plain sampling of the original space
    assert space.refine([], 5, seed=1) == space.sample(5, seed=1)


def test_refine_accepts_candidates_and_feeds_points_search():
    graph = _vecadd()
    grid = u280_grid()
    space = SearchSpace(utils=(0.7, 0.8))
    res = explore_design_space(graph, grid, space=space, sim_firings=40)
    pts = space.refine(res.frontier, 6, seed=2)
    assert pts
    zoom = explore_design_space(graph, grid, points=pts, sim_firings=40)
    assert zoom.space_size == len(pts)
    assert zoom.best.fmax >= 0.95 * res.best.fmax


# ---------------------------------------------------------------------------
# FIFO-sizing area credit (fmax surrogate feedback)
# ---------------------------------------------------------------------------


def test_buffer_bits_charge_is_monotone():
    """More buffered bits -> more slot load -> never a higher fmax."""
    graph = _vecadd()
    grid = u280_grid()
    pl = {n: (0, 0) if i % 2 else (1, 0)
          for i, n in enumerate(graph.tasks)}
    small = {s.name: 1e3 for s in graph.streams}
    big = {s.name: 4e6 for s in graph.streams}
    r0 = analyze_timing(graph, grid, pl)
    r_small = analyze_timing(graph, grid, pl, buffer_bits=small)
    r_big = analyze_timing(graph, grid, pl, buffer_bits=big)
    assert r_small.fmax_mhz <= r0.fmax_mhz
    assert r_big.fmax_mhz < r_small.fmax_mhz
    # the charge lands in slot utilization, not just the fmax number
    assert max(r_big.slot_util.values()) > max(r0.slot_util.values())


def test_sized_candidate_never_scores_below_uniform_twin():
    """Regression (ROADMAP item): crediting reclaimed FIFO bits back into
    slot utilization must never score the sized design below its
    uniform-headroom twin."""
    graph = _vecadd()
    grid = u280_grid()
    model = PhysicalModel()
    res = explore_design_space(graph, grid,
                               space=SearchSpace(utils=(0.7, 0.8)),
                               model=model, sim_firings=60, fifo_sizing=True)
    assert res.frontier
    for c in res.frontier:
        assert c.sized_capacity is not None
        assert c.sized_report is not None and c.uniform_report is not None
        assert c.fifo_savings_bits >= 0
        assert c.sized_report.fmax_mhz >= c.uniform_report.fmax_mhz


# ---------------------------------------------------------------------------
# cross-design benchmark batching (fmax suite acceptance)
# ---------------------------------------------------------------------------


def _load_bench(name):
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks", f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_fmax_suite_fast_subset_is_one_padded_sweep():
    """Acceptance: the fast subset's whole simulation phase is one padded
    array-sweep — >= 3x fewer Python-level simulation invocations than the
    one-batch-per-design path it replaces, with zero event-engine runs."""
    fs = _load_bench("fmax_suite")
    from repro.fpga import benchmarks as B
    entries = [fs.prepare(name, board, graph)
               for name, board, graph in B.autobridge_suite()
               if name in fs.FAST_SUBSET]
    assert len(entries) >= 6          # 6 designs, some on both boards
    sim = fs.score_all(entries, 60, "numpy")
    assert sim["counts"]["numpy"] == 1
    assert sim["counts"]["event"] == 0
    assert sim["backends"] == ["numpy-padded"]
    # the replaced path issued one simulate_batch per design
    assert sim["invocations"] * 3 <= len(entries)
    rows = [fs.finish(e, 60) for e in entries]
    for r in rows:
        assert r["opt_mhz"] > 0, r
        assert r["sim_deadlock"] is False
        assert r["throughput_preserved"] is True
        assert r["backend_used"] == "numpy-padded"


@jax_only
def test_fmax_suite_jax_backend_matches_numpy_rows():
    """Acceptance for the jitted backend at the suite level: scoring the
    same designs with ``backend="jax"`` reproduces the NumPy rows exactly
    (everything but wall time and the engine label), runs exactly one
    jitted sweep with zero numpy/event/fallback ticks, and records the
    jit compile-cache plus the measured NumPy-vs-jax speedup."""
    fs = _load_bench("fmax_suite")
    from repro.fpga import benchmarks as B
    names = {"stencil_x2", "bucket_sort"}

    def entries():
        return [fs.prepare(name, board, graph)
                for name, board, graph in B.autobridge_suite()
                if name in names]

    e_np = entries()
    fs.score_all(e_np, 60, "numpy")
    rows_np = [fs.finish(e, 60) for e in e_np]
    e_jx = entries()
    sim = fs.score_all(e_jx, 60, "jax")
    rows_jx = [fs.finish(e, 60) for e in e_jx]
    assert sim["counts"]["jax"] == 1
    assert sim["counts"]["numpy"] == sim["counts"]["event"] == 0
    assert sim["counts"]["fallback"] == 0
    assert sim["backends"] == ["jax-padded"]
    assert sim["jit_cache"]["compiles"] + sim["jit_cache"]["hits"] >= 1
    assert sim["speedup"]["numpy_wall_s"] > 0       # measured, not asserted
    assert sim["speedup"]["jax_wall_s"] > 0
    for a, b in zip(rows_np, rows_jx):
        assert b["backend_used"] == "jax-padded"
        for k in a:
            if k not in ("wall_s", "backend_used"):
                assert a[k] == b[k], k


def test_check_regression_jax_gate(tmp_path):
    """check_jax_backend: a --backend jax run gated against the fresh
    NumPy JSON — row-exact identity, jax counter > 0, zero silent
    fallbacks, jit_cache presence."""
    import json
    cr = _load_bench("check_regression")

    def doc(counts, *, backend, engine, opt=300.0, cycles=100, jit=False):
        d = {
            "suite": "fmax_suite",
            "subset": ["stencil_x2"],
            "backend": backend,
            "rows": [{"name": "d", "board": "u280", "opt_mhz": opt,
                      "cycles_opt": cycles, "backend_used": engine}],
            "summary": {"opt_avg_mhz": opt, "sim_deadlocks": 0,
                        "throughput_violations": 0},
            "sim": {"counts": counts, "invocations": sum(counts.values()),
                    "analysis": {"analyzed": 7, "doomed": 0, "skipped": 0,
                                 "infeasible": 0}},
        }
        if jit:
            d["sim"]["jit_cache"] = {"compiles": 1, "hits": 0}
        return d

    def write(name, d):
        p = tmp_path / name
        p.write_text(json.dumps(d))
        return str(p)

    NP = {"event": 0, "cycle": 0, "numpy": 1, "jax": 0, "fallback": 0}
    JX = {"event": 0, "cycle": 0, "numpy": 0, "jax": 1, "fallback": 0}
    base = write("base.json", doc(NP, backend="numpy", engine="numpy-padded"))
    good = write("good.json",
                 doc(JX, backend="jax", engine="jax-padded", jit=True))
    assert cr.main([good, base]) == 0
    # bit-exact identity: even an fmax IMPROVEMENT fails...
    up = write("up.json", doc(JX, backend="jax", engine="jax-padded",
                              opt=301.0, jit=True))
    assert cr.main([up, base]) == 1
    # ...as does any cycle-count divergence
    cyc = write("cyc.json", doc(JX, backend="jax", engine="jax-padded",
                                cycles=101, jit=True))
    assert cr.main([cyc, base]) == 1
    # silent degrade out of the jitted path: numpy ran under backend=jax
    mixed = dict(JX, numpy=1)
    deg = write("deg.json", doc(mixed, backend="jax", engine="jax-padded",
                                jit=True))
    assert cr.main([deg, base]) == 1
    # the sweep never ran at all
    off = write("off.json", doc(dict(JX, jax=0), backend="jax",
                                engine="jax-padded", jit=True))
    assert cr.main([off, base]) == 1
    # a fallback tick fails
    fb = write("fb.json", doc(dict(JX, fallback=1), backend="jax",
                              engine="jax-padded", jit=True))
    assert cr.main([fb, base]) == 1
    # a row scored on the wrong engine fails
    eng = write("eng.json", doc(JX, backend="jax", engine="numpy-padded",
                                jit=True))
    assert cr.main([eng, base]) == 1
    # missing jit_cache counters fail
    nojit = write("nojit.json", doc(JX, backend="jax", engine="jax-padded"))
    assert cr.main([nojit, base]) == 1


def test_check_regression_flags_event_fallback(tmp_path):
    """The CI gate fails a fast-subset run whose simulation phase degraded
    to per-job event simulation."""
    import json
    cr = _load_bench("check_regression")

    def doc(counts):
        return {
            "suite": "fmax_suite",
            "subset": ["stencil_x2"],
            "rows": [{"name": "d", "board": "u280", "opt_mhz": 300.0}],
            "summary": {
                "opt_avg_mhz": 300.0,
                "sim_deadlocks": 0,
                "throughput_violations": 0,
            },
            "sim": {"counts": counts,
                    "invocations": sum(counts.values()),
                    "analysis": {"analyzed": 7, "doomed": 0,
                                 "skipped": 0, "infeasible": 0}},
        }

    def write(name, d):
        p = tmp_path / name
        p.write_text(json.dumps(d))
        return str(p)

    base = write("base.json", doc({"event": 0, "cycle": 0, "numpy": 1}))
    good = write("good.json", doc({"event": 0, "cycle": 0, "numpy": 1}))
    fell = write("fell.json", doc({"event": 12, "cycle": 0, "numpy": 0}))
    multi = write("multi.json", doc({"event": 0, "cycle": 0, "numpy": 5}))
    assert cr.main([good, base]) == 0
    assert cr.main([fell, base]) == 1
    assert cr.main([multi, base]) == 1
    # vacuous pass closed: a sim phase that never ran is also a failure
    none_ran = write("none.json", doc({"event": 0, "cycle": 0, "numpy": 0}))
    assert cr.main([none_ran, base]) == 1
    cycled = write("cycled.json", doc({"event": 0, "cycle": 3, "numpy": 1}))
    assert cr.main([cycled, base]) == 1

    # the throughput suite shares the gate (no subset key: always applies)
    def tdoc(counts):
        return {
            "suite": "throughput",
            "rows": [{"name": "d", "cycles_tapa": 100}],
            "sim": {"counts": counts,
                    "invocations": sum(counts.values()),
                    "analysis": {"analyzed": 5, "doomed": 0,
                                 "skipped": 0, "infeasible": 0}},
        }

    tbase = write("tbase.json", tdoc({"event": 0, "cycle": 0, "numpy": 1}))
    tfell = write("tfell.json", tdoc({"event": 5, "cycle": 0, "numpy": 0}))
    assert cr.main([tbase, tbase]) == 0
    assert cr.main([tfell, tbase]) == 1


def test_check_regression_chaos_gate(tmp_path):
    """check_chaos + check_store: the chaos drill's resumed JSON gated
    against the clean converged run — row identity plus proof the faults
    fired (injected counters), bit (retries/rebuilds/store quarantines)
    and never escalated (zero pool quarantines / merge conflicts)."""
    import json
    cr = _load_bench("check_regression")

    COUNTS = {"event": 0, "cycle": 0, "numpy": 3, "jax": 0, "fallback": 0}

    def doc(*, chaos=True, opt=300.0, resumed_rounds=1, kill_rc=-9,
            retried=4, timed_out=1, rebuilds=2, pool_quar=0,
            store_quar=3, conflicts=0, merge_conflicts=0, injected=None):
        if injected is None:
            injected = {"worker_crash": 5, "worker_hang": 2,
                        "torn_write": 3, "parent_kill": 0}
        row = {"name": "d", "board": "u280", "opt_mhz": opt, "util": 0.8,
               "frontier": 2, "hypervolume": 1.5, "rounds_run": 3,
               "points_evaluated": 18, "cycles_opt": 100, "cycles_base": 90,
               "resumed_rounds": resumed_rounds if chaos else 0}
        d = {
            "suite": "fmax_suite", "converge": True, "subset": ["d"],
            "rows": [row],
            "summary": {"opt_avg_mhz": opt, "sim_deadlocks": 0,
                        "throughput_violations": 0},
            "sim": {
                "counts": COUNTS, "points_evaluated": 18,
                "floorplan": {"solved": 9, "cache_hits": 12,
                              "merge_conflicts": 0, "ilp_bipartitions": 20},
                "pool": {"jobs": 2, "dispatched": 9, "merged": 9,
                         "worker_solves": 9, "worker_infeasible": 0,
                         "retried": retried if chaos else 0,
                         "timed_out": timed_out if chaos else 0,
                         "quarantined": pool_quar,
                         "pool_rebuilds": rebuilds if chaos else 0},
                "analysis": {"analyzed": 7, "doomed": 0, "skipped": 0,
                             "infeasible": 0},
                "store": {"writes": 9, "disk_hits": 0, "disk_misses": 18,
                          "quarantined": store_quar if chaos else 0,
                          "evictions": 0, "conflicts": conflicts,
                          "entries": 9},
                "faults": {
                    "plan": ({"seed": 7, "worker_crash": 0.25}
                             if chaos else None),
                    "injected": (injected if chaos else
                                 dict.fromkeys(injected, 0)),
                    "observed": {
                        "retried": retried if chaos else 0,
                        "timed_out": timed_out if chaos else 0,
                        "quarantined": pool_quar,
                        "pool_rebuilds": rebuilds if chaos else 0,
                        "store_quarantined": store_quar if chaos else 0,
                        "merge_conflicts": merge_conflicts},
                },
            },
        }
        if chaos:
            d["chaos"] = {"killed_runs": 1, "kill_returncode": kill_rc,
                          "resumed": resumed_rounds > 0,
                          "resumed_designs": ["d"] if resumed_rounds else [],
                          "fault_plan": {"seed": 7}}
        return d

    def write(name, d):
        p = tmp_path / name
        p.write_text(json.dumps(d))
        return str(p)

    base = write("base.json", doc(chaos=False))
    good = write("good.json", doc())
    assert cr.main([good, base]) == 0
    # identity is exact: a row divergence fails even when it improves
    assert cr.main([write("row.json", doc(opt=301.0)), base]) == 1
    # the kill must have been delivered by signal
    assert cr.main([write("rc.json", doc(kill_rc=0)), base]) == 1
    # a drill where nothing resumed proves nothing
    assert cr.main([write("nores.json", doc(resumed_rounds=0)), base]) == 1
    # fault machinery must show activity...
    assert cr.main([write("noretry.json", doc(retried=0)), base]) == 1
    assert cr.main([write("norebuild.json", doc(rebuilds=0)), base]) == 1
    assert cr.main([write("noquar.json", doc(store_quar=0)), base]) == 1
    vac = dict.fromkeys(("worker_crash", "worker_hang", "torn_write"), 0)
    assert cr.main([write("noinj.json", doc(injected=vac)), base]) == 1
    # ...but never escalate to frontier-moving verdicts
    assert cr.main([write("poison.json", doc(pool_quar=1)), base]) == 1
    assert cr.main([write("mc.json", doc(merge_conflicts=1)), base]) == 1


def test_check_regression_store_gate(tmp_path):
    """check_store on a healthy (non-chaos) converged --store run: write
    conflicts always fail; quarantined entries fail without chaos."""
    import json
    cr = _load_bench("check_regression")

    def doc(*, conflicts=0, quarantined=0, converge=True):
        d = {
            "suite": "fmax_suite", "converge": converge, "subset": ["d"],
            "rows": [{"name": "d", "board": "u280", "opt_mhz": 300.0}],
            "summary": {"opt_avg_mhz": 300.0, "sim_deadlocks": 0,
                        "throughput_violations": 0},
            "sim": {
                "counts": {"event": 0, "cycle": 0, "numpy": 3, "jax": 0,
                           "fallback": 0},
                "points_evaluated": 18,
                "floorplan": {"solved": 9, "cache_hits": 12,
                              "merge_conflicts": 0, "ilp_bipartitions": 20},
                "analysis": {"analyzed": 7, "doomed": 0, "skipped": 0,
                             "infeasible": 0},
                "store": {"writes": 9, "disk_hits": 0, "disk_misses": 18,
                          "quarantined": quarantined, "evictions": 0,
                          "conflicts": conflicts, "entries": 9},
            },
        }
        return d

    def write(name, d):
        p = tmp_path / name
        p.write_text(json.dumps(d))
        return str(p)

    # the converged run gates against the NON-converged fmax baseline
    base = write("base.json", doc(converge=False))
    assert cr.main([write("ok.json", doc()), base]) == 0
    assert cr.main([write("conf.json", doc(conflicts=1)), base]) == 1
    assert cr.main([write("quar.json", doc(quarantined=2)), base]) == 1


def test_fmax_suite_converged_parallel_surrogate_fast_subset(tmp_path):
    """Tier-1 coverage for the converged ``--jobs N --proposer surrogate``
    path (previously nightly-only): on a fast-subset design the parallel
    surrogate run must reproduce the sequential surrogate run's rows
    bit-identically (the pool only relocates deterministic ILP solves),
    record the worker dispatch/merge counters, and stamp the proposer and
    jobs into the JSON sim block the CI gate reads."""
    import json

    fs = _load_bench("fmax_suite")
    kw = dict(verbose=False, sim_firings=60, subset=("stencil_x2",),
              proposer="surrogate")
    seq_rows = fs.main_converged(**kw)
    par_path = tmp_path / "par.json"
    par_rows = fs.main_converged(jobs=2, json_path=str(par_path), **kw)
    assert seq_rows and len(seq_rows) == len(par_rows)
    identity = ("opt_mhz", "util", "frontier", "hypervolume",
                "rounds_run", "points_evaluated", "cycles_opt",
                "cycles_base")
    for a, b in zip(seq_rows, par_rows):
        for field in identity:
            assert a[field] == b[field], (a["name"], field)
        assert b["converged"] in (True, False)
    doc = json.loads(par_path.read_text())
    assert doc["converge"] is True
    sim = doc["sim"]
    assert sim["proposer"] == "surrogate"
    assert sim["pool"]["jobs"] == 2
    assert sim["pool"]["merged"] == sim["pool"]["dispatched"]
    assert sim["counts"]["fallback"] == 0
    assert sim["floorplan"]["cache_hits"] > 0
