"""The ``repro.search`` subsystem (PR-5 tentpole): worker-pool parallel
floorplan solving, mergeable caches/counters, the surrogate proposer, and
backward compatibility of the old ``repro.core.explorer`` import surface.

Covers: ``jobs=4`` frontier identity with ``jobs=1`` (the parallel path's
contract is *bit-identical* results), pool survival of worker-side
``InfeasibleError`` (a verdict, not a crash), ``floorplan_counts()``
staying correct when solves happen in subprocesses, the
``FloorplanCache.merge`` property (stateful-machine-tested against
interleaved single-process solves), the surrogate proposer's
equal-or-better convergence regression, and the uniform fallback's
bit-identity when the fit is underdetermined.
"""
import contextlib
import dataclasses

import numpy as np
import pytest

from _propcheck import RuleBasedStateMachine, machine_st, rule, run_state_machine

import repro.search as search_pkg
from repro.core import (
    FloorplanCache,
    Interval,
    SearchPoint,
    SearchSpace,
    SlotGrid,
    TaskGraphBuilder,
    autobridge,
    floorplan_counts,
    initial_floorplan_key,
    merge_floorplan_counts,
)
from repro.core.ilp import InfeasibleError
from repro.fpga import benchmarks as B, grid_for, u280_grid
from repro.search import (
    PoolStats,
    ResponseSurface,
    SurrogateProposer,
    UniformProposer,
    explore_design_space,
    hypervolume,
    make_proposer,
    pool_counts,
    search_until_converged,
    warm_floorplan_cache,
)
from repro.search.engine import _objective


def _chain_graph(n=4, width=64, lut=100):
    b = TaskGraphBuilder("chain")
    for i in range(n - 1):
        b.stream(f"s{i}", width=width)
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": lut},
                 ins=[f"s{i - 1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


def _vecadd():
    pe = 4
    b = TaskGraphBuilder("VecAdd")
    a = b.streams("str_a", n=pe, width=512)
    bb = b.streams("str_b", n=pe, width=512)
    c = b.streams("str_c", n=pe, width=512)
    b.invoke("LoadA", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=a, count=pe)
    b.invoke("LoadB", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=bb, count=pe)
    b.invoke("Add", area={"LUT": 60e3, "DSP": 256}, ins=a + bb, outs=c,
             count=pe)
    b.invoke("Store", area={"LUT": 12e3, "hbm_channels": 1}, ins=c, count=pe)
    return b.build()


def _frontier_fingerprint(res):
    """Everything observable about a frontier candidate, for exact-identity
    comparison across execution modes."""
    return sorted(
        (dataclasses.astuple(c.point), c.fmax, c.plan.area_overhead,
         tuple(sorted(c.plan.depth.items())),
         tuple(sorted(c.plan.floorplan.placement.items())),
         c.sim.cycles if c.sim else None)
        for c in res.frontier)


# ---------------------------------------------------------------------------
# backward compatibility: repro.core.explorer -> repro.search
# ---------------------------------------------------------------------------


def test_core_explorer_is_the_search_engine():
    import repro.core.explorer as explorer_mod
    import repro.search.engine as engine_mod

    assert explorer_mod is engine_mod
    assert explorer_mod.explore_design_space is search_pkg.explore_design_space
    assert explorer_mod.SearchSpace is search_pkg.SearchSpace
    # the names tests/benchmarks reach into survive the move
    for name in ("_objective", "_derive_depth_variant", "simulate_batch",
                 "autobridge", "InfeasibleError", "Interval"):
        assert hasattr(explorer_mod, name)


def test_core_package_reexports_search_names():
    import repro.core as core

    for name in ("explore_design_space", "search_until_converged",
                 "sweep_backends", "SearchSpace", "Interval", "hypervolume",
                 "pareto_frontier", "best_candidate"):
        assert getattr(core, name) is getattr(search_pkg, name)
        assert name in core.__all__
    assert "search_until_converged" in dir(core)


# ---------------------------------------------------------------------------
# worker pool: jobs>1 is bit-identical to jobs=1, only faster
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("design", ["stencil_x4", "bucket_sort", "page_rank"])
def test_parallel_converged_search_matches_sequential(design):
    """The acceptance contract on fast-subset designs: jobs=4 returns a
    frontier identical to jobs=1 — same points, placements, depths, fmax
    and simulated cycles — with the same hypervolume trajectory."""
    name, board, graph = next((n, b, g) for n, b, g in B.autobridge_suite()
                              if n == design)
    space = SearchSpace(utils=Interval(0.7, 1.0))
    kwargs = dict(space=space, rounds=2, points_per_round=6,
                  sim_firings=60, tol=0.0)
    seq = search_until_converged(graph, grid_for(board), **kwargs)
    par = search_until_converged(graph, grid_for(board), jobs=4, **kwargs)
    assert _frontier_fingerprint(par) == _frontier_fingerprint(seq)
    assert par.hypervolumes == seq.hypervolumes
    assert par.rounds_run == seq.rounds_run
    assert par.points_evaluated == seq.points_evaluated
    assert par.jobs == 4 and seq.jobs == 1
    assert par.pool is not None and par.pool.merged == par.pool.dispatched
    assert seq.pool is None


def test_parallel_explore_design_space_matches_sequential():
    graph = _vecadd()
    grid = u280_grid()
    space = SearchSpace(seeds=(0, 1), utils=(0.6, 0.7, 0.8),
                        depth_scales=(1.0, 2.0))
    seq = explore_design_space(graph, grid, space=space, sim_firings=60)
    par = explore_design_space(_vecadd(), grid, space=space, sim_firings=60,
                               jobs=2)
    assert _frontier_fingerprint(par) == _frontier_fingerprint(seq)
    assert len(par.candidates) == len(seq.candidates)


def test_pool_survives_worker_infeasible_and_merges_counters():
    """A worker hitting InfeasibleError ships the verdict back as a cached
    entry: the search completes with failed candidates, and the global
    floorplan counters see the workers' solve attempts (not the silent 0
    the per-process globals would otherwise read)."""
    graph = _chain_graph(n=5, lut=1000)
    tiny = SlotGrid("tiny", rows=1, cols=2, base_capacity={"LUT": 10},
                    max_util=1.0)
    res = explore_design_space(graph, tiny,
                               space=SearchSpace(utils=(0.5, 1.0)),
                               jobs=2)
    assert res.frontier == []
    assert all(c.plan is None and c.error for c in res.candidates)
    counts = floorplan_counts()
    assert counts["solved"] > 0          # merged in from the workers
    assert counts["ilp_bipartitions"] > 0
    pc = pool_counts()
    assert pc["dispatched"] == pc["merged"] == 2
    assert pc["worker_infeasible"] == 2


def test_warm_cache_skips_already_cached_points():
    graph = _chain_graph()
    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 200},
                    max_util=1.0)
    cache = FloorplanCache()
    pts = [SearchPoint(max_util=0.9), SearchPoint(max_util=1.0)]
    first = warm_floorplan_cache(graph, grid, pts, cache=cache, jobs=2)
    assert first.dispatched == 2 and first.merged == 2
    again = warm_floorplan_cache(graph, grid, pts, cache=cache, jobs=2)
    assert again.dispatched == 0         # everything already cached
    # jobs=1 is the exact in-process fallback: the pool never spins up
    seq = warm_floorplan_cache(graph, grid, pts, cache=FloorplanCache(),
                               jobs=1)
    assert seq.dispatched == 0 and seq.jobs == 1


def test_initial_floorplan_key_matches_autobridge_first_solve():
    graph = _chain_graph()
    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 200},
                    max_util=1.0)
    cache = FloorplanCache()
    autobridge(graph, grid, max_util=0.9, seed=1, depth_scale=2.0,
               cache=cache)
    key = initial_floorplan_key(graph, grid, max_util=0.9, seed=1,
                                depth_scale=2.0)
    assert key in cache
    assert initial_floorplan_key(graph, grid, max_util=0.8, seed=1) not in cache


def test_merge_floorplan_counts_aggregates():
    merge_floorplan_counts({"solved": 3, "cache_hits": 2,
                            "ilp_bipartitions": 7})
    merge_floorplan_counts({"solved": 1})
    c = floorplan_counts()
    assert (c["solved"], c["cache_hits"], c["ilp_bipartitions"]) == (4, 2, 7)


def test_pool_stats_absorb():
    a = PoolStats(jobs=2, dispatched=3, merged=3, worker_solves=5,
                  worker_infeasible=1, wall_s=0.5, static_skipped=1,
                  retried=2, timed_out=1, quarantined=1, pool_rebuilds=1)
    b = PoolStats(jobs=4, dispatched=2, merged=2, worker_solves=2,
                  wall_s=0.25, static_skipped=2, retried=1, pool_rebuilds=2)
    a.absorb(b)
    assert (a.jobs, a.dispatched, a.merged, a.worker_solves,
            a.worker_infeasible, a.static_skipped) == (4, 5, 5, 7, 1, 3)
    assert (a.retried, a.timed_out, a.quarantined,
            a.pool_rebuilds) == (3, 1, 1, 3)
    assert a.wall_s == pytest.approx(0.75)
    assert set(a.as_dict()) == {"jobs", "dispatched", "merged",
                                "worker_solves", "worker_infeasible",
                                "wall_s", "static_skipped", "retried",
                                "timed_out", "quarantined", "pool_rebuilds"}


# ---------------------------------------------------------------------------
# FloorplanCache.merge: property-tested against interleaved solves
# ---------------------------------------------------------------------------


class CacheMergeMachine(RuleBasedStateMachine):
    """Interleave autobridge solves across two 'worker' caches while a
    reference cache sees every solve (the single-process interleaving).
    Merging the workers into a fresh parent must reproduce the reference:
    same keys, same plans/verdicts, and replaying any solved configuration
    on the parent is a pure hit."""

    GRID = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 400},
                    max_util=1.0)

    def __init__(self):
        self.workers = [FloorplanCache(), FloorplanCache()]
        self.reference = FloorplanCache()
        self.configs: list[tuple] = []

    @rule(w=machine_st.integers(0, 1),
          n=machine_st.integers(3, 6),
          seed=machine_st.integers(0, 2),
          util=machine_st.sampled_from([0.02, 0.9, 1.0]))
    def solve(self, w, n, seed, util):
        # util=0.02 caps every slot below one task -> cached infeasibility
        def run(cache):
            try:
                plan = autobridge(_chain_graph(n=n), self.GRID, seed=seed,
                                  max_util=util, cache=cache)
                return ("ok", tuple(sorted(plan.floorplan.placement.items())),
                        tuple(sorted(plan.depth.items())))
            except InfeasibleError as e:
                return ("err", str(e))

        got = run(self.workers[w])
        want = run(self.reference)
        assert got == want       # worker solve ≡ single-process solve
        self.configs.append((n, seed, util))

    def finalize(self):
        parent = FloorplanCache()
        added = sum(parent.merge(wc) for wc in self.workers)
        assert added == len(parent)
        assert set(parent._entries) == set(self.reference._entries)
        for k, (kind, val) in parent._entries.items():
            rkind, rval = self.reference._entries[k]
            assert kind == rkind
            if kind == "ok":
                assert val.placement == rval.placement
                assert val.cost == pytest.approx(rval.cost)
            else:
                assert val == rval
        # replaying every recorded configuration on the merged parent never
        # solves again: pure hits (misses stay 0)
        for n, seed, util in self.configs:
            with contextlib.suppress(InfeasibleError):
                autobridge(_chain_graph(n=n), self.GRID, seed=seed,
                           max_util=util, cache=parent)
        assert parent.misses == 0
        assert parent.hits >= len(self.configs)


def test_floorplan_cache_merge_property():
    run_state_machine(CacheMergeMachine, steps=6, max_examples=5)


def test_floorplan_cache_merge_first_writer_wins_and_counts():
    g = _chain_graph()
    grid = SlotGrid("g", rows=1, cols=2, base_capacity={"LUT": 300},
                    max_util=1.0)
    a, b = FloorplanCache(), FloorplanCache()
    autobridge(g, grid, cache=a)
    autobridge(_chain_graph(), grid, cache=b)          # same key, own solve
    autobridge(g, grid, seed=1, cache=b)               # b-only entry
    parent = FloorplanCache()
    assert parent.merge(a) == 1
    assert parent.merge(b) == 1                        # dup key not re-added
    assert len(parent) == 2
    # merge does not rewrite lookup history
    assert parent.hits == parent.misses == 0


def test_merge_detects_conflicting_values_and_keeps_first():
    a, b = FloorplanCache(), FloorplanCache()
    a.record_infeasible(("k",), "reason A")
    b.record_infeasible(("k",), "reason B")
    b.record_infeasible(("k2",), "only in b")
    parent = FloorplanCache()
    assert parent.merge(a) == 1
    assert parent.merge(b) == 1                 # k2 added, k kept as a's
    assert parent.merge_conflicts == 1
    assert floorplan_counts()["merge_conflicts"] == 1
    assert parent.cached_error(("k",)) == "reason A"
    # agreeing duplicates are not conflicts
    c = FloorplanCache()
    c.record_infeasible(("k",), "reason A")
    assert parent.merge(c) == 0
    assert parent.merge_conflicts == 1


# ---------------------------------------------------------------------------
# surrogate proposer
# ---------------------------------------------------------------------------


def test_response_surface_recovers_quadratic():
    pts = [SearchPoint(max_util=u, depth_scale=d)
           for u in (0.5, 0.6, 0.7, 0.8, 0.9, 1.0)
           for d in (1.0, 1.5, 2.0)]
    y = np.array([[2.0 + 3.0 * p.max_util - 1.5 * p.max_util ** 2
                   + 0.5 * p.depth_scale] for p in pts])
    rs = ResponseSurface(ridge=1e-10)
    assert rs.fit(pts, y)
    pred = rs.predict([SearchPoint(max_util=0.65, depth_scale=1.2)])
    want = 2.0 + 3.0 * 0.65 - 1.5 * 0.65 ** 2 + 0.5 * 1.2
    assert pred[0, 0] == pytest.approx(want, rel=1e-4)


def test_response_surface_underdetermined_refuses():
    rs = ResponseSurface()
    ok = rs.fit([SearchPoint(max_util=0.6), SearchPoint(max_util=0.7)],
                np.array([[1.0], [2.0]]))
    # two samples cannot determine bias+linear+quadratic in one axis
    assert not ok
    with pytest.raises(RuntimeError):
        rs.predict([SearchPoint()])


def test_surrogate_fallback_is_bit_identical_to_uniform():
    """With no evaluated candidates the fit is underdetermined and the
    surrogate must propose EXACTLY the uniform draws — the fallback is the
    uniform proposer, not merely 'something random'.  That must hold on
    continuous AND discrete spaces (a discrete space's oversampled pool
    degenerates to grid order, which is NOT the uniform draw)."""
    cont = SearchSpace(utils=Interval(0.6, 0.9), depth_scales=(1.0, 2.0))
    disc = SearchSpace(utils=(0.6, 0.7, 0.8, 0.85, 0.9),
                       depth_scales=(1.0, 2.0))
    for space in (cont, disc):
        for seed in (0, 42):
            uni = UniformProposer().propose(space, [], [], 6, seed=seed)
            sur = SurrogateProposer().propose(space, [], [], 6, seed=seed)
            assert sur == uni


def test_make_proposer_resolves_names_and_objects():
    assert isinstance(make_proposer("uniform"), UniformProposer)
    assert isinstance(make_proposer("surrogate"), SurrogateProposer)
    custom = SurrogateProposer(oversample=4)
    assert make_proposer(custom) is custom
    with pytest.raises(ValueError):
        make_proposer("genetic")


@pytest.mark.parametrize("case", ["vecadd", "page_rank"])
def test_surrogate_converges_no_slower_at_equal_or_better_hypervolume(case):
    """The regression-tested acceptance: on these pinned designs the
    surrogate proposer converges in <= the uniform proposer's rounds and
    its merged frontier's hypervolume (common reference) is >= uniform's."""
    if case == "vecadd":
        graph, grid = _vecadd(), u280_grid()
    else:
        _, board, graph = next((n, b, g) for n, b, g in B.autobridge_suite()
                               if n == case)
        grid = grid_for(board)
    space = SearchSpace(utils=Interval(0.6, 0.95), depth_scales=(1.0, 2.0))
    kwargs = dict(space=space, rounds=4, points_per_round=10,
                  sim_firings=100, tol=0.01)
    uni = search_until_converged(graph, grid, **kwargs)
    sur = search_until_converged(graph, grid, proposer="surrogate", **kwargs)
    assert sur.proposer == "surrogate" and uni.proposer == "uniform"
    assert sur.rounds_run <= uni.rounds_run
    ref = tuple(min(min(_objective(c)[i] for c in r.frontier)
                    for r in (uni, sur)) - 1.0 for i in range(3))
    hv_uni = hypervolume([_objective(c) for c in uni.frontier], ref)
    hv_sur = hypervolume([_objective(c) for c in sur.frontier], ref)
    assert hv_sur >= hv_uni - 1e-9
