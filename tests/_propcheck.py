"""Property-test compatibility shim.

Uses real `hypothesis` when it is installed; otherwise provides a small,
deterministic fixed-examples fallback implementing the subset this test
suite uses: ``given``, ``settings`` and ``strategies.integers /
sampled_from / floats / booleans / lists``.

The fallback draws a fixed number of examples per test (boundary values
first, then pseudo-random ones from a seed derived from the test name), so
runs are reproducible with or without hypothesis and tier-1 never dies at
collection on a missing optional dependency.

On failure the fallback *greedily shrinks* the counterexample the way the
real library would — integers/floats step toward 0 (clamped into range),
sampled values move to earlier elements, lists are halved and their
elements shrunk — re-running the test after each candidate simplification
and keeping it only if the test still fails.  The minimal example is
printed and its failure re-raised, so fallback-mode CI reports match the
real-`hypothesis` job's minimized counterexamples closely.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random
    import types

    DEFAULT_MAX_EXAMPLES = 25
    MAX_SHRINK_TRIES = 500

    try:
        from _pytest.outcomes import Skipped as _Skipped
    except Exception:  # pragma: no cover - pytest always present in CI
        class _Skipped(BaseException):
            pass

    #: exceptions that must propagate, never count as falsifying examples
    #: (Ctrl-C, interpreter exit, pytest.skip control flow)
    _NON_FALSIFYING = (KeyboardInterrupt, SystemExit, GeneratorExit, _Skipped)

    class _Strategy:
        """A value source: boundary examples first, then seeded draws, plus
        a shrinker yielding strictly-simpler candidates for a value."""

        def __init__(self, edge_values, draw, shrink=None):
            self.edge_values = list(edge_values)
            self.draw = draw
            self.shrink = shrink or (lambda value: ())

    def _shrink_number(value, target, *, integer):
        """Candidates between ``value`` and ``target`` (nearest-to-target
        first: big jumps before single steps)."""
        if value == target:
            return
        yield target
        mid = (value + target) // 2 if integer else (value + target) / 2
        if mid not in (value, target):
            yield mid
        if integer:
            step = value - 1 if value > target else value + 1
            if step != mid:
                yield step

    def _integers(min_value=0, max_value=2 ** 31 - 1):
        target = min(max(0, min_value), max_value)
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.randint(min_value, max_value),
            lambda v: _shrink_number(v, target, integer=True))

    def _sampled_from(elements):
        elems = list(elements)

        def shrink(v):
            # earlier elements are simpler; try the front first
            try:
                i = elems.index(v)
            except ValueError:
                return
            if i > 0:
                yield elems[0]
            if i // 2 not in (0, i):
                yield elems[i // 2]

        return _Strategy(elems[:2],
                         lambda rng: elems[rng.randrange(len(elems))],
                         shrink)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        target = min(max(0.0, min_value), max_value)
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.uniform(min_value, max_value),
            lambda v: _shrink_number(v, target, integer=False))

    def _booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5,
                         lambda v: (False,) if v else ())

    def _lists(elements, *, min_size=0, max_size=8):
        def draw(rng):
            return [elements.draw(rng)
                    for _ in range(rng.randint(min_size, max_size))]

        def shrink(v):
            # structural first: halves, then dropping single elements,
            # then shrinking elements in place
            if len(v) > min_size:
                half = max(min_size, len(v) // 2)
                if half < len(v):
                    yield list(v[:half])
                    yield list(v[len(v) - half:])
                for i in range(len(v)):
                    if len(v) - 1 >= min_size:
                        yield v[:i] + v[i + 1:]
            for i, item in enumerate(v):
                for cand in elements.shrink(item):
                    yield v[:i] + [cand] + v[i + 1:]

        edges = [[]] if min_size == 0 else [
            [elements.edge_values[0]] * min_size]
        return _Strategy(edges, draw, shrink)

    strategies = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, floats=_floats,
        booleans=_booleans, lists=_lists)

    def _shrink_case(run, strats, case):
        """Greedy coordinate descent: repeatedly adopt the first simpler
        per-argument candidate that still fails, until no candidate does
        (or the try budget runs out).  Returns the minimal failing case and
        its exception (None if nothing simpler failed)."""
        best = list(case)
        best_exc = None
        tries = 0
        improved = True
        while improved and tries < MAX_SHRINK_TRIES:
            improved = False
            for i, s in enumerate(strats):
                for cand in s.shrink(best[i]):
                    tries += 1
                    trial = list(best)
                    trial[i] = cand
                    exc = run(trial)
                    if exc is not None:
                        best = trial
                        best_exc = exc
                        improved = True
                        break
                    if tries >= MAX_SHRINK_TRIES:
                        break
                if improved or tries >= MAX_SHRINK_TRIES:
                    break
        return tuple(best), best_exc

    def given(*strats, **kw_strats):
        if kw_strats:
            raise NotImplementedError(
                "_propcheck fallback supports positional strategies only")

        def deco(fn):
            # NB: no functools.wraps — it sets __wrapped__, which makes
            # pytest resolve the original (n, m, seed) signature and demand
            # fixtures for the strategy-provided arguments.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", DEFAULT_MAX_EXAMPLES)
                rng = random.Random(
                    f"propcheck::{fn.__module__}::{fn.__qualname__}")

                def run(case):
                    try:
                        fn(*args, *case, **kwargs)
                    except _Skipped:
                        # a skip on a shrink candidate means "invalid input,
                        # keep shrinking" (hypothesis semantics) — it must
                        # not escape and mask the original failure
                        return None
                    except (KeyboardInterrupt, SystemExit, GeneratorExit):
                        raise
                    except BaseException as e:  # noqa: BLE001 - re-raised
                        return e
                    return None

                for i in range(n):
                    case = tuple(
                        s.edge_values[i] if i < len(s.edge_values)
                        else s.draw(rng)
                        for s in strats)
                    try:
                        fn(*args, *case, **kwargs)
                    except _NON_FALSIFYING:
                        raise
                    except BaseException:
                        minimal, exc = _shrink_case(run, strats, case)
                        print(f"_propcheck falsifying example: "
                              f"{fn.__qualname__}{case}")
                        if exc is not None and minimal != case:
                            print(f"_propcheck shrunk to: "
                                  f"{fn.__qualname__}{minimal}")
                            raise exc
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._pc_given = True
            return wrapper

        return deco

    def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn

        return deco
