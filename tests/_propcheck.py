"""Property-test compatibility shim.

Uses real `hypothesis` when it is installed; otherwise provides a small,
deterministic fixed-examples fallback implementing the subset this test
suite uses: ``given``, ``settings`` and ``strategies.integers /
sampled_from / floats / booleans / lists``.

The fallback draws a fixed number of examples per test (boundary values
first, then pseudo-random ones from a seed derived from the test name), so
runs are reproducible with or without hypothesis and tier-1 never dies at
collection on a missing optional dependency.

On failure the fallback *greedily shrinks* the counterexample the way the
real library would — integers/floats step toward 0 (clamped into range),
sampled values move to earlier elements, lists are halved and their
elements shrunk — re-running the test after each candidate simplification
and keeping it only if the test still fails.  The minimal example is
printed and its failure re-raised, so fallback-mode CI reports match the
real-`hypothesis` job's minimized counterexamples closely.

Stateful testing (``RuleBasedStateMachine`` / ``rule`` /
``run_state_machine``, a minimal ``hypothesis.stateful`` analogue) is
implemented here unconditionally — it does NOT switch to hypothesis's
engine, so stateful tests behave identically with and without the real
library installed.  Random *programs* (sequences of rule calls with drawn
arguments, drawn from the ``machine_st`` strategies) run against a fresh
machine instance; a failing program is greedily shrunk — first structurally
(dropping rule calls) then per-call (shrinking drawn arguments) —
re-executed from scratch after every candidate simplification, and the
minimal failing program is printed before the failure is re-raised.
Machines may define an optional ``finalize`` method: it runs after the
last rule of every (shrunk or not) program, so end-state invariants
participate in shrinking.
"""
from __future__ import annotations

import random
import types

DEFAULT_MAX_EXAMPLES = 25
MAX_SHRINK_TRIES = 500

try:
    from _pytest.outcomes import Skipped as _Skipped
except Exception:  # pragma: no cover - pytest always present in CI
    class _Skipped(BaseException):
        pass

#: exceptions that must propagate, never count as falsifying examples
#: (Ctrl-C, interpreter exit, pytest.skip control flow)
_NON_FALSIFYING = (KeyboardInterrupt, SystemExit, GeneratorExit, _Skipped)


# ---------------------------------------------------------------------------
# strategy machinery — always available: the fallback `given` uses it when
# hypothesis is missing, and the stateful engine below uses it always
# ---------------------------------------------------------------------------

class _Strategy:
    """A value source: boundary examples first, then seeded draws, plus
    a shrinker yielding strictly-simpler candidates for a value."""

    def __init__(self, edge_values, draw, shrink=None):
        self.edge_values = list(edge_values)
        self.draw = draw
        self.shrink = shrink or (lambda value: ())


def _shrink_number(value, target, *, integer):
    """Candidates between ``value`` and ``target`` (nearest-to-target
    first: big jumps before single steps)."""
    if value == target:
        return
    yield target
    mid = (value + target) // 2 if integer else (value + target) / 2
    if mid not in (value, target):
        yield mid
    if integer:
        step = value - 1 if value > target else value + 1
        if step != mid:
            yield step


def _integers(min_value=0, max_value=2 ** 31 - 1):
    target = min(max(0, min_value), max_value)
    return _Strategy(
        [min_value, max_value],
        lambda rng: rng.randint(min_value, max_value),
        lambda v: _shrink_number(v, target, integer=True))


def _sampled_from(elements):
    elems = list(elements)

    def shrink(v):
        # earlier elements are simpler; try the front first
        try:
            i = elems.index(v)
        except ValueError:
            return
        if i > 0:
            yield elems[0]
        if i // 2 not in (0, i):
            yield elems[i // 2]

    return _Strategy(elems[:2],
                     lambda rng: elems[rng.randrange(len(elems))],
                     shrink)


def _floats(min_value=0.0, max_value=1.0, **_kw):
    target = min(max(0.0, min_value), max_value)
    return _Strategy(
        [min_value, max_value],
        lambda rng: rng.uniform(min_value, max_value),
        lambda v: _shrink_number(v, target, integer=False))


def _booleans():
    return _Strategy([False, True], lambda rng: rng.random() < 0.5,
                     lambda v: (False,) if v else ())


def _lists(elements, *, min_size=0, max_size=8):
    def draw(rng):
        return [elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))]

    def shrink(v):
        # structural first: halves, then dropping single elements,
        # then shrinking elements in place
        if len(v) > min_size:
            half = max(min_size, len(v) // 2)
            if half < len(v):
                yield list(v[:half])
                yield list(v[len(v) - half:])
            for i in range(len(v)):
                if len(v) - 1 >= min_size:
                    yield v[:i] + v[i + 1:]
        for i, item in enumerate(v):
            for cand in elements.shrink(item):
                yield v[:i] + [cand] + v[i + 1:]

    edges = [[]] if min_size == 0 else [
        [elements.edge_values[0]] * min_size]
    return _Strategy(edges, draw, shrink)


#: strategies for state-machine rule arguments.  Deliberately its own
#: namespace (NOT ``strategies``): with real hypothesis installed
#: ``strategies`` is hypothesis's and its objects have no ``.draw(rng)`` —
#: the stateful engine always runs on the fallback machinery so stateful
#: tests behave identically in both CI matrix legs.
machine_st = types.SimpleNamespace(
    integers=_integers, sampled_from=_sampled_from, floats=_floats,
    booleans=_booleans, lists=_lists)


try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    strategies = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, floats=_floats,
        booleans=_booleans, lists=_lists)

    def _shrink_case(run, strats, case):
        """Greedy coordinate descent: repeatedly adopt the first simpler
        per-argument candidate that still fails, until no candidate does
        (or the try budget runs out).  Returns the minimal failing case and
        its exception (None if nothing simpler failed)."""
        best = list(case)
        best_exc = None
        tries = 0
        improved = True
        while improved and tries < MAX_SHRINK_TRIES:
            improved = False
            for i, s in enumerate(strats):
                for cand in s.shrink(best[i]):
                    tries += 1
                    trial = list(best)
                    trial[i] = cand
                    exc = run(trial)
                    if exc is not None:
                        best = trial
                        best_exc = exc
                        improved = True
                        break
                    if tries >= MAX_SHRINK_TRIES:
                        break
                if improved or tries >= MAX_SHRINK_TRIES:
                    break
        return tuple(best), best_exc

    def given(*strats, **kw_strats):
        if kw_strats:
            raise NotImplementedError(
                "_propcheck fallback supports positional strategies only")

        def deco(fn):
            # NB: no functools.wraps — it sets __wrapped__, which makes
            # pytest resolve the original (n, m, seed) signature and demand
            # fixtures for the strategy-provided arguments.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", DEFAULT_MAX_EXAMPLES)
                rng = random.Random(
                    f"propcheck::{fn.__module__}::{fn.__qualname__}")

                def run(case):
                    try:
                        fn(*args, *case, **kwargs)
                    except _Skipped:
                        # a skip on a shrink candidate means "invalid input,
                        # keep shrinking" (hypothesis semantics) — it must
                        # not escape and mask the original failure
                        return None
                    except (KeyboardInterrupt, SystemExit, GeneratorExit):
                        raise
                    except BaseException as e:  # noqa: BLE001 - re-raised
                        return e
                    return None

                for i in range(n):
                    case = tuple(
                        s.edge_values[i] if i < len(s.edge_values)
                        else s.draw(rng)
                        for s in strats)
                    try:
                        fn(*args, *case, **kwargs)
                    except _NON_FALSIFYING:
                        raise
                    except BaseException:
                        minimal, exc = _shrink_case(run, strats, case)
                        print(f"_propcheck falsifying example: "
                              f"{fn.__qualname__}{case}")
                        if exc is not None and minimal != case:
                            print(f"_propcheck shrunk to: "
                                  f"{fn.__qualname__}{minimal}")
                            raise exc
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._pc_given = True
            return wrapper

        return deco

    def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn

        return deco


# ---------------------------------------------------------------------------
# stateful testing: rule-based state machines with program shrinking
# ---------------------------------------------------------------------------

def rule(**arg_specs):
    """Mark a ``RuleBasedStateMachine`` method as a rule.

    Keyword arguments are ``machine_st`` strategies; each executed call of
    the rule draws fresh values for them.  A method with no arguments is
    declared with bare ``@rule()``."""
    def deco(fn):
        fn._pc_rule_specs = dict(arg_specs)
        return fn

    return deco


class RuleBasedStateMachine:
    """Base class for stateful property tests (hypothesis.stateful subset).

    Subclasses define ``@rule(...)`` methods mutating/checking ``self``;
    ``run_state_machine`` executes random programs against fresh instances.
    An optional ``finalize`` method runs after the last rule of every
    program — put end-state invariants there so they participate in
    shrinking (e.g. "merging the worker caches reproduces the reference").
    """

    @classmethod
    def _rules(cls) -> dict[str, dict]:
        out = {}
        for name in sorted(dir(cls)):
            specs = getattr(getattr(cls, name), "_pc_rule_specs", None)
            if specs is not None:
                out[name] = specs
        return out


def _run_program(cls, program, *, shrinking=False) -> BaseException | None:
    """One program against a fresh machine; the triggering exception, or
    None when every rule (and ``finalize``) passed.

    Skips follow the ``given``-fallback's semantics: a ``pytest.skip`` on
    a *detection* program propagates (the test really is skipped), but on
    a *shrink candidate* it means "invalid input, keep shrinking" — it
    must neither mask the original failure nor count as one."""
    try:
        machine = cls()
        for name, kwargs in program:
            getattr(machine, name)(**kwargs)
        fin = getattr(machine, "finalize", None)
        if fin is not None:
            fin()
    except (KeyboardInterrupt, SystemExit, GeneratorExit):
        raise
    except _Skipped:
        if shrinking:
            return None
        raise
    except BaseException as e:  # noqa: BLE001 - re-raised by the caller
        return e
    return None


def _program_candidates(rules, program):
    """Strictly-simpler variants of a failing program: structural shrinks
    of the rule sequence first (halves, single-step drops), then per-call
    argument shrinks."""
    n = len(program)
    if n > 1:
        half = n // 2
        yield program[:half]
        yield program[n - half:]
    for i in range(n):
        if n > 1:
            yield program[:i] + program[i + 1:]
    for i, (name, kwargs) in enumerate(program):
        for k, spec in sorted(rules[name].items()):
            for cand in spec.shrink(kwargs[k]):
                yield (program[:i]
                       + [(name, {**kwargs, k: cand})]
                       + program[i + 1:])


def _shrink_program(cls, rules, program):
    """Greedy descent over ``_program_candidates`` (same discipline as
    ``_shrink_case``): adopt the first simpler program that still fails,
    repeat until none does or the try budget runs out."""
    best = list(program)
    best_exc = None
    tries = 0
    improved = True
    while improved and tries < MAX_SHRINK_TRIES:
        improved = False
        for cand in _program_candidates(rules, best):
            tries += 1
            exc = _run_program(cls, cand, shrinking=True)
            if exc is not None:
                best, best_exc = list(cand), exc
                improved = True
                break
            if tries >= MAX_SHRINK_TRIES:
                break
    return best, best_exc


def _format_program(program) -> str:
    return "\n".join(
        f"  {name}({', '.join(f'{k}={v!r}' for k, v in sorted(kw.items()))})"
        for name, kw in program)


def run_state_machine(cls, *, steps: int = 20, max_examples: int = 10,
                      seed=None) -> None:
    """Run ``max_examples`` random programs of 1..``steps`` rule calls
    against fresh ``cls`` instances; shrink and report the first failure.

    Deterministic: the program RNG is seeded from the machine's qualified
    name (override with ``seed=``), so a failure reproduces bit-identically
    run to run — matching the ``given`` fallback's discipline."""
    rules = cls._rules()
    if not rules:
        raise TypeError(f"{cls.__name__} defines no @rule methods")
    names = sorted(rules)
    base = (seed if seed is not None
            else f"propcheck-machine::{cls.__module__}::{cls.__qualname__}")
    for example in range(max_examples):
        rng = random.Random(f"{base}::{example}")
        program = []
        for _ in range(rng.randint(1, steps)):
            name = names[rng.randrange(len(names))]
            kwargs = {k: spec.draw(rng)
                      for k, spec in sorted(rules[name].items())}
            program.append((name, kwargs))
        exc = _run_program(cls, program)
        if exc is None:
            continue
        minimal, mexc = _shrink_program(cls, rules, program)
        print(f"_propcheck falsifying program ({cls.__name__}):")
        print(_format_program(program))
        if mexc is not None and minimal != program:
            print(f"_propcheck shrunk to ({cls.__name__}):")
            print(_format_program(minimal))
            raise mexc
        raise exc
