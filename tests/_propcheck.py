"""Property-test compatibility shim.

Uses real `hypothesis` when it is installed; otherwise provides a small,
deterministic fixed-examples fallback implementing the subset this test
suite uses: ``given``, ``settings`` and ``strategies.integers /
sampled_from / floats``.

The fallback draws a fixed number of examples per test (boundary values
first, then pseudo-random ones from a seed derived from the test name), so
runs are reproducible with or without hypothesis and tier-1 never dies at
collection on a missing optional dependency.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random
    import types

    DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A value source: boundary examples first, then seeded draws."""

        def __init__(self, edge_values, draw):
            self.edge_values = list(edge_values)
            self.draw = draw

    def _integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elems = list(elements)
        return _Strategy(elems[:2],
                         lambda rng: elems[rng.randrange(len(elems))])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    strategies = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, floats=_floats,
        booleans=_booleans)

    def given(*strats, **kw_strats):
        if kw_strats:
            raise NotImplementedError(
                "_propcheck fallback supports positional strategies only")

        def deco(fn):
            # NB: no functools.wraps — it sets __wrapped__, which makes
            # pytest resolve the original (n, m, seed) signature and demand
            # fixtures for the strategy-provided arguments.
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", DEFAULT_MAX_EXAMPLES)
                rng = random.Random(
                    f"propcheck::{fn.__module__}::{fn.__qualname__}")
                for i in range(n):
                    case = tuple(
                        s.edge_values[i] if i < len(s.edge_values)
                        else s.draw(rng)
                        for s in strats)
                    try:
                        fn(*args, *case, **kwargs)
                    except BaseException:
                        print(f"_propcheck falsifying example: "
                              f"{fn.__qualname__}{case}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._pc_given = True
            return wrapper

        return deco

    def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn

        return deco
