"""Tier-1 doctest gate for the public API surface.

The module docstrings of the explorer and simulator (plus the device-grid
registry) carry runnable ``>>>`` examples — the same ones the CI ``docs``
job executes via ``pytest --doctest-modules``.  Running them here too makes
the examples part of tier-1, so they cannot rot between doc builds: a
signature change that breaks an example breaks ``pytest -x -q``.
"""

import doctest
import importlib

import pytest

# NB: resolved via importlib, not attribute access — ``repro.core.simulate``
# the *module* is shadowed by ``repro.core.simulate`` the *function* once
# the package __init__ runs its re-exports.  ``repro.core.explorer`` is the
# backcompat alias of ``repro.search.engine``; listing both proves the alias
# resolves to a module whose examples still run.
MODULES = ("repro.search.engine", "repro.search.space", "repro.search.pareto",
           "repro.core.explorer", "repro.core.simulate", "repro.fpga.archs",
           "repro.analysis", "repro.corpus", "repro.obs",
           "repro.obs.metrics", "repro.obs.trace")


@pytest.mark.parametrize("name", MODULES)
def test_public_api_doctests(name):
    mod = importlib.import_module(name)
    results = doctest.testmod(mod, verbose=False)
    assert results.attempted > 0, f"{name} lost its >>> examples"
    assert results.failed == 0
