"""Floorplanner + autobridge orchestration + throughput simulation tests."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (Boundary, SlotGrid, TaskGraphBuilder, autobridge,
                        floorplan, pipeline_headroom, simulate)
from repro.core.ilp import InfeasibleError


def chain_graph(n, area=100, width=256):
    b = TaskGraphBuilder("chain")
    for i in range(n - 1):
        b.stream(f"s{i}", width=width)
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": area},
                 ins=[f"s{i-1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


def test_chain_snakes_through_grid():
    g = chain_graph(8)
    grid = SlotGrid("g", rows=4, cols=2, base_capacity={"LUT": 150},
                    max_util=1.0)
    plan = autobridge(g, grid)
    # a chain of 8 across 8 slots of capacity 1.5 tasks each must use all 8
    # slots, and the optimal tour has exactly 7 boundary crossings.
    assert plan.floorplan.cost == 7 * 256
    slots = set(plan.floorplan.placement.values())
    assert len(slots) == 8
    # every cross-slot edge is pipelined with 2 regs per crossing
    assert all(d == 2 for d in plan.pipelining.lat.values())


def test_capacity_respected():
    g = chain_graph(4, area=100)
    grid = SlotGrid("g", rows=2, cols=1, base_capacity={"LUT": 250},
                    max_util=1.0)
    fp = floorplan(g, grid)
    loads = {}
    for slot in fp.placement.values():
        loads[slot] = loads.get(slot, 0) + 100
    assert all(v <= 250 for v in loads.values())


def test_infeasible_raises():
    g = chain_graph(4, area=100)
    grid = SlotGrid("g", rows=2, cols=1, base_capacity={"LUT": 150},
                    max_util=1.0)
    with pytest.raises(InfeasibleError):
        floorplan(g, grid)


def test_pinning_honored():
    b = TaskGraphBuilder("pin")
    b.stream("s0", width=8)
    b.invoke("IO", area={"LUT": 10, "hbm_channels": 1}, outs=["s0"],
             pinned=(0, 1))
    b.invoke("C", area={"LUT": 10}, ins=["s0"])
    g = b.build()
    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 100},
                    slot_caps={(0, 1): {"hbm_channels": 2}}, max_util=1.0)
    fp = floorplan(g, grid)
    assert fp.placement["IO"] == (0, 1)
    assert fp.placement["C"] == (0, 1)  # width pulls C next to IO


def test_hbm_channel_binding_as_resource():
    """Paper §6.2: HBM channels are a slot resource owned by row 0 only."""
    b = TaskGraphBuilder("hbm")
    for i in range(4):
        b.stream(f"s{i}", width=512)
    for i in range(4):
        b.invoke("IO", area={"LUT": 10, "hbm_channels": 1}, outs=[f"s{i}"])
        b.invoke("PE", area={"LUT": 10}, ins=[f"s{i}"])
    g = b.build()
    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 1000},
                    slot_caps={(0, 0): {"hbm_channels": 2},
                               (0, 1): {"hbm_channels": 2}}, max_util=1.0)
    fp = floorplan(g, grid)
    for i in range(4):
        name = f"IO_{i}" if i else "IO"
        assert fp.placement[name][0] == 0, "IO must bind to HBM row"


def test_zero_capacity_utilization_reports_overflow():
    """A nonzero load on a zero-capacity resource is overflow, not 0%
    utilization (regression: it used to report 0.0 and hide the bug)."""
    from repro.core import Floorplan
    grid = SlotGrid("g", rows=1, cols=2,
                    base_capacity={"LUT": 100, "hbm_channels": 0},
                    slot_caps={(0, 1): {"hbm_channels": 2}}, max_util=1.0)
    fp = Floorplan(grid=grid, placement={}, cost=0.0, iteration_stats=[],
                   max_util=1.0,
                   slot_loads={(0, 0): {"LUT": 50.0, "hbm_channels": 1.0,
                                        "URAM": 3.0},
                               (0, 1): {"LUT": 0.0, "hbm_channels": 1.0}})
    util = fp.utilization()
    assert util[(0, 0)]["hbm_channels"] == float("inf")   # overflow surfaced
    assert util[(0, 0)]["LUT"] == pytest.approx(0.5)
    assert "URAM" not in util[(0, 0)]       # unconstrained resource: omitted
    assert util[(0, 1)]["hbm_channels"] == pytest.approx(0.5)
    assert util[(0, 1)]["LUT"] == 0.0       # zero load stays 0, not inf


def test_weighted_boundaries_prefer_cheap_crossings():
    """Pod (DCN) boundary is 8x the ICI boundary cost: the cut should go
    through the cheap one."""
    b = TaskGraphBuilder("w")
    b.stream("s0", width=100)
    b.invoke("A", area={"LUT": 100}, outs=["s0"])
    b.invoke("B", area={"LUT": 100}, ins=["s0"])
    g = b.build()
    grid = SlotGrid("tpu", rows=2, cols=2, base_capacity={"LUT": 110},
                    row_boundaries=[Boundary(weight=8.0)],
                    col_boundaries=[Boundary(weight=1.0)], max_util=1.0)
    fp = floorplan(g, grid)
    a, bb = fp.placement["A"], fp.placement["B"]
    assert a[0] == bb[0] and a[1] != bb[1], (a, bb)


# ---------------------------------------------------------------------------
# throughput preservation (the paper's central claim, via simulation)
# ---------------------------------------------------------------------------

def _simulate_piped(g, *, firings, latency, **kw):
    """Pipelined run with the almost-full round-trip headroom the pipeliner
    owns (simulate() itself adds no implicit capacity)."""
    return simulate(g, firings=firings, latency=latency,
                    extra_capacity=pipeline_headroom(latency), **kw)


def test_simulate_chain_throughput():
    g = chain_graph(4, width=32)
    base = simulate(g, firings=100)
    piped = _simulate_piped(g, firings=100,
                            latency={"s0": 2, "s1": 2, "s2": 2})
    assert not base.deadlocked and not piped.deadlocked
    # latency adds only fill/drain skew, not steady-state cycles
    assert piped.cycles - base.cycles <= 6 + 1


def test_simulate_unbalanced_vs_balanced_diamond():
    b = TaskGraphBuilder("d")
    for s in ("ab", "bd", "ad"):
        b.stream(s, width=32, depth=2)
    b.invoke("A", area={}, outs=["ab", "ad"])
    b.invoke("B", area={}, ins=["ab"], outs=["bd"])
    b.invoke("D", area={}, ins=["bd", "ad"])
    g = b.build()
    base = simulate(g, firings=200)
    unbal = _simulate_piped(g, firings=200, latency={"ab": 4, "bd": 4})
    bal = _simulate_piped(g, firings=200,
                          latency={"ab": 4, "bd": 4, "ad": 8})
    # unbalanced pipelining stalls the source through the shallow skip FIFO
    assert unbal.cycles > 1.5 * base.cycles
    # balanced depths restore full throughput: ~1 firing/cycle + fill skew
    assert bal.cycles <= 200 + 20
    assert bal.cycles <= base.cycles  # balancing never hurts


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_balanced_plans_preserve_throughput(seed):
    """Random layered DAG; pipeline random edges; balanced depths from the
    SDC solver must keep cycles within fill+drain of the unpipelined run."""
    from repro.core.balance import balance_latencies
    rng = np.random.default_rng(seed)
    layers = [["src"]]
    b = TaskGraphBuilder("rand")
    b.invoke("src", area={})
    nid = 0
    edges = []
    for _li in range(1, int(rng.integers(2, 5))):
        width = int(rng.integers(1, 4))
        layer = []
        for _j in range(width):
            name = f"t{nid}"
            nid += 1
            srcs = rng.choice(layers[-1],
                              size=int(rng.integers(1, len(layers[-1]) + 1)),
                              replace=False)
            snames = []
            for s in srcs:
                sn = f"e{len(edges)}"
                b.stream(sn, width=8)
                edges.append(sn)
                snames.append((s, sn))
            layer.append((name, snames))
        for name, snames in layer:
            b.invoke(name, area={}, ins=[sn for _, sn in snames])
            for s, sn in snames:
                b._stream_defs[sn].src = s  # wire producer
        layers.append([n for n, _ in layer])
    g = b.build()
    lat = {e: int(rng.integers(0, 4)) for e in edges}
    bal = balance_latencies([(s.name, s.src, s.dst, lat[s.name], s.width)
                             for s in g.streams])
    depth = {e: lat[e] + bal.balance[e] for e in edges}
    n = 150
    base = simulate(g, firings=n)
    piped = _simulate_piped(g, firings=n, latency=depth)
    assert not piped.deadlocked
    fill = sum(depth.values()) + g.num_tasks
    assert piped.cycles <= base.cycles + fill
    # steady state: at most +1 cycle per 50 firings beyond fill
    assert piped.cycles - base.cycles <= fill
