"""Dry-run machinery at test scale: 8 host devices, reduced configs.
(The 512-device production dry-run runs via `python -m repro.launch.dryrun`;
this test proves the same builders lower/compile in-process quickly.)"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro import configs
    from repro.distributed.taskgraph import ShapeCell
    from repro.launch import steps as S
    from repro.launch.hlo_analysis import collective_summary

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cell = ShapeCell("train_tiny", seq_len=32, global_batch=8, kind="train")
    ok = []
    for arch in ("granite-8b", "granite-moe-3b-a800m", "zamba2-7b",
                 "rwkv6-1.6b", "whisper-tiny"):
        cfg = configs.get_reduced(arch)
        with mesh:
            step, args, ins, outs = S.build_baseline_train(cfg, mesh, cell,
                                                           n_micro=2)
            c = jax.jit(step, in_shardings=ins,
                        out_shardings=outs).lower(*args).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax 0.4.x returns [dict]
            ca = ca[0] if ca else {}
        assert ca.get("flops", 0) > 0
        coll = collective_summary(c.as_text(), pod_size=4)
        assert coll["count"] > 0, arch
        ok.append(arch)
        jax.clear_caches()
    # serve path
    cell_d = ShapeCell("decode_tiny", seq_len=64, global_batch=8,
                       kind="decode")
    cfg = configs.get_reduced("gemma3-12b")
    with mesh:
        step, args, ins, outs = S.build_baseline_serve(cfg, mesh, cell_d)
        c = jax.jit(step, in_shardings=ins,
                    out_shardings=outs).lower(*args).compile()
    ok.append("serve")
    # tapa pipeline path on a refined mesh
    from repro.distributed.sharding import TpuPlan, refined_mesh
    cfg = configs.get_reduced("granite-8b")
    plan = TpuPlan(mode="tapa", n_stages=2, groups_per_stage=1,
                   stage_slots=[(0, 0), (0, 1)], boundary_depth=[2], tp=1,
                   crossing_cost=0.0)
    with mesh:
        step, args, ins, outs, _ = S.build_tapa_train(
            cfg, mesh, cell, plan=plan, n_micro=2)
        c = jax.jit(step, in_shardings=ins,
                    out_shardings=outs).lower(*args).compile()
    txt = c.as_text()
    assert "collective-permute" in txt   # the pipeline's stage shifts
    ok.append("tapa")
    print("DRYRUN_SMALL_OK", ok)
""")


@pytest.mark.slow
def test_dryrun_small_8dev():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       cwd="/root/repo", capture_output=True, text=True,
                       timeout=2400)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "DRYRUN_SMALL_OK" in r.stdout
