"""FPGA reproduction invariants: benchmark areas match the paper's
utilization tables; the co-optimization beats the packed baseline; cycles
are preserved."""
import pytest

from repro.core import analyze_timing, autobridge, packed_placement
from repro.fpga import benchmarks as B, u250_grid, u280_grid

U250 = {"LUT": 1728e3, "BRAM": 5376, "DSP": 12288}
U280 = {"LUT": 1303e3, "BRAM": 4032, "DSP": 9024, "URAM": 960}


@pytest.mark.parametrize("graph,dev,key,paper_pct", [
    (B.cnn(2), U250, "LUT", 17.8), (B.cnn(16), U250, "DSP", 67.8),
    (B.gaussian(24), U250, "LUT", 54.05), (B.bucket_sort(), U280, "LUT", 28.44),
    (B.page_rank(), U280, "LUT", 38.56), (B.spmm(False), U280, "BRAM", 71.55),
    (B.spmv(28, False), U280, "LUT", 27.95),
])
def test_areas_match_paper(graph, dev, key, paper_pct):
    tot = graph.total_area()
    pct = 100 * tot.get(key, 0) / dev[key]
    assert pct == pytest.approx(paper_pct, rel=0.06), (graph.name, key, pct)


def test_async_mmap_area_delta():
    """Table 3/8: async_mmap saves exactly 15 BRAM per channel."""
    mm = B.spmm(False).total_area()
    an = B.spmm(True).total_area()
    assert mm["BRAM"] - an["BRAM"] == 29 * 15


@pytest.mark.slow
@pytest.mark.parametrize("make,grid", [
    (lambda: B.stencil(4), u250_grid()),
    (lambda: B.cnn(4), u250_grid()),
    (lambda: B.gaussian(16), u280_grid()),
    (lambda: B.page_rank(), u280_grid()),
])
def test_tapa_beats_baseline(make, grid):
    g = make()
    base = analyze_timing(g, grid, packed_placement(g, grid))
    plan = autobridge(g, grid, max_util=0.75)
    opt = analyze_timing(g, grid, plan.floorplan.placement, plan.depth)
    assert opt.routed
    base_f = base.fmax_mhz if base.routed else 0.0
    assert opt.fmax_mhz > base_f


def test_cycles_preserved_bucket_sort():
    g = B.bucket_sort()
    plan = autobridge(g, u280_grid(), max_util=0.75)
    base, opt = plan.verify_throughput(firings=200)
    assert not opt.deadlocked
    # fill/drain only (paper Table 6: 78629 -> 78632)
    assert opt.cycles - base.cycles <= sum(plan.depth.values()) + g.num_tasks


def test_pagerank_cycles_feasible_with_control_streams():
    g = B.page_rank()
    plan = autobridge(g, u280_grid(), max_util=0.75)
    assert plan.feedback_rounds == 0         # control streams break cycles
    assert all(plan.balancing.balance[s.name] == 0
               for s in g.streams if s.control)
