"""Corpus generator + differential harness tests (``repro.corpus``).

Covers: deterministic generation and content fingerprints, family knob
coverage (broken fuzz graphs, lint-clean benchmark families, SDF rate
annotations, HBM channel demands), the ``random_graph`` test shim's
coverage classes (cycles, detached tasks, zero-capacity FIFOs), the
differential harness end to end, the HBM channel-binding axis through
``SlotGrid`` / ``SearchSpace`` / ``autobridge``, and the ``check_corpus``
CI gate's failure modes on synthetic JSONs.
"""
import copy
import dataclasses
import importlib.util
import os
import random

import pytest

from repro.analysis import analyze
from repro.core import simulate
from repro.core.autobridge import autobridge
from repro.corpus import (CLEAN_FAMILIES, FAMILIES, CorpusSpec,
                          DifferentialReport, generate_design,
                          generate_graph, graph_fingerprint, random_graph,
                          run_differential, sample_corpus)
from repro.fpga import U280_HBM_CHANNELS, grid_for, u280_grid
from repro.search.space import Interval, SearchPoint, SearchSpace


def _load_bench(name):
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks", f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# generator determinism + fingerprints
# ---------------------------------------------------------------------------

def test_generation_is_deterministic_per_family_and_seed():
    for fam in FAMILIES:
        a = generate_design(11, FAMILIES[fam])
        b = generate_design(11, FAMILIES[fam])
        assert a.fingerprint == b.fingerprint
        assert (a.latency, a.extra_capacity, a.ii, a.firings) == \
            (b.latency, b.extra_capacity, b.ii, b.firings)
        assert a.name == f"{fam}-00011"


def test_fingerprints_distinguish_seeds_families_and_content():
    fps = {generate_design(s, FAMILIES[f]).fingerprint
           for s in range(10) for f in FAMILIES}
    assert len(fps) == 10 * len(FAMILIES)   # no collisions across the set
    d = generate_design(0, FAMILIES["dag"])
    mutated = copy.deepcopy(d.graph)
    mutated.streams[0].width += 1.0
    assert graph_fingerprint(mutated) != d.fingerprint
    # fingerprinting is order-independent for tasks but not streams
    assert graph_fingerprint(d.graph) == d.fingerprint


def test_sample_corpus_is_indexable_by_seed():
    spec = FAMILIES["wide"]
    batch = sample_corpus(spec, 6, seed=3)
    assert [d.seed for d in batch] == [3, 4, 5, 6, 7, 8]
    assert batch[2].fingerprint == generate_design(5, spec).fingerprint
    # name-based lookup works too
    assert sample_corpus("wide", 2)[0].family == "wide"


# ---------------------------------------------------------------------------
# family knob coverage
# ---------------------------------------------------------------------------

def test_clean_families_lint_clean():
    """Every clean-family design must be free of structure errors — the
    CI corpus gate's lint leg."""
    grid = u280_grid()
    for fam in CLEAN_FAMILIES:
        for d in sample_corpus(fam, 12):
            rep = analyze(d.graph, grid=grid, passes=("structure",))
            assert rep.ok, (fam, d.seed, [str(x) for x in rep.diagnostics])


def test_cyclic_family_cycles_are_control_closed():
    """The cyclic family generates real feedback edges, but closed through
    control streams — so no design statically deadlocks."""
    saw_feedback = False
    for d in sample_corpus("cyclic", 12):
        rep = analyze(d.graph, latency=d.latency,
                      extra_capacity=d.extra_capacity, ii=d.ii,
                      firings=d.firings)
        assert rep.deadlock is not True, (d.seed, rep.codes())
        saw_feedback |= any(s.control for s in d.graph.streams)
    assert saw_feedback


def test_sdf_family_rate_annotations_consistent():
    saw_rates = False
    for d in sample_corpus("sdf", 8):
        for s in d.graph.streams:
            if "rate_src" in s.meta:
                saw_rates = True
                assert s.meta["rate_src"] == s.meta["rate_dst"]
        rep = analyze(d.graph, passes=("structure", "rates"))
        assert "R001-rate-inconsistent" not in rep.codes()
    assert saw_rates


def test_hbm_family_demands_channels():
    total = 0
    for d in sample_corpus("hbm", 8):
        io = [t for t in d.graph.tasks.values()
              if "hbm_channels" in t.area]
        total += len(io)
        for t in io:
            assert t.area["hbm_channels"] >= 1.0
            assert t.meta.get("hbm_io") is True
    assert total >= 8 * FAMILIES["hbm"].hbm_io_tasks[0]


def test_random_graph_shim_keeps_broken_coverage():
    """The test-helper shim must keep the coverage classes the simulator
    and analysis property tests rely on: zero-capacity FIFOs, detached
    tasks, control streams, and (allow_cycle) dependency cycles."""
    zero_cap = detached = control = 0
    deadlocks = 0
    for seed in range(60):
        rng = random.Random(seed)
        g = random_graph(rng, allow_cycle=True)
        names = {s.name for s in g.streams}
        assert len(names) == len(g.streams)
        zero_cap += any(s.depth == 0 for s in g.streams)
        detached += any(t.detached for t in g.tasks.values())
        control += any(s.control for s in g.streams)
        deadlocks += simulate(g, engine="event", firings=5,
                              max_cycles=100_000).deadlocked
    assert zero_cap > 10 and detached > 5 and control > 10
    assert deadlocks > 5            # cycles/zero-caps really deadlock
    # allow_cycle=False still builds valid graphs (no feedback edges)
    g = random_graph(random.Random(0))
    assert g.tasks


# ---------------------------------------------------------------------------
# differential harness
# ---------------------------------------------------------------------------

def test_differential_full_table_on_mixed_corpus():
    designs = []
    for fam in ("dag", "hbm"):
        designs += sample_corpus(fam, 4)
    designs += sample_corpus("fuzz", 8)
    rep = run_differential(designs, floorplan_limit=8, search_designs=1)
    assert rep.ok, rep.mismatches
    assert rep.verdicts_checked == len(designs)
    assert rep.sims_checked == len(designs)
    assert rep.feasible > 0
    assert rep.searches_checked == 1
    assert rep.families == {"dag": 4, "hbm": 4, "fuzz": 8}


def test_differential_report_flags_mismatches():
    rep = DifferentialReport()
    assert rep.ok
    d = generate_design(0, FAMILIES["dag"])
    rep._flag(d, "sim", "numpy 10 vs event 11")
    assert not rep.ok
    assert d.fingerprint in rep.mismatches[0]
    assert rep.counters()["ok"] is False


# ---------------------------------------------------------------------------
# HBM channel-binding axis
# ---------------------------------------------------------------------------

def test_with_hbm_binding_identity_and_conservation():
    g = u280_grid()
    assert g.with_hbm_binding(0.5) is g                 # symmetric default
    assert g.total_hbm_channels() == U280_HBM_CHANNELS
    assert g.hbm_slots() == [(0, 0), (0, 1)]
    tilted = g.with_hbm_binding(0.75)
    assert tilted is not g
    assert tilted.total_hbm_channels() == pytest.approx(U280_HBM_CHANNELS)
    assert tilted.slot_caps[(0, 0)]["hbm_channels"] > \
        tilted.slot_caps[(0, 1)]["hbm_channels"]
    # non-HBM capacities and the DDR slots are untouched
    assert tilted.slot_caps[(2, 0)] == g.slot_caps[(2, 0)]
    with pytest.raises(ValueError):
        g.with_hbm_binding(1.5)
    # grids without (enough) HBM slots are returned unchanged
    from repro.fpga import u250_grid
    g250 = u250_grid()
    assert g250.with_hbm_binding(0.1) is g250


def test_channel_aware_named_grids():
    left = grid_for("u280_hbm_left")
    right = grid_for("u280_hbm_right")
    assert left.slot_caps[(0, 0)]["hbm_channels"] == \
        right.slot_caps[(0, 1)]["hbm_channels"]
    assert left.total_hbm_channels() == pytest.approx(U280_HBM_CHANNELS)
    assert u280_grid(hbm_split=0.75).slot_caps == left.slot_caps


def test_search_space_hbm_axis():
    sp = SearchSpace(seeds=(0,), utils=(0.6,), hbm_splits=(0.25, 0.5, 0.75))
    assert sp.size == 3
    pts = sp.grid_points()
    assert [p.hbm_split for p in pts] == [0.25, 0.5, 0.75]
    # the default single-value axis adds nothing and keeps old enumeration
    assert SearchSpace(seeds=(0, 1), utils=(0.6, 0.7)).size == 4
    assert SearchPoint().hbm_split == 0.5
    assert SearchPoint(hbm_split=0.3).floorplan_key[-1] == 0.3
    # continuous axis sampling stays in range and refines around winners
    cont = SearchSpace(utils=(0.7,), hbm_splits=Interval(0.0, 1.0))
    draws = cont.sample(8, seed=1)
    assert all(0.0 <= p.hbm_split <= 1.0 for p in draws)
    refined = cont.refined([draws[0]])
    assert isinstance(refined.hbm_splits, Interval)
    assert refined.hbm_splits.span < 1.0


def test_autobridge_hbm_split_changes_working_grid():
    """A tilted binding really reaches the floorplanner: the plan's grid
    carries the re-bound slot_caps, and distinct splits are distinct
    floorplan cache keys."""
    from repro.core.autobridge import initial_floorplan_key
    d = generate_design(0, FAMILIES["hbm"])
    grid = u280_grid()
    k_sym = initial_floorplan_key(d.graph, grid)
    k_tilt = initial_floorplan_key(d.graph, grid, hbm_split=0.75)
    assert k_sym != k_tilt
    plan = autobridge(d.graph, grid, hbm_split=0.75)
    caps = plan.floorplan.grid.slot_caps
    assert caps[(0, 0)]["hbm_channels"] > caps[(0, 1)]["hbm_channels"]


# ---------------------------------------------------------------------------
# check_corpus gate
# ---------------------------------------------------------------------------

def _corpus_doc(**over):
    doc = {
        "suite": "corpus",
        "designs": 10,
        "lint": {"checked": 10, "errors": 0, "codes": []},
        "differential": {"ok": True, "designs": 12, "mismatches": [],
                         "verdicts_checked": 12, "sims_checked": 12,
                         "feasible": 5, "infeasible": 2,
                         "searches_checked": 1},
        "engine": {"fallback": 0},
        "buckets": [
            {"design": "dag-00000", "family": "dag",
             "hypervolume": 100.0, "hbm_axis": False},
            {"design": "hbm-00000", "family": "hbm",
             "hypervolume": 120.0, "hbm_axis": True},
        ],
    }
    doc.update(over)
    return doc


def test_check_corpus_gate_passes_and_fails():
    cr = _load_bench("check_regression")
    base = _corpus_doc()
    assert cr.check_corpus(_corpus_doc(), base, 0.02) == []
    # lint errors fail
    bad = _corpus_doc(lint={"checked": 10, "errors": 2,
                            "codes": ["A005-zero-capacity"]})
    assert any("lint" in e for e in cr.check_corpus(bad, base, 0.02))
    # differential mismatch fails, quoting the mismatch
    bad = _corpus_doc()
    bad["differential"] = dict(bad["differential"], ok=False,
                               mismatches=["[sim] dag-00001 fp=x: boom"])
    assert any("boom" in e for e in cr.check_corpus(bad, base, 0.02))
    # a stage that never ran fails
    bad = _corpus_doc()
    bad["differential"] = dict(bad["differential"], infeasible=0)
    assert any("infeasible" in e for e in cr.check_corpus(bad, base, 0.02))
    # silent backend fallback fails
    bad = _corpus_doc(engine={"fallback": 1})
    assert any("fallback" in e for e in cr.check_corpus(bad, base, 0.02))
    # hypervolume regression beyond tol fails; within tol passes
    bad = _corpus_doc()
    bad["buckets"][1] = dict(bad["buckets"][1], hypervolume=100.0)
    assert any("hypervolume" in e for e in cr.check_corpus(bad, base, 0.02))
    ok = _corpus_doc()
    ok["buckets"][1] = dict(ok["buckets"][1], hypervolume=119.0)
    assert cr.check_corpus(ok, base, 0.02) == []
    # missing bucket fails
    bad = _corpus_doc(buckets=[_corpus_doc()["buckets"][0]])
    assert any("missing" in e for e in cr.check_corpus(bad, base, 0.02))
    # no HBM-axis bucket fails
    bad = _corpus_doc()
    bad["buckets"][1] = dict(bad["buckets"][1], hbm_axis=False)
    assert any("HBM" in e for e in cr.check_corpus(bad, base, 0.02))
    # corpus shrink fails
    bad = _corpus_doc()
    bad["differential"] = dict(bad["differential"], designs=6)
    assert any("shrank" in e for e in cr.check_corpus(bad, base, 0.02))
    # main() dispatches the corpus suite
    import json
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        cur_p = os.path.join(td, "cur.json")
        with open(cur_p, "w") as f:
            json.dump(_corpus_doc(), f)
        assert cr.main([cur_p, cur_p]) == 0


def test_corpus_suite_small_run_end_to_end(tmp_path):
    """The bench suite itself on a tiny budget: JSON schema complete,
    differential ok, lint clean, and the gate accepts the run against the
    committed baseline's *structure* (self-comparison)."""
    cs = _load_bench("corpus_suite")
    out = cs.main(["--designs", "10", "--fuzz", "6",
                   "--search-per-family", "1", "--floorplans", "8",
                   "--json", str(tmp_path / "BENCH_corpus.json")])
    assert out["suite"] == "corpus"
    assert out["lint"]["errors"] == 0
    assert out["differential"]["ok"] is True
    assert out["engine"]["fallback"] == 0
    assert any(b["hbm_axis"] for b in out["buckets"])
    cr = _load_bench("check_regression")
    assert cr.main([str(tmp_path / "BENCH_corpus.json"),
                    str(tmp_path / "BENCH_corpus.json")]) == 0
