"""SDC latency balancing: exactness vs brute force, the paper's Fig. 9
worked example, and cycle detection."""
import itertools

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.balance import CycleError, balance_latencies


def brute_force_balance(edges, s_max):
    """Exhaustive search over integer potentials (tiny graphs only)."""
    nodes = sorted({n for _, s, d, _, _ in edges for n in (s, d)})
    best = None
    for vals in itertools.product(range(s_max + 1), repeat=len(nodes)):
        S = dict(zip(nodes, vals))
        ok = all(S[s] - S[d] >= lat for _, s, d, lat, _ in edges)
        if not ok:
            continue
        obj = sum(w * (S[s] - S[d] - lat) for _, s, d, lat, w in edges)
        if best is None or obj < best:
            best = obj
    return best


def test_paper_fig9_example():
    edges = [
        ("e12", "v1", "v2", 0, 1), ("e13", "v1", "v3", 1, 1),
        ("e14", "v1", "v4", 0, 2), ("e15", "v1", "v5", 0, 1),
        ("e16", "v1", "v6", 0, 1),
        ("e27", "v2", "v7", 1, 1), ("e37", "v3", "v7", 1, 1),
        ("e47", "v4", "v7", 0, 1), ("e57", "v5", "v7", 0, 1),
        ("e67", "v6", "v7", 0, 1),
    ]
    res = balance_latencies(edges)
    # paper: +2 on each of e47/e57/e67 and +1 on the v2 path => overhead 7,
    # crucially NOT placed on the width-2 edge e14.
    assert res.overhead == 7
    assert res.balance["e14"] == 0
    assert res.balance["e47"] == 2
    # every reconvergent v1->v7 path must now carry equal latency
    for _via, e_in, e_out in [("v2", "e12", "e27"), ("v3", "e13", "e37"),
                             ("v4", "e14", "e47"), ("v5", "e15", "e57"),
                             ("v6", "e16", "e67")]:
        lat = dict((n, el) for n, _, _, el, _ in edges)
        total = (lat[e_in] + res.balance[e_in]
                 + lat[e_out] + res.balance[e_out])
        assert total == 2


def test_diamond():
    edges = [("ab", "a", "b", 3, 1), ("bd", "b", "d", 0, 1),
             ("ad", "a", "d", 0, 4)]
    res = balance_latencies(edges)
    # balancing 3 units: on 'ad' costs 12; optimal is forced (only path)
    assert res.balance["ad"] == 3
    assert res.overhead == 12


def test_parallel_streams_same_pair():
    # two streams between the same tasks with different pipelining
    edges = [("s1", "a", "b", 2, 1), ("s2", "a", "b", 0, 1)]
    res = balance_latencies(edges)
    assert res.balance["s1"] == 0
    assert res.balance["s2"] == 2


def test_positive_cycle_raises():
    edges = [("ab", "a", "b", 1, 1), ("ba", "b", "a", 0, 1)]
    with pytest.raises(CycleError):
        balance_latencies(edges)


def test_zero_cycle_feasible():
    edges = [("ab", "a", "b", 0, 1), ("ba", "b", "a", 0, 1),
             ("bc", "b", "c", 2, 1)]
    res = balance_latencies(edges)
    assert res.balance["ab"] == 0 and res.balance["ba"] == 0


def test_fractional_widths_feasible():
    """0.5-wide fanout: per-node supplies used to round to a nonzero total
    demand (NetworkXUnfeasible) before widths were integer-scaled."""
    edges = [("ab", "a", "b", 1, 0.5), ("ac", "a", "c", 0, 0.5),
             ("ad", "a", "d", 0, 0.5)]
    res = balance_latencies(edges)
    assert res.overhead == 0                    # pure fanout: no balancing
    for name, s, d, lat, _ in edges:
        assert res.potentials[s] - res.potentials[d] >= lat
        assert res.balance[name] >= 0


def test_fractional_widths_match_brute_force():
    edges = [("ab", "a", "b", 2, 0.5), ("bd", "b", "d", 0, 0.25),
             ("ad", "a", "d", 0, 0.25)]
    res = balance_latencies(edges)
    ref = brute_force_balance(edges, s_max=4)
    assert res.overhead == pytest.approx(ref) == pytest.approx(0.5)
    assert res.balance["ad"] == 2               # cheapest reconvergent fix


def test_fractional_widths_mixed_with_integers():
    edges = [("ab", "a", "b", 3, 1.5), ("bd", "b", "d", 0, 0.5),
             ("ad", "a", "d", 0, 4)]
    res = balance_latencies(edges)
    ref = brute_force_balance(edges, s_max=6)
    assert res.overhead == pytest.approx(ref)


@settings(max_examples=40, deadline=None)
@given(st.integers(3, 6), st.integers(2, 9), st.integers(0, 99999))
def test_property_matches_brute_force(n, m, seed):
    rng = np.random.default_rng(seed)
    # random DAG on n nodes
    edges = []
    for j in range(m):
        u, v = sorted(rng.integers(0, n, size=2).tolist())
        if u == v:
            continue
        edges.append((f"e{j}", f"v{u}", f"v{v}",
                      int(rng.integers(0, 3)), int(rng.integers(1, 5))))
    if not edges:
        return
    res = balance_latencies(edges)
    # feasibility + non-negativity
    for name, s, d, lat, _w in edges:
        assert res.potentials[s] - res.potentials[d] >= lat
        assert res.balance[name] >= 0
    # optimality vs exhaustive search over small potential range
    max_lat = sum(el for _, _, _, el, _ in edges)
    ref = brute_force_balance(edges, s_max=max_lat)
    assert res.overhead == pytest.approx(ref)
