"""Converging search, continuous axes, floorplan memoization and batch
chunking (the PR-4 tentpole).

Covers: ``Interval`` axes (sampling determinism, refine narrowing), the
hypervolume indicator, ``search_until_converged`` (early stop on a
saturated space, monotone hypervolume trajectory, shared baseline
simulation, never worse than a single-round search), ``FloorplanCache``
(identical plans to a cold solve — property-tested over randomized graphs
— plus infeasibility caching and cross-object hits), ``simulate_batch``
byte-budget chunking, and the converge-aware CI regression gate.
"""

import importlib
import importlib.util
import json
import math
import os
import random

import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    FloorplanCache,
    Interval,
    SearchPoint,
    SearchSpace,
    SimJob,
    TaskGraphBuilder,
    SlotGrid,
    autobridge,
    engine_counts,
    explore_design_space,
    floorplan_counts,
    hypervolume,
    pareto_frontier,
    reset_engine_counts,
    reset_floorplan_counts,
    search_until_converged,
    simulate_batch,
)
from repro.core.explorer import _objective
from repro.core.ilp import InfeasibleError
from repro.fpga import u280_grid


# ---------------------------------------------------------------------------
# Interval axes
# ---------------------------------------------------------------------------


def test_interval_validates_and_spans():
    iv = Interval(0.6, 0.9)
    assert iv.span == pytest.approx(0.3)
    assert iv.clamp(0.1) == 0.6 and iv.clamp(1.5) == 0.9
    assert Interval(0.7, 0.7).span == 0.0
    with pytest.raises(ValueError):
        Interval(0.9, 0.6)


def test_continuous_space_sampling_is_deterministic_and_in_range():
    space = SearchSpace(seeds=(0, 1), utils=Interval(0.6, 0.9),
                        depth_scales=(1.0, 2.0))
    assert space.continuous
    assert space.size == math.inf
    pts = space.sample(16, seed=3)
    assert len(pts) == len(set(pts)) == 16
    for p in pts:
        assert 0.6 <= p.max_util <= 0.9
        assert p.seed in (0, 1) and p.depth_scale in (1.0, 2.0)
    assert pts == space.sample(16, seed=3)
    assert pts != space.sample(16, seed=4)
    with pytest.raises(ValueError):
        space.grid_points()


def test_discrete_space_behavior_unchanged():
    space = SearchSpace(seeds=(0, 1), utils=(0.6, 0.7))
    assert not space.continuous
    assert space.size == 4
    assert space.sample(10) == space.grid_points()


def test_refine_narrows_intervals_around_frontier():
    space = SearchSpace(utils=Interval(0.5, 1.0), row_weights=(1.0, 2.0))
    frontier = [SearchPoint(max_util=0.75, row_weight=2.0)]
    pts = space.refine(frontier, 30, seed=5)
    assert pts
    # quarter-span padding around a single winner: [0.625, 0.875]
    for p in pts:
        assert 0.625 - 1e-9 <= p.max_util <= 0.875 + 1e-9
        assert p.row_weight in (1.5, 2.0)  # discrete axis: midpoint halving
    # refinement never escapes the original range, even near an edge
    edge = [SearchPoint(max_util=0.98)]
    for p in space.refine(edge, 20, seed=6):
        assert 0.5 <= p.max_util <= 1.0


# ---------------------------------------------------------------------------
# hypervolume
# ---------------------------------------------------------------------------


def test_hypervolume_known_values():
    assert hypervolume([(2.0, 2.0)], (0.0, 0.0)) == pytest.approx(4.0)
    assert hypervolume([(2.0, 1.0), (1.0, 2.0)], (0.0, 0.0)) == pytest.approx(3.0)
    assert hypervolume([(2.0, 1.0), (1.0, 2.0), (1.5, 1.5)],
                       (0.0, 0.0)) == pytest.approx(3.25)
    assert hypervolume([], (0.0, 0.0)) == 0.0
    # 3D: unit cube plus a disjoint sliver
    assert hypervolume([(1, 1, 1), (2, 1, 0.5)], (0, 0, 0)) == pytest.approx(1.5)


def test_hypervolume_dominated_and_clipped_points_add_nothing():
    base = hypervolume([(2.0, 2.0)], (0.0, 0.0))
    assert hypervolume([(2.0, 2.0), (1.0, 1.0)], (0.0, 0.0)) == base
    assert hypervolume([(2.0, 2.0), (-5.0, 9.0)], (0.0, 0.0)) == base


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.0, 4.0), min_size=2, max_size=6),
       st.floats(0.0, 4.0), st.floats(0.0, 4.0))
def test_hypervolume_monotone_under_adding_points(coords, x, y):
    pts = [(coords[i], coords[i + 1]) for i in range(len(coords) - 1)]
    before = hypervolume(pts, (0.0, 0.0))
    after = hypervolume(pts + [(x, y)], (0.0, 0.0))
    assert after >= before - 1e-12


# ---------------------------------------------------------------------------
# search_until_converged
# ---------------------------------------------------------------------------


def _chain_graph(widths=(64, 64, 64)):
    b = TaskGraphBuilder("chain")
    for i, w in enumerate(widths):
        b.stream(f"s{i}", width=w)
    n = len(widths) + 1
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": 100},
                 ins=[f"s{i - 1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


def _small_grid():
    return SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 150},
                    max_util=1.0)


def _vecadd():
    pe = 4
    b = TaskGraphBuilder("VecAdd")
    a = b.streams("str_a", n=pe, width=512)
    bb = b.streams("str_b", n=pe, width=512)
    c = b.streams("str_c", n=pe, width=512)
    b.invoke("LoadA", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=a, count=pe)
    b.invoke("LoadB", area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
             outs=bb, count=pe)
    b.invoke("Add", area={"LUT": 60e3, "DSP": 256}, ins=a + bb, outs=c,
             count=pe)
    b.invoke("Store", area={"LUT": 12e3, "hbm_channels": 1}, ins=c, count=pe)
    return b.build()


def test_converged_search_stops_early_on_saturated_space():
    """A space whose frontier saturates in round 1 must converge (and stop)
    at round 2, not burn the whole round budget."""
    res = search_until_converged(
        _chain_graph(), _small_grid(),
        space=SearchSpace(utils=Interval(0.9, 1.0)),
        rounds=6, points_per_round=4, sim_firings=50, tol=0.02)
    assert res.converged
    assert res.rounds_run == 2 < 6
    assert len(res.hypervolumes) == 2
    assert res.hypervolumes[0] == pytest.approx(res.hypervolumes[1])


def test_converged_search_hypervolume_never_regresses():
    res = search_until_converged(
        _vecadd(), u280_grid(),
        space=SearchSpace(utils=Interval(0.6, 0.9),
                          depth_scales=(1.0, 2.0)),
        rounds=3, points_per_round=8, sim_firings=60, tol=0.0)
    assert res.hypervolumes == sorted(res.hypervolumes)
    assert res.frontier and pareto_frontier(res.frontier) == res.frontier
    # the merged frontier dedups re-anchored points: one candidate per point
    pts = [c.point for c in res.frontier]
    assert len(pts) == len(set(pts))


def test_converged_search_reuses_one_baseline_simulation():
    res = search_until_converged(
        _vecadd(), u280_grid(),
        space=SearchSpace(utils=Interval(0.6, 0.9)),
        rounds=3, points_per_round=6, sim_firings=50, tol=0.0)
    assert res.rounds_run >= 2
    base_ids = {id(c.base_sim) for c in res.frontier if c.base_sim}
    assert len(base_ids) == 1  # every round shares round 1's baseline
    # jobs across all batch calls: one baseline total, not one per round
    counts = engine_counts()
    assert counts["cycle"] == 0
    assert res.sim_calls == res.rounds_run


def test_converged_search_beats_single_round_and_proves_cache_hits():
    """The acceptance criterion: on the quickstart design the converged
    frontier's hypervolume is >= the single-round frontier's, with
    floorplan_counts() showing strictly fewer ILP solves than points
    evaluated and cache hits > 0."""
    graph = _vecadd()
    grid = u280_grid()
    space = SearchSpace(seeds=(0,), utils=(0.6, 0.7, 0.8),
                        depth_scales=(1.0, 2.0))
    single = explore_design_space(graph, grid, space=space, sim_firings=60)

    reset_floorplan_counts()
    conv = search_until_converged(
        graph, grid,
        space=SearchSpace(seeds=(0,), utils=Interval(0.6, 0.8),
                          depth_scales=(1.0, 2.0)),
        rounds=3, points_per_round=8, sim_firings=60,
        initial_points=space.grid_points())
    counts = floorplan_counts()

    # common reference point below both frontiers
    vecs_s = [_objective(c) for c in single.frontier]
    vecs_c = [_objective(c) for c in conv.frontier]
    ref = tuple(min(v[i] for v in vecs_s + vecs_c) - 1.0 for i in range(3))
    assert hypervolume(vecs_c, ref) >= hypervolume(vecs_s, ref) - 1e-9

    assert counts["cache_hits"] > 0
    assert counts["solved"] < conv.points_evaluated
    assert conv.best.fmax >= single.best.fmax


# ---------------------------------------------------------------------------
# FloorplanCache
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 6), st.integers(0, 2))
def test_floorplan_cache_returns_identical_plans_to_cold_solve(n, seed):
    """Property: for randomized graphs, a cache-hitting autobridge run is
    indistinguishable from a cold one — same placement, cost and depths —
    even across distinct-but-equal graph objects."""
    rng = random.Random(10_007 * n + seed)
    widths = tuple(rng.choice((32, 64, 128)) for _ in range(n - 1))

    def build():
        return _chain_graph(widths)

    grid = SlotGrid("g", rows=2, cols=2,
                    base_capacity={"LUT": 100.0 * n}, max_util=1.0)
    cache = FloorplanCache()
    cold = autobridge(build(), grid, seed=seed)
    warm1 = autobridge(build(), grid, seed=seed, cache=cache)
    warm2 = autobridge(build(), grid, seed=seed, cache=cache)
    assert cache.hits >= 1  # warm2 hit warm1's entry (equal, distinct graph)
    for plan in (warm1, warm2):
        assert plan.floorplan.placement == cold.floorplan.placement
        assert plan.floorplan.cost == pytest.approx(cold.floorplan.cost)
        assert plan.depth == cold.depth
        assert plan.area_overhead == pytest.approx(cold.area_overhead)


def test_floorplan_cache_key_separates_knobs():
    cache = FloorplanCache()
    g = _chain_graph()
    grid = _small_grid()
    autobridge(g, grid, seed=0, cache=cache)
    autobridge(g, grid, seed=0, cache=cache)           # hit
    autobridge(g, grid, seed=1, cache=cache)           # new seed -> miss
    autobridge(g, grid, seed=0, max_util=0.9, cache=cache)  # new util -> miss
    # depth_scale does NOT key the floorplan: same entry, new working grid
    plan = autobridge(g, grid, seed=0, depth_scale=2.0, cache=cache)
    assert cache.hits == 2 and cache.misses == 3
    assert plan.floorplan.grid.row_boundaries[0].pipeline_depth == 4


def test_floorplan_cache_caches_infeasibility():
    cache = FloorplanCache()
    g = _chain_graph()
    tiny = SlotGrid("tiny", rows=1, cols=2, base_capacity={"LUT": 10},
                    max_util=1.0)
    for _ in range(2):
        with pytest.raises(InfeasibleError):
            autobridge(g, tiny, cache=cache)
    assert cache.misses == 1 and cache.hits == 1


# ---------------------------------------------------------------------------
# simulate_batch byte-budget chunking
# ---------------------------------------------------------------------------


def test_simulate_batch_chunking_matches_unchunked():
    g1 = _chain_graph()
    g2 = _vecadd()
    jobs = [SimJob(g1), SimJob(g1, ii={"K0": 3}), SimJob(g2),
            SimJob(g2, latency={"str_a[0]": 2},
                   extra_capacity={"str_a[0]": 4})]
    full = simulate_batch(jobs, firings=40, backend="numpy")
    assert engine_counts()["numpy"] == 1
    reset_engine_counts()
    chunked = simulate_batch(jobs, firings=40, backend="numpy",
                             max_bytes=1)                   # 1 job/chunk
    # engine counters report the chunk count
    assert engine_counts()["numpy"] == len(jobs)
    assert engine_counts()["event"] == 0
    for a, b in zip(full, chunked):
        assert (a.cycles, a.fired, a.deadlocked) == (b.cycles, b.fired,
                                                     b.deadlocked)
    # an intermediate budget splits into fewer, larger chunks
    sim_mod = importlib.import_module("repro.core.simulate")
    reset_engine_counts()
    two = simulate_batch(jobs, firings=40, backend="numpy",
                         max_bytes=2 * sim_mod._job_bytes_estimate(jobs))
    assert 1 < engine_counts()["numpy"] <= len(jobs)
    assert [r.cycles for r in two] == [r.cycles for r in full]


def test_simulate_batch_default_budget_keeps_one_sweep():
    g = _chain_graph()
    simulate_batch([SimJob(g) for _ in range(20)], firings=30,
                   backend="numpy")
    assert engine_counts()["numpy"] == 1


# ---------------------------------------------------------------------------
# converge-aware regression gate
# ---------------------------------------------------------------------------


def _load_check_regression():
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks",
        "check_regression.py",
    )
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _converged_doc(opt_avg, *, hits, solved, points):
    return {
        "suite": "fmax_suite",
        "converge": True,
        "rows": [{"name": "d", "board": "u280", "opt_mhz": opt_avg}],
        "summary": {
            "opt_avg_mhz": opt_avg,
            "sim_deadlocks": 0,
            "throughput_violations": 0,
        },
        "sim": {
            "mode": "converged",
            "counts": {"event": 2, "cycle": 0, "numpy": 6},
            "floorplan": {"solved": solved, "cache_hits": hits,
                          "ilp_bipartitions": 3 * solved},
            "points_evaluated": points,
            "analysis": {"analyzed": points, "doomed": 0, "skipped": 0,
                         "infeasible": 0},
        },
    }


def test_check_regression_converged_gate(tmp_path):
    cr = _load_check_regression()

    def write(name, doc):
        p = tmp_path / name
        p.write_text(json.dumps(doc))
        return str(p)

    base = write("base.json", {
        "suite": "fmax_suite",
        "rows": [{"name": "d", "board": "u280", "opt_mhz": 300.0}],
        "summary": {"opt_avg_mhz": 300.0, "sim_deadlocks": 0,
                    "throughput_violations": 0},
    })
    ok = write("ok.json", _converged_doc(305.0, hits=10, solved=20, points=40))
    assert cr.main([ok, base]) == 0
    # no cache hits -> memoization silently dead -> fail
    cold = write("cold.json",
                 _converged_doc(305.0, hits=0, solved=40, points=40))
    assert cr.main([cold, base]) == 1
    # one solve per point -> fail even with hits recorded elsewhere
    full = write("full.json",
                 _converged_doc(305.0, hits=3, solved=40, points=40))
    assert cr.main([full, base]) == 1
    # fmax regression still gates converged runs
    slow = write("slow.json",
                 _converged_doc(200.0, hits=10, solved=20, points=40))
    assert cr.main([slow, base]) == 1
    # a cycle-engine fallback fails; extra event runs (1-job rounds) do not
    doc = _converged_doc(305.0, hits=10, solved=20, points=40)
    doc["sim"]["counts"]["cycle"] = 1
    bad = write("cyc.json", doc)
    assert cr.main([bad, base]) == 1
    # the padded array backend must have run at least once: a run whose
    # every round degraded to per-job event simulation fails
    doc = _converged_doc(305.0, hits=10, solved=20, points=40)
    doc["sim"]["counts"] = {"event": 24, "cycle": 0, "numpy": 0}
    noarr = write("noarr.json", doc)
    assert cr.main([noarr, base]) == 1


def _parallel_doc(opt_avg, *, jobs=2, dispatched=30, merged=30, solves=30,
                  hypervolume=1.5, rounds=3):
    doc = _converged_doc(opt_avg, hits=10, solved=20, points=40)
    doc["rows"][0].update({"util": 0.8, "frontier": 2,
                           "hypervolume": hypervolume,
                           "rounds_run": rounds, "points_evaluated": 40})
    doc["sim"]["pool"] = {"jobs": jobs, "dispatched": dispatched,
                          "merged": merged, "worker_solves": solves,
                          "worker_infeasible": 0}
    return doc


def test_check_regression_parallel_gate(tmp_path):
    """Both JSONs converged -> the exact-identity parallel gate: any row
    divergence or missing/short pool counters fails, identical rows pass."""
    cr = _load_check_regression()

    def write(name, doc):
        p = tmp_path / name
        p.write_text(json.dumps(doc))
        return str(p)

    seq = write("seq.json", _parallel_doc(305.0, jobs=1, dispatched=0,
                                          merged=0, solves=0))
    par = write("par.json", _parallel_doc(305.0))
    assert cr.main([par, seq]) == 0
    # bit-identity: even an above-tolerance fmax IMPROVEMENT fails
    better = write("better.json", _parallel_doc(306.0))
    assert cr.main([better, seq]) == 1
    # hypervolume divergence fails
    hv = write("hv.json", _parallel_doc(305.0, hypervolume=1.6))
    assert cr.main([hv, seq]) == 1
    # rounds divergence fails
    rd = write("rd.json", _parallel_doc(305.0, rounds=2))
    assert cr.main([rd, seq]) == 1
    # pool metadata must prove subprocess work: jobs < 2 fails...
    j1 = write("j1.json", _parallel_doc(305.0, jobs=1))
    assert cr.main([j1, seq]) == 1
    # ...as do unmerged worker results and dispatches without solves
    um = write("um.json", _parallel_doc(305.0, merged=29))
    assert cr.main([um, seq]) == 1
    ns = write("ns.json", _parallel_doc(305.0, solves=0))
    assert cr.main([ns, seq]) == 1
    # missing pool block entirely fails
    nop = _parallel_doc(305.0)
    del nop["sim"]["pool"]
    nopool = write("nopool.json", nop)
    assert cr.main([nopool, seq]) == 1


def _load_check_links():
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "docs", "check_links.py")
    spec = importlib.util.spec_from_file_location("check_links", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_link_checker_resolves_and_fails_correctly(tmp_path):
    cl = _load_check_links()
    a = tmp_path / "a.md"
    b = tmp_path / "b.md"
    b.write_text("# Real Heading\n\nbody\n")
    a.write_text("[ok](b.md) [anchor](b.md#real-heading) [self](#my-title)\n"
                 "# My Title\n")
    assert cl.main([str(a)]) == 0
    a.write_text("[broken](missing.md) [bad](b.md#nope)\n")
    assert cl.main([str(a)]) == 1
    # repo docs stay green (the CI docs job runs exactly this)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = [os.path.join(root, "README.md"),
             os.path.join(root, "docs", "architecture.md"),
             os.path.join(root, "docs", "search-guide.md"),
             os.path.join(root, "docs", "deployment.md")]
    assert cl.main(files) == 0
