"""Property tests for the canonical padded batch layout
(``repro.kernels.padded_batch``) in isolation.

The two invariants every array backend relies on:

* **phantom tasks never fire** — padding columns are masked out of the
  firing rule (``task_active`` False) and vacuously done in the
  termination/deadlock checks (``counted`` False);
* **phantom streams never stall** — padding streams attach to the
  sentinel task column, so no real task's readiness can ever depend on
  them.

Structural properties check the masks/sentinels directly on randomized
heterogeneous batches; behavioral properties compare each job's padded
result against its own unpadded batch-of-one run (equal cycles prove no
phantom stream ever stalled a real task) and, under jax, inspect the
sweep's padded ``fired`` array itself (phantom columns must stay 0).
"""

import random

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import SimJob, simulate_batch
from repro.core.simulate import _jax_ready
from repro.corpus import random_graph as _random_graph
from repro.kernels.padded_batch import build_padded_batch

jax_only = pytest.mark.skipif(not _jax_ready(), reason="jax not installed")


def _mixed_jobs(seed: int) -> list:
    """2-6 jobs over independently random topologies (cycles, detached
    tasks, zero-capacity FIFOs, random latency/headroom/II knobs)."""
    rng = random.Random(seed)
    jobs = []
    for _ in range(rng.randint(2, 6)):
        g = _random_graph(rng, allow_cycle=True)
        lat = {s.name: rng.randint(0, 4) for s in g.streams}
        extra = {s.name: rng.choice([0, 0, 2, 2 * lat[s.name]]) for s in g.streams}
        ii = {n: rng.randint(1, 4) for n in g.tasks}
        jobs.append(SimJob(g, latency=lat, extra_capacity=extra, ii=ii))
    return jobs


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 99_999))
def test_layout_masks_keep_padding_inert(seed):
    """Structural invariants: groups tile the rows contiguously, ``perm``
    is a permutation, and every padding column is inert — masked tasks
    with identity II, sentinel-attached streams with zero knobs."""
    jobs = _mixed_jobs(seed)
    pb = build_padded_batch(jobs)
    assert sorted(pb.perm) == list(range(pb.V))
    spans = [(g.r0, g.r1) for g in pb.groups]
    assert spans[0][0] == 0 and spans[-1][1] == pb.V
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    assert pb.T == max(g.T for g in pb.groups)
    assert pb.S == max(g.S for g in pb.groups)
    assert pb.H >= int(pb.lat.max(initial=0)) + 2
    # counted (termination-relevant) is a subset of the real-task mask
    assert (pb.counted <= pb.task_active).all()
    for g in pb.groups:
        rows = slice(g.r0, g.r1)
        T, S = g.T, g.S
        # phantom tasks: out of the firing rule, vacuously done, II=1
        assert pb.task_active[rows, :T].all()
        assert not pb.task_active[rows, T:].any()
        assert not pb.counted[rows, T:].any()
        assert (pb.ii[rows, T:] == 1).all()
        # phantom streams: attached to the sentinel column, zero knobs
        assert pb.stream_active[rows, :S].all()
        assert not pb.stream_active[rows, S:].any()
        assert (pb.cons[rows, S:] == pb.T).all()
        assert (pb.prod[rows, S:] == pb.T).all()
        assert (pb.lat[rows, S:] == 0).all()
        assert (pb.cap[rows, S:] == 0).all()
        # real streams always attach below the group's own task count
        if S:
            assert (pb.cons[rows, :S] < T).all()
            assert (pb.prod[rows, :S] < T).all()
        # incidence matrices agree with the flat producer/consumer maps
        for si in range(S):
            assert g.a_in[si].sum() == 1 and g.a_in[si, g.cons[si]] == 1
            assert g.a_out[si].sum() == 1 and g.a_out[si, g.prod[si]] == 1
        assert (g.indeg == g.a_in.sum(axis=0)).all()
        assert (g.outdeg == g.a_out.sum(axis=0)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 99_999))
def test_phantom_streams_never_stall_padded_equals_solo(seed):
    """Behavioral: each job's padded result equals its own batch-of-one
    run (where no cross-job padding exists at all) — so phantom streams
    introduced by batching can never have stalled a real task."""
    jobs = _mixed_jobs(seed)
    padded = simulate_batch(jobs, firings=20, backend="numpy")
    for job, got in zip(jobs, padded):
        solo = simulate_batch([job], firings=20, backend="numpy")[0]
        assert got.cycles == solo.cycles
        assert got.fired == solo.fired
        assert got.deadlocked == solo.deadlocked


@jax_only
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 99_999))
def test_phantom_tasks_never_fire(seed):
    """Behavioral, on the sweep's own padded state: every phantom task
    column — group padding AND the jit bucketing's extra columns — ends
    the sweep with a zero firing count."""
    from repro.kernels.sim_sweep import simulate_padded_jax

    jobs = _mixed_jobs(seed)
    pb = build_padded_batch(jobs)
    _, _, fired, _ = simulate_padded_jax(pb, firings=20, max_cycles=11_280)
    fired = np.asarray(fired)
    T = pb.T
    assert (fired[:, :T][~pb.task_active] == 0).all()
    assert (fired[:, T:] == 0).all()


def test_unpack_restores_original_job_order():
    """``unpack`` inverts the grouping permutation: padded row ``v`` lands
    at original index ``perm[v]``, and each result's fired dict names
    exactly its own graph's tasks (phantom columns never leak out)."""
    jobs = _mixed_jobs(123)
    pb = build_padded_batch(jobs)
    cycles = np.arange(pb.V)
    dead = np.zeros(pb.V, dtype=bool)
    fired = np.zeros((pb.V, pb.T), dtype=np.int64)
    out = pb.unpack(cycles, dead, fired, 7, "test")
    assert all(r is not None for r in out)
    for v in range(pb.V):
        assert out[pb.perm[v]].cycles == v
    for job, res in zip(jobs, out):
        assert set(res.fired) == set(job.graph.tasks)
        assert res.steps == 7 and res.engine == "test"
