"""Joint design-space search tests (paper §6.3 generalized).

Covers the Pareto pruner (dominated-point removal, tie handling),
``SearchSpace`` enumeration/sampling, ``explore_floorplans`` backward
compatibility on the candidate fields PR 1 introduced (``sim``,
``base_sim``, ``throughput_preserved``), knob plumbing through
``SlotGrid.with_knobs``, profile-driven FIFO sizing, the CI regression
gate, and the headline acceptance: >= 100 joint configurations on the
quickstart design scored with <= 5 ``simulate_batch`` calls.
"""

import importlib.util
import json
import os

import pytest

from repro.core import (
    SearchSpace,
    SlotGrid,
    TaskGraphBuilder,
    best_candidate,
    explore_design_space,
    explore_floorplans,
    pareto_frontier,
    pareto_indices,
    simulate,
)
from repro.core import explorer as explorer_mod
from repro.fpga import u280_grid


# ---------------------------------------------------------------------------
# Pareto pruner
# ---------------------------------------------------------------------------


def test_pareto_removes_dominated():
    vecs = [(1.0, 1.0), (2.0, 2.0), (0.5, 3.0), (1.5, 1.5)]
    # (1,1) and (1.5,1.5) are dominated by (2,2); (0.5,3) survives on axis 2
    assert pareto_indices(vecs) == [1, 2]


def test_pareto_keeps_exact_ties():
    vecs = [(1.0, 2.0), (1.0, 2.0), (0.0, 0.0)]
    # identical vectors never dominate each other: both copies survive
    assert pareto_indices(vecs) == [0, 1]


def test_pareto_partial_tie_on_one_axis():
    vecs = [(1.0, 5.0), (1.0, 4.0)]
    # equal on axis 1, strictly worse on axis 2 -> dominated
    assert pareto_indices(vecs) == [0]


def test_pareto_single_and_empty():
    assert pareto_indices([(3.0, 1.0)]) == [0]
    assert pareto_indices([]) == []


def test_pareto_three_axis_frontier_is_mutually_nondominated():
    vecs = [(1, 9, 1), (2, 8, 2), (3, 7, 3), (1, 1, 1), (3, 7, 3)]
    keep = pareto_indices(vecs)
    assert 3 not in keep  # strictly dominated
    kept = [vecs[i] for i in keep]
    assert pareto_indices(kept) == list(range(len(kept)))


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------


def test_search_space_grid_enumeration():
    space = SearchSpace(
        seeds=(0, 1), utils=(0.6, 0.7), row_weights=(1.0, 2.0), depth_scales=(1.0,)
    )
    pts = space.grid_points()
    assert space.size == len(pts) == 8
    assert len(set(pts)) == 8
    # single-seed wrapper ordering: utils vary slowest after seed
    assert [p.max_util for p in pts[:4]] == [0.6, 0.6, 0.7, 0.7]


def test_search_space_sampling_is_deterministic_subset():
    space = SearchSpace(seeds=(0, 1, 2), utils=(0.6, 0.7, 0.8), depth_scales=(1, 2))
    pts = space.sample(7, seed=42)
    assert len(pts) == len(set(pts)) == 7
    assert set(pts) <= set(space.grid_points())
    assert pts == space.sample(7, seed=42)
    # n >= size degrades to the full grid
    assert space.sample(10_000) == space.grid_points()


def test_with_knobs_scales_weights_and_depths():
    grid = u280_grid()
    scaled = grid.with_knobs(row_weight=3.0, depth_scale=2.0)
    assert scaled.row_boundaries[0].weight == 3.0 * grid.row_boundaries[0].weight
    assert (
        scaled.row_boundaries[0].pipeline_depth
        == 2 * grid.row_boundaries[0].pipeline_depth
    )
    # physical delay is a device property, never scaled
    assert scaled.row_boundaries[0].delay_ns == grid.row_boundaries[0].delay_ns
    # identity knobs return the grid unchanged (no copy churn)
    assert grid.with_knobs() is grid


# ---------------------------------------------------------------------------
# explore_floorplans backward compatibility
# ---------------------------------------------------------------------------


def _chain_graph():
    b = TaskGraphBuilder("chain")
    for i in range(3):
        b.stream(f"s{i}", width=64)
    for i in range(4):
        b.invoke(
            f"K{i}",
            area={"LUT": 100},
            ins=[f"s{i - 1}"] if i > 0 else [],
            outs=[f"s{i}"] if i < 3 else [],
        )
    return b.build()


def _small_grid():
    return SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 150}, max_util=1.0)


def test_explore_floorplans_backcompat_fields():
    cands = explore_floorplans(
        _chain_graph(), _small_grid(), utils=(0.3, 0.8, 1.0), sim_firings=100
    )
    assert [c.max_util for c in cands] == [0.3, 0.8, 1.0]
    infeasible = cands[0]
    assert infeasible.plan is None and infeasible.error
    assert infeasible.sim is None and infeasible.throughput_preserved is None
    feasible = [c for c in cands if c.plan is not None]
    assert feasible
    for c in feasible:
        assert c.sim is not None and not c.sim.deadlocked
        assert c.throughput_preserved is True
        # the shared baseline is simulated once for the whole sweep
        assert c.base_sim is feasible[0].base_sim
        assert c.point is not None and c.point.max_util == c.max_util
    assert best_candidate(cands).plan is not None


def test_explore_floorplans_without_sim():
    cands = explore_floorplans(_chain_graph(), _small_grid(), utils=(0.8,))
    (c,) = cands
    assert c.sim is None and c.base_sim is None
    assert c.throughput_preserved is None


# ---------------------------------------------------------------------------
# joint search acceptance (quickstart design)
# ---------------------------------------------------------------------------


def _vecadd():
    pe = 4
    b = TaskGraphBuilder("VecAdd")
    a = b.streams("str_a", n=pe, width=512)
    bb = b.streams("str_b", n=pe, width=512)
    c = b.streams("str_c", n=pe, width=512)
    b.invoke(
        "LoadA",
        area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
        outs=a,
        count=pe,
    )
    b.invoke(
        "LoadB",
        area={"LUT": 12e3, "BRAM": 30, "hbm_channels": 1},
        outs=bb,
        count=pe,
    )
    b.invoke("Add", area={"LUT": 60e3, "DSP": 256}, ins=a + bb, outs=c, count=pe)
    b.invoke("Store", area={"LUT": 12e3, "hbm_channels": 1}, ins=c, count=pe)
    return b.build()


def test_explore_design_space_quickstart_acceptance(monkeypatch):
    """>= 100 joint (seed x util x weight x depth) configurations on the
    quickstart design, <= 5 simulate_batch calls, Pareto-only frontier,
    and a best candidate no worse than the old single-axis sweep."""
    graph = _vecadd()
    grid = u280_grid()
    calls = []
    real_batch = explorer_mod.simulate_batch

    def counting_batch(jobs, **kw):
        calls.append(len(list(jobs)))
        return real_batch(jobs, **kw)

    monkeypatch.setattr(explorer_mod, "simulate_batch", counting_batch)
    space = SearchSpace(
        seeds=(0, 1, 2, 3),
        row_weights=(1.0, 2.0),
        depth_scales=(1.0, 2.0),
    )
    assert space.size >= 100
    res = explore_design_space(
        graph, grid, space=space, sim_firings=60, fifo_sizing=True
    )
    assert res.space_size == len(res.candidates) == space.size
    assert len(calls) == res.sim_calls
    assert res.sim_calls <= 5

    # frontier: non-empty, subset of candidates, mutually non-dominated
    assert res.frontier
    assert pareto_frontier(res.frontier) == res.frontier
    feasible = [c for c in res.candidates if c.plan is not None]
    assert set(id(c) for c in res.frontier) <= set(id(c) for c in feasible)

    best = res.best
    assert best in res.frontier
    assert best.throughput_preserved is True

    # no worse than the old single-axis sweep (same default utils, seed 0)
    old_best = best_candidate(explore_floorplans(graph, grid, sim_firings=60))
    assert best.fmax >= old_best.fmax

    # profile-driven FIFO sizing: trimming to observed peak occupancy must
    # reproduce the exact simulated schedule, never grow capacity, and its
    # savings metric must be non-negative
    for c in res.frontier:
        assert c.profile is not None and c.sized_capacity is not None
        assert c.sized_sim.cycles == c.sim.cycles
        assert not c.sized_sim.deadlocked
        uniform = c.plan.sim_extra_capacity
        assert all(e <= uniform[n] for n, e in c.sized_capacity.items())
        assert c.fifo_savings_bits >= 0


def test_demotion_mutation_is_confined_to_candidate_copies(monkeypatch):
    """autobridge's cycle-breaking last resort demotes a stream by mutating
    the input graph; the joint sweep must not leak that into later points,
    the shared baseline, or the caller's graph."""
    graph = _chain_graph()
    grid = _small_grid()
    real_autobridge = explorer_mod.autobridge
    mutated_calls = []

    def demoting_autobridge(g, *a, **kw):
        plan = real_autobridge(g, *a, **kw)
        g.streams[0].control = True  # simulate the demotion fallback
        mutated_calls.append(kw.get("seed"))
        return plan

    monkeypatch.setattr(explorer_mod, "autobridge", demoting_autobridge)
    res = explore_design_space(
        graph, grid, space=SearchSpace(seeds=(0,), utils=(0.8, 1.0)), sim_firings=50
    )
    # caller's graph untouched
    assert not graph.streams[0].control
    # each candidate's plan lives on its own private copy with the demotion
    for c in res.candidates:
        assert c.plan is not None
        assert c.plan.graph is not graph
        assert c.plan.graph.streams[0].control
    # infeasible + mutating run also restores the caller's flags
    def failing_autobridge(g, *a, **kw):
        g.streams[0].control = True
        raise explorer_mod.InfeasibleError("boom")

    monkeypatch.setattr(explorer_mod, "autobridge", failing_autobridge)
    res = explore_design_space(graph, grid, space=SearchSpace(seeds=(0,), utils=(0.8,)))
    assert not graph.streams[0].control
    assert res.candidates[0].error


def test_depth_scale_variants_share_floorplan_but_differ_in_depth():
    graph = _vecadd()
    grid = u280_grid()
    space = SearchSpace(seeds=(0,), utils=(0.7,), depth_scales=(1.0, 2.0))
    res = explore_design_space(graph, grid, space=space)
    c1, c2 = res.candidates
    assert c1.plan.floorplan.placement == c2.plan.floorplan.placement
    crossing = [n for n, d in c1.plan.pipelining.lat.items() if d > 0]
    assert crossing, "expected at least one cross-slot stream"
    for n in crossing:
        assert c2.plan.pipelining.lat[n] == 2 * c1.plan.pipelining.lat[n]


# ---------------------------------------------------------------------------
# event-engine occupancy profiles
# ---------------------------------------------------------------------------


def test_stream_profile_histogram_and_backpressure():
    b = TaskGraphBuilder("pc")
    b.stream("s", width=32, depth=2)
    b.invoke("P", area={}, outs=["s"])
    b.invoke("C", area={}, ins=["s"])
    g = b.build()
    # consumer at II=3 -> the FIFO saturates and the producer stalls
    res = simulate(g, firings=10, ii={"C": 3}, profile=True)
    p = res.profiles["s"]
    assert p.capacity == 2
    assert p.peak == 2
    assert p.full_cycles > 0
    assert sum(p.hist.values()) == res.cycles
    assert p.mean == pytest.approx(
        sum(k * v for k, v in p.hist.items()) / res.cycles
    )


def test_profile_requires_event_engine():
    g = _chain_graph()
    with pytest.raises(ValueError):
        simulate(g, firings=5, engine="cycle", profile=True)


# ---------------------------------------------------------------------------
# CI regression gate
# ---------------------------------------------------------------------------


def _load_check_regression():
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks",
        "check_regression.py",
    )
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _fmax_doc(opt_avg, deadlocks=0):
    return {
        "suite": "fmax_suite",
        "rows": [{"name": "d", "board": "u280", "opt_mhz": opt_avg}],
        "summary": {
            "opt_avg_mhz": opt_avg,
            "sim_deadlocks": deadlocks,
            "throughput_violations": 0,
        },
    }


def test_check_regression_gate(tmp_path):
    cr = _load_check_regression()

    def write(name, doc):
        p = tmp_path / name
        p.write_text(json.dumps(doc))
        return str(p)

    base = write("base.json", _fmax_doc(300.0))
    ok = write("ok.json", _fmax_doc(298.0))
    bad = write("bad.json", _fmax_doc(250.0))
    dead = write("dead.json", _fmax_doc(300.0, deadlocks=1))
    assert cr.main([ok, base, "--tol", "0.02"]) == 0
    assert cr.main([bad, base, "--tol", "0.02"]) == 1
    assert cr.main([dead, base]) == 1

    tp_base = write(
        "tp_base.json",
        {"suite": "throughput", "rows": [{"name": "d", "cycles_tapa": 100}]},
    )
    tp_ok = write(
        "tp_ok.json",
        {"suite": "throughput", "rows": [{"name": "d", "cycles_tapa": 101}]},
    )
    tp_bad = write(
        "tp_bad.json",
        {"suite": "throughput", "rows": [{"name": "d", "cycles_tapa": 150}]},
    )
    assert cr.main([tp_ok, tp_base]) == 0
    assert cr.main([tp_bad, tp_base]) == 1
    # suite mismatch is a hard configuration error
    assert cr.main([tp_ok, base]) == 2
