"""Test-suite bootstrap: make the tests directory importable so modules can
use the `_propcheck` hypothesis-compat shim regardless of pytest import
mode, and make `src/` importable even without PYTHONPATH=src.

Also skips the jax-only test modules (kernels, models, training substrate,
distributed launch) when jax is not installed — the CI no-jax tier-1 leg
runs the whole dataflow/search/simulator suite without them, proving the
core never needs jax and that ``backend="auto"`` degrades cleanly."""
import importlib.util
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)

#: modules that import jax (directly or through repro.model/launch) at
#: collection time; everything else must collect and pass without jax
_JAX_ONLY = [
    "test_distributed.py",
    "test_dryrun_small.py",
    "test_kernels.py",
    "test_models_smoke.py",
    "test_substrate.py",
]

collect_ignore = (
    [] if importlib.util.find_spec("jax") is not None else list(_JAX_ONLY)
)

import pytest  # noqa: E402  (after the sys.path bootstrap above)


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Per-test observability isolation: snapshot the process-wide metrics
    registry and trace state before each test and restore them after, so
    tests never see counters or spans leaked by an earlier test and no
    longer need ad-hoc ``reset_*_counts()`` preambles."""
    from repro.obs import metrics, trace

    snap = metrics.snapshot()
    was_enabled = trace.enabled()
    saved_events = trace.drain()
    try:
        yield
    finally:
        metrics.restore(snap)
        trace.clear()
        trace.attach("")  # drop any worker-token base a test installed
        if was_enabled:
            trace.enable()
        else:
            trace.disable()
        trace.absorb(saved_events)
