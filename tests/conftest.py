"""Test-suite bootstrap: make the tests directory importable so modules can
use the `_propcheck` hypothesis-compat shim regardless of pytest import
mode, and make `src/` importable even without PYTHONPATH=src."""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")
for p in (_HERE, _SRC):
    if p not in sys.path:
        sys.path.insert(0, p)
