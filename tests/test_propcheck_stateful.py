"""Stateful-testing engine self-tests (``_propcheck`` rule-based state
machines).  Unlike the ``given``-fallback tests these run in BOTH CI matrix
legs: the stateful engine never delegates to hypothesis, so its behavior —
deterministic program generation, greedy rule-sequence shrinking, the
``finalize`` end-state hook — must hold with and without the real library
installed."""
import pytest

import _propcheck as pc
from _propcheck import RuleBasedStateMachine, machine_st, rule, run_state_machine


class Counter(RuleBasedStateMachine):
    """A model/implementation pair that only diverges after an `add(3)`."""

    def __init__(self):
        self.total = 0
        self.model = 0

    @rule(n=machine_st.integers(0, 9))
    def add(self, n):
        self.total += n if n != 3 else n + 1   # planted bug
        self.model += n

    @rule()
    def check(self):
        assert self.total == self.model


def test_machine_finds_and_shrinks_planted_bug(capsys):
    with pytest.raises(AssertionError):
        run_state_machine(Counter, steps=12, max_examples=20)
    out = capsys.readouterr().out
    assert "falsifying program" in out
    assert "shrunk to" in out
    # the minimal program is exactly the bug trigger plus its detector
    shrunk = out.split("shrunk to", 1)[1]
    assert "add(n=3)" in shrunk
    assert "check()" in shrunk
    assert shrunk.count("add(") == 1


def test_shrinking_reexecutes_from_fresh_machines():
    """Shrink candidates must not leak state between executions: a machine
    whose bug needs TWO pushes in one program only reproduces if every
    candidate re-runs from a fresh instance."""
    class TwoPush(RuleBasedStateMachine):
        def __init__(self):
            self.pushes = 0

        @rule()
        def push(self):
            self.pushes += 1
            assert self.pushes < 2

    with pytest.raises(AssertionError):
        run_state_machine(TwoPush, steps=8, max_examples=10)


def test_finalize_participates_in_failure_detection():
    class EndsOdd(RuleBasedStateMachine):
        def __init__(self):
            self.n = 0

        @rule()
        def bump(self):
            self.n += 1

        def finalize(self):
            assert self.n % 2 == 0, f"odd after {self.n} bumps"

    with pytest.raises(AssertionError, match="odd after 1 bumps"):
        # the shrinker drops bumps pairwise down to the minimal odd count
        run_state_machine(EndsOdd, steps=9, max_examples=5)


def test_passing_machine_runs_all_examples():
    runs = []

    class Fine(RuleBasedStateMachine):
        @rule(x=machine_st.sampled_from(["a", "b"]))
        def go(self, x):
            runs.append(x)
            assert x in ("a", "b")

    run_state_machine(Fine, steps=5, max_examples=7)
    assert runs  # rules actually executed
    run_state_machine(Fine, steps=5, max_examples=7)  # deterministic rerun


def test_machine_without_rules_is_an_error():
    class Empty(RuleBasedStateMachine):
        pass

    with pytest.raises(TypeError, match="no @rule methods"):
        run_state_machine(Empty)


def test_determinism_across_runs():
    seen: list[list] = []

    class Recorder(RuleBasedStateMachine):
        def __init__(self):
            self.log = []

        @rule(n=machine_st.integers(0, 100))
        def note(self, n):
            self.log.append(n)

        def finalize(self):
            seen.append(self.log)

    run_state_machine(Recorder, steps=6, max_examples=4)
    first = list(seen)
    seen.clear()
    run_state_machine(Recorder, steps=6, max_examples=4)
    assert seen == first


def test_rule_skip_propagates_as_skip_not_failure():
    """pytest.skip inside a rule on a detection program must skip the
    test, not masquerade as a falsifying program (and must not trigger
    the up-to-500-reexecution shrinker)."""
    class Skippy(RuleBasedStateMachine):
        @rule()
        def go(self):
            pytest.skip("unsupported platform")

    with pytest.raises(pc._Skipped):
        run_state_machine(Skippy, steps=3, max_examples=2)


def test_skip_during_shrinking_does_not_mask_machine_failure():
    """A skip hit only on shrink candidates means 'invalid input, keep
    shrinking' — the original assertion failure must surface as a
    failure.  The skip band [400, 600] is never drawn directly for this
    seed's failing program, but arg-shrinking from a large n walks into
    it."""
    skipped_at = []

    class BandSkip(RuleBasedStateMachine):
        @rule(n=machine_st.integers(0, 10_000))
        def probe(self, n):
            if 400 <= n <= 600:
                skipped_at.append(n)
                pytest.skip("invalid region")
            assert n <= 900

    with pytest.raises(AssertionError):
        run_state_machine(BandSkip, steps=4, max_examples=30)
    assert skipped_at  # shrinking really did enter the skip band


def test_machine_st_available_regardless_of_hypothesis():
    """The stateful strategies never come from hypothesis: they must have
    the fallback draw/shrink interface in both CI legs."""
    s = machine_st.integers(2, 8)
    assert hasattr(s, "draw") and hasattr(s, "shrink")
    assert list(s.shrink(8))[0] == 2   # shrinks toward the range floor
    assert pc.machine_st is machine_st
