"""Crash-safety of the search stack (PR-8 tentpole): the fault-injection
harness, the hardened worker pool, and checkpointed ``search_until_converged``.

Covers: ``FaultPlan`` purity and env-var propagation, pool survival of
injected worker crashes and hangs with the frontier bit-identical to a
clean run, poison-point quarantine as a cached verdict, the
``REPRO_POOL_CTX`` start-method override, kill-between-rounds resume
(a real SIGKILLed subprocess) reproducing the uninterrupted frontier,
completed-checkpoint replay without re-solving, torn-store-write
transparency, and the checkpoint config fingerprint refusing foreign
arguments.
"""
import dataclasses
import json
import os
import signal
import subprocess
import sys
import textwrap

import pytest

from repro.core import (
    FloorplanCache,
    SearchSpace,
    SlotGrid,
    TaskGraphBuilder,
    floorplan_counts,
    reset_floorplan_counts,
)
from repro.search import (
    DiskFloorplanStore,
    FaultPlan,
    fault_counts,
    install_faults,
    search_until_converged,
    warm_floorplan_cache,
)
from repro.search import faults
from repro.search.pool import _mp_context
from repro.search.space import SearchPoint


def _chain_graph(n=4, width=64, lut=100):
    b = TaskGraphBuilder("chain")
    for i in range(n - 1):
        b.stream(f"s{i}", width=width)
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": lut},
                 ins=[f"s{i - 1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


GRID = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 400},
                max_util=1.0)
SPACE = SearchSpace(seeds=(0, 1), utils=(0.8, 0.9, 1.0))
POINTS = [SearchPoint(seed=s, max_util=u)
          for s in (0, 1) for u in (0.8, 0.9, 1.0)]


def _converge_kwargs():
    return dict(space=SearchSpace(utils=(0.7, 0.85, 1.0)), rounds=3,
                points_per_round=6, sim_firings=50)


def _fingerprint(res):
    return sorted(
        (dataclasses.astuple(c.point), c.fmax, c.plan.area_overhead,
         tuple(sorted(c.plan.floorplan.placement.items())),
         c.sim.cycles if c.sim else None)
        for c in res.frontier)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------


def test_fault_plan_decisions_are_pure_and_seeded():
    plan = FaultPlan(seed=3, worker_crash=0.5)
    tokens = [f"t{i}" for i in range(64)]
    first = [plan.decide("worker_crash", t) for t in tokens]
    assert first == [plan.decide("worker_crash", t) for t in tokens]
    assert any(first) and not all(first)      # a rate, not a constant
    # a different seed reshuffles the selection
    other = FaultPlan(seed=4, worker_crash=0.5)
    assert first != [other.decide("worker_crash", t) for t in tokens]
    # transient by default: attempt >= attempts never faults
    victim = tokens[first.index(True)]
    assert not plan.decide("worker_crash", victim, attempt=1)
    assert FaultPlan(seed=3, worker_crash=0.5, attempts=3).decide(
        "worker_crash", victim, attempt=2)


def test_fault_plan_kill_site_matches_round_token():
    plan = FaultPlan(kill_after_round=2)
    assert plan.decide("parent_kill", "2")
    assert not plan.decide("parent_kill", "1")
    assert not FaultPlan().decide("parent_kill", "2")


def test_fault_plan_env_roundtrip(monkeypatch):
    plan = FaultPlan(seed=9, torn_write=0.25, kill_after_round=1)
    assert FaultPlan.from_dict(plan.as_dict()) == plan
    # unknown keys from a newer writer are ignored, not fatal
    assert FaultPlan.from_dict(
        dict(plan.as_dict(), future_knob=1)) == plan
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    with install_faults(plan):
        assert json.loads(os.environ[faults.ENV_VAR]) == plan.as_dict()
        assert faults.active_plan() == plan
    assert faults.ENV_VAR not in os.environ
    assert faults.active_plan() is None


def test_install_none_masks_ambient_env_plan(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR,
                       json.dumps(FaultPlan(torn_write=1.0).as_dict()))
    assert faults.active_plan() is not None
    with install_faults(None):
        assert faults.active_plan() is None
    assert faults.active_plan() is not None


def test_fire_counts_and_returns_for_torn_write():
    with install_faults(FaultPlan(torn_write=1.0), env=False):
        assert faults.fire("torn_write", "any-token") is True
    with install_faults(FaultPlan(torn_write=0.0), env=False):
        assert faults.fire("torn_write", "any-token") is False
    assert fault_counts()["torn_write"] == 1


# ---------------------------------------------------------------------------
# hardened pool under injected faults
# ---------------------------------------------------------------------------


def _warm(plan, **kw):
    cache = DiskFloorplanStore(kw.pop("root")) if "root" in kw \
        else FloorplanCache()
    with install_faults(plan):
        stats = warm_floorplan_cache(_chain_graph(), GRID, POINTS,
                                     cache=cache, jobs=2, **kw)
    return cache, stats


def test_pool_survives_transient_worker_crashes_bit_identically():
    clean_cache = FloorplanCache()
    clean = warm_floorplan_cache(_chain_graph(), GRID, POINTS,
                                 cache=clean_cache, jobs=2)
    assert clean.retried == clean.pool_rebuilds == 0

    cache, stats = _warm(FaultPlan(seed=1, worker_crash=1.0))
    assert stats.retried >= stats.dispatched      # every point died once
    assert stats.pool_rebuilds >= 1
    assert stats.quarantined == 0                 # transient, not poison
    assert stats.merged == stats.dispatched == clean.dispatched
    assert set(cache._entries) == set(clean_cache._entries)
    for k, (kind, v) in clean_cache._entries.items():
        got_kind, got_v = cache._entries[k]
        assert got_kind == kind
        if kind == "ok":
            assert got_v.placement == v.placement


def test_pool_survives_hung_workers_via_timeout():
    cache, stats = _warm(FaultPlan(seed=2, worker_hang=1.0, hang_s=60.0),
                         timeout_s=1.0, backoff_s=0.01)
    assert stats.timed_out >= 1
    assert stats.pool_rebuilds >= 1
    assert stats.quarantined == 0
    assert stats.merged == stats.dispatched == len(POINTS)


def test_poison_point_is_quarantined_as_a_verdict():
    from repro.core import initial_floorplan_key
    # attempts high: the selected points crash on every retry
    plan = FaultPlan(seed=5, worker_crash=1.0, attempts=99)
    cache, stats = _warm(plan, crash_limit=2, backoff_s=0.01)
    assert stats.quarantined == len(POINTS)
    assert stats.merged == 0
    for pt in POINTS:
        key = initial_floorplan_key(_chain_graph(), GRID,
                                    **{f.name: getattr(pt, f.name)
                                       for f in dataclasses.fields(pt)})
        reason = cache.cached_error(key)
        assert reason is not None and reason.startswith("quarantined:")
    # the quarantine verdicts are ordinary cache entries: a re-run skips
    # the poisoned points instead of re-dispatching them
    with install_faults(plan):
        again = warm_floorplan_cache(_chain_graph(), GRID, POINTS,
                                     cache=cache, jobs=2)
    assert again.dispatched == 0


def test_injected_faults_never_change_the_converged_frontier(tmp_path):
    kw = _converge_kwargs()
    clean = search_until_converged(_chain_graph(), GRID, **kw)
    plan = FaultPlan(seed=4, worker_crash=0.5, torn_write=0.5)
    with install_faults(plan):
        chaotic = search_until_converged(
            _chain_graph(), GRID, jobs=2,
            cache=DiskFloorplanStore(tmp_path / "store"), **kw)
    assert _fingerprint(chaotic) == _fingerprint(clean)
    assert chaotic.hypervolumes == clean.hypervolumes
    assert chaotic.pool.quarantined == 0


# ---------------------------------------------------------------------------
# REPRO_POOL_CTX override
# ---------------------------------------------------------------------------


def test_pool_ctx_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_POOL_CTX", "spawn")
    assert _mp_context().get_start_method() == "spawn"
    monkeypatch.setenv("REPRO_POOL_CTX", "not-a-method")
    with pytest.raises(ValueError, match="REPRO_POOL_CTX"):
        _mp_context()
    monkeypatch.delenv("REPRO_POOL_CTX")
    assert _mp_context().get_start_method() in ("fork", "spawn")


def test_pool_solves_under_spawn_context(monkeypatch):
    monkeypatch.setenv("REPRO_POOL_CTX", "spawn")
    cache = FloorplanCache()
    stats = warm_floorplan_cache(_chain_graph(), GRID, POINTS[:2],
                                 cache=cache, jobs=2)
    assert stats.merged == stats.dispatched == 2
    ref = FloorplanCache()
    warm_floorplan_cache(_chain_graph(), GRID, POINTS[:2], cache=ref, jobs=2)
    monkeypatch.delenv("REPRO_POOL_CTX")
    assert set(cache._entries) == set(ref._entries)


# ---------------------------------------------------------------------------
# kill-between-rounds resume
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent("""
    import sys
    from repro.core import SearchSpace, SlotGrid, TaskGraphBuilder
    from repro.search import search_until_converged

    def chain(n=4, width=64, lut=100):
        b = TaskGraphBuilder("chain")
        for i in range(n - 1):
            b.stream(f"s{i}", width=width)
        for i in range(n):
            b.invoke(f"K{i}", area={"LUT": lut},
                     ins=[f"s{i - 1}"] if i > 0 else [],
                     outs=[f"s{i}"] if i < n - 1 else [])
        return b.build()

    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 400},
                    max_util=1.0)
    res = search_until_converged(chain(), grid,
                                 space=SearchSpace(utils=(0.7, 0.85, 1.0)),
                                 rounds=3, points_per_round=6,
                                 sim_firings=50, checkpoint=sys.argv[1])
    print(f"done rounds_run={res.rounds_run} "
          f"resumed_rounds={res.resumed_rounds}")
""")


def _child_env(plan=None):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop(faults.ENV_VAR, None)
    if plan is not None:
        env[faults.ENV_VAR] = json.dumps(plan.as_dict())
    return env


def test_sigkill_between_rounds_then_resume_is_bit_identical(tmp_path):
    ckpt = tmp_path / "ckpt"
    # 1) the victim: SIGKILLs itself right after the round-0 checkpoint
    victim = subprocess.run(
        [sys.executable, "-c", _CHILD, str(ckpt)],
        env=_child_env(FaultPlan(kill_after_round=0)),
        capture_output=True, text=True)
    assert victim.returncode == -signal.SIGKILL, victim.stderr
    assert (ckpt / "state_r0000.pkl").exists()

    # 2) resume in-process so the result object is inspectable
    resumed = search_until_converged(_chain_graph(), GRID,
                                     checkpoint=ckpt, **_converge_kwargs())
    assert resumed.resumed_rounds == 1

    # 3) the uninterrupted run it must reproduce, bit for bit
    clean = search_until_converged(_chain_graph(), GRID, **_converge_kwargs())
    assert _fingerprint(resumed) == _fingerprint(clean)
    assert resumed.hypervolumes == clean.hypervolumes
    assert resumed.rounds_run == clean.rounds_run
    assert resumed.converged == clean.converged


def test_completed_checkpoint_replays_without_solving(tmp_path):
    ckpt = tmp_path / "ckpt"
    first = search_until_converged(_chain_graph(), GRID, checkpoint=ckpt,
                                   **_converge_kwargs())
    reset_floorplan_counts()
    again = search_until_converged(_chain_graph(), GRID, checkpoint=ckpt,
                                   **_converge_kwargs())
    assert floorplan_counts()["solved"] == 0
    assert again.resumed_rounds == first.rounds_run
    assert _fingerprint(again) == _fingerprint(first)
    assert again.checkpoint_dir == os.fspath(ckpt)


def test_checkpoint_refuses_different_search_arguments(tmp_path):
    ckpt = tmp_path / "ckpt"
    search_until_converged(_chain_graph(), GRID, checkpoint=ckpt,
                           **_converge_kwargs())
    kw = _converge_kwargs() | {"rounds": 4}
    with pytest.raises(ValueError, match="config mismatch"):
        search_until_converged(_chain_graph(), GRID, checkpoint=ckpt, **kw)


def test_checkpoint_creates_disk_store_by_default(tmp_path):
    ckpt = tmp_path / "ckpt"
    res = search_until_converged(_chain_graph(), GRID, checkpoint=ckpt,
                                 **_converge_kwargs())
    assert res.checkpoint_dir == os.fspath(ckpt)
    assert DiskFloorplanStore(ckpt / "store").disk_entries() >= 1
