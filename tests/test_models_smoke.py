"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + prefill/decode on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.model import lm


def _extra(cfg, batch):
    if cfg.family == "vlm":
        return {"vision": jnp.ones((batch, cfg.frontend_tokens,
                                    cfg.frontend_dim), jnp.bfloat16) * 0.01}
    if cfg.family == "audio":
        return {"frames": jnp.ones((batch, cfg.frontend_tokens,
                                    cfg.frontend_dim), jnp.bfloat16) * 0.01}
    return None


@pytest.mark.parametrize("name", configs.ARCHS)
def test_forward_and_grad(name):
    cfg = configs.get_reduced(name)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    extra = _extra(cfg, B)
    if extra is not None:
        batch["extra"] = extra

    logits, aux = jax.jit(
        lambda p, t: lm.forward(p, cfg, t, extra=extra))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g.astype(jnp.float32)).any()) for g in flat)


@pytest.mark.parametrize("name", configs.ARCHS)
def test_prefill_then_decode(name):
    cfg = configs.get_reduced(name)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    extra = _extra(cfg, B)
    cache = lm.init_cache(params, cfg, B, max_seq=64, extra=extra)
    logits, cache = jax.jit(lambda p, c, t: lm.step(p, cfg, c, t))(
        params, cache, tokens)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # two decode steps
    for _ in range(2):
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = jax.jit(lambda p, c, t: lm.step(p, cfg, c, t))(
            params, cache, nxt)
        assert logits.shape == (B, cfg.vocab)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert int(cache["pos"]) == S + 2


def test_decode_matches_forward_dense():
    """Teacher-forced decode logits must match the full forward pass."""
    cfg = configs.get_reduced("granite-8b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full_logits, _ = lm.forward(params, cfg, tokens)
    cache = lm.init_cache(params, cfg, B, max_seq=32)
    outs = []
    for t in range(S):
        lg, cache = lm.step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec, np.float32),
        rtol=0.05, atol=0.05)


def test_sliding_window_ring_cache_consistency():
    """gemma-style local attention: decode through a ring buffer must match
    the full forward pass once context exceeds the window."""
    cfg = configs.get_reduced("gemma3-12b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 48   # window is 32 in the reduced config
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full_logits, _ = lm.forward(params, cfg, tokens)
    cache = lm.init_cache(params, cfg, B, max_seq=64)
    outs = []
    for t in range(S):
        lg, cache = lm.step(params, cfg, cache, tokens[:, t:t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec, np.float32),
        rtol=0.06, atol=0.06)
