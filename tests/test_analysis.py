"""Static dataflow verifier tests (``repro.analysis``).

The load-bearing part is the soundness property: on randomized graphs
(cycles, zero-capacity FIFOs, control closures, detached tasks) a graph
``analyze()`` calls safe must never deadlock in the event engine at the
same wave size — and, because the marked-graph analysis is exact, a graph
it calls doomed must.  Around that: golden diagnostics for every lint
code, the ``simulate(check=...)`` / ``autobridge(check=True)`` wiring, the
search engine's static pre-flight gate (bit-identical frontier, doomed
candidates never simulated), the worker pool's parent-side short-circuit,
``add_stream`` construction-time validation, and the ``python -m
repro.analysis`` CLI the ``lint-designs`` CI step runs.
"""
import json
import random
import warnings

import pytest
from _propcheck import given, settings, strategies as st

from repro.analysis import (StaticAnalysisError, analysis_counts, analyze,
                            min_cycles_bound, repetition_vector,
                            reset_analysis_counts)
from repro.analysis.__main__ import main as lint_main
from repro.core import (InfeasibleError, TaskGraphBuilder, simulate,
                        simulate_batch)
from repro.core.autobridge import (FloorplanCache, autobridge,
                                   initial_floorplan_key)
from repro.core.graph import Stream, Task, TaskGraph
from repro.corpus import random_graph
from repro.fpga import benchmarks as B, grid_for
from repro.search.engine import explore_design_space
from repro.search.pool import warm_floorplan_cache
from repro.search.space import SearchPoint, SearchSpace


# ---------------------------------------------------------------------------
# graph constructors
# ---------------------------------------------------------------------------


def _chain(depth=2, width=32):
    # raw construction: ``depth=0`` deliberately bypasses the builder's
    # add_stream validation (the escape hatch the broken-graph tests need)
    g = TaskGraph("chain")
    g.add_task(Task("P"))
    g.add_task(Task("C"))
    g.add_stream(Stream(name="s", src="P", dst="C", width=width,
                        depth=depth), validate=False)
    return g


def _cycle(control_back=False):
    b = TaskGraphBuilder("cyc")
    b.stream("ab")
    b.stream("ba", control=control_back)
    b.invoke("A", ins=["ba"], outs=["ab"])
    b.invoke("B", ins=["ab"], outs=["ba"])
    return b.build()


def _random_graph(rng: random.Random) -> TaskGraph:
    """Fuzz-family corpus graph, cycles always allowed: layered graph with
    random fanin, zero-depth FIFOs, control streams, detached sinks, skip
    edges, and an occasional (possibly control-closed) feedback cycle."""
    return random_graph(rng, allow_cycle=True)


# ---------------------------------------------------------------------------
# soundness against the event engine (the tentpole property)
# ---------------------------------------------------------------------------


@settings(max_examples=220, deadline=None)
@given(st.integers(0, 999_983))
def test_deadlock_verdict_sound_and_exact(seed):
    """>= 200 randomized graphs: ``analyze`` may never call a graph safe
    that the event engine deadlocks on (soundness), and — the marked-graph
    analysis being exact — every graph it dooms must really deadlock.  The
    static cycles bound must hold whenever the run completes."""
    rng = random.Random(seed)
    g = _random_graph(rng)
    lat = {s.name: rng.randint(0, 3) for s in g.streams}
    extra = {s.name: rng.choice([0, 0, 1, 2]) for s in g.streams}
    ii = {n: rng.randint(1, 3) for n in g.tasks}
    firings = rng.randint(1, 25)
    rep = analyze(g, latency=lat, extra_capacity=extra, ii=ii,
                  firings=firings)
    res = simulate(g, engine="event", firings=firings, latency=lat,
                   extra_capacity=extra, ii=ii, max_cycles=500_000)
    assert rep.deadlock == res.deadlocked, (
        f"static verdict {rep.deadlock} vs engine {res.deadlocked} "
        f"(seed {seed}): {[str(d) for d in rep.diagnostics]}")
    if not res.deadlocked and rep.min_cycles is not None:
        assert res.cycles >= rep.min_cycles
    # the firing bounds are true upper bounds on what the engine achieved
    for n, bound in rep.max_firings.items():
        if bound is not None:
            assert res.fired[n] <= bound


def test_firing_bound_respects_extra_capacity():
    """A zero-depth FIFO dooms the chain; pipeline headroom rescues it —
    exactly the capacity model ``simulate`` uses."""
    g = _chain(depth=0)
    assert analyze(g).deadlock is False            # no verdict w/o firings
    doomed = analyze(g, firings=5)
    assert doomed.max_firings == {"P": 0, "C": 0}
    assert doomed.deadlock and doomed.doomed(5) and not doomed.ok
    rescued = analyze(g, firings=5, extra_capacity={"s": 2})
    assert rescued.max_firings == {"P": None, "C": None}
    assert not rescued.deadlock
    sim = simulate(g, firings=5, extra_capacity={"s": 2})
    assert not sim.deadlocked


def test_min_cycles_bound_exact_on_chain():
    g = _chain()
    assert min_cycles_bound(g, firings=10) == 11
    assert simulate(g, firings=10).cycles == 11
    assert min_cycles_bound(_cycle(), firings=10) is None


# ---------------------------------------------------------------------------
# golden diagnostics, one per lint code
# ---------------------------------------------------------------------------


def _codes(g, **kw):
    return analyze(g, **kw).codes()


def test_a001_dangling_stream():
    g = _chain()
    del g.tasks["C"]
    rep = analyze(g)
    assert "A001-dangling-stream" in rep.codes() and not rep.ok


def test_a002_self_loop():
    g = TaskGraph("sl")
    g.add_task(Task("A"))
    g.add_stream(Stream(name="aa", src="A", dst="A"), validate=False)
    assert "A002-self-loop-stream" in _codes(g)


def test_a003_a004_bad_width_depth():
    g = TaskGraph("wd")
    g.add_task(Task("P"))
    g.add_task(Task("C"))
    g.add_stream(Stream(name="s", src="P", dst="C", width=0, depth=-1),
                 validate=False)
    got = _codes(g)
    assert {"A003-nonpositive-width", "A004-negative-depth"} <= got


def test_a005_zero_capacity_and_headroom():
    g = _chain(depth=0)
    assert "A005-zero-capacity" in _codes(g)
    assert "A005-zero-capacity" not in _codes(g, extra_capacity={"s": 2})
    # control streams carry no tokens: depth 0 is legal there
    c = TaskGraph("ctl")
    c.add_task(Task("P"))
    c.add_task(Task("C"))
    c.add_stream(Stream(name="k", src="P", dst="C", depth=0, control=True),
                 validate=False)
    assert "A005-zero-capacity" not in _codes(c)


def test_a006_width_change_is_info():
    b = TaskGraphBuilder("wc")
    b.stream("i", width=32)
    b.stream("o", width=64)
    b.invoke("Src", outs=["i"])
    b.invoke("Widen", ins=["i"], outs=["o"])
    b.invoke("Dst", ins=["o"])
    rep = analyze(b.build())
    assert "A006-width-change" in rep.codes() and rep.ok


def test_a007_a008_cycle_reachability():
    got = _codes(_cycle())
    assert {"A007-unreachable-task", "A008-sinkless-task"} <= got
    assert _codes(_cycle(control_back=True)) == set()


def test_a009_a010_a011_pin_lints():
    grid = grid_for("u250")
    g = TaskGraph("pins")
    g.add_task(Task("Out", pinned=(99, 99)))
    g.add_task(Task("A", area={"LUT": 1.0}, pinned=(0, 0)))
    g.add_task(Task("B", area={"LUT": 1e12}, pinned=(0, 0)))
    got = _codes(g, grid=grid)
    assert {"A009-pin-outside-grid", "A010-pin-shared-slot",
            "A011-pin-overflow"} <= got
    assert _codes(g) == set()          # pin lints need the grid


def test_a012_stale_index():
    g = _chain()
    g.streams.append(Stream(name="rogue", src="P", dst="C"))  # not add_stream
    assert "A012-stale-index" in _codes(g)


def test_d001_d002_dead_cycle_starves_downstream():
    g = _cycle()
    g.add_task(Task("C"))
    g.add_stream(Stream(name="bc", src="B", dst="C"))
    rep = analyze(g, firings=10)
    assert {"D001-dead-cycle", "D002-starved-task"} <= rep.codes()
    assert rep.deadlock and rep.firing_bound("C") == 0
    # without a wave size the starvation downgrades to a warning
    warned = analyze(g)
    d002 = [d for d in warned.diagnostics if d.code == "D002-starved-task"]
    assert d002 and all(d.severity == "warn" for d in d002)


def test_r001_r002_rate_lints():
    b = TaskGraphBuilder("rates")
    for s in ("ab", "ac", "cb"):
        b.stream(s, width=32)
    b.invoke("A", outs=["ab", "ac"])
    b.invoke("Cc", ins=["ac"], outs=["cb"])
    b.invoke("Bb", ins=["ab", "cb"])
    g = b.build()
    assert repetition_vector(g) == {"A": 1, "Cc": 1, "Bb": 1}
    next(s for s in g.streams if s.name == "ab").meta["rate_src"] = 64.0
    rep = analyze(g)
    assert "R001-rate-inconsistent" in rep.codes()
    assert rep.repetition is None and rep.ok  # rate findings only warn
    next(s for s in g.streams if s.name == "ab").meta["rate_src"] = 0.0
    assert "R002-nonpositive-rate" in analyze(g).codes()


def test_unknown_pass_rejected():
    with pytest.raises(ValueError, match="unknown analysis pass"):
        analyze(_chain(), passes=("structure", "wat"))


# ---------------------------------------------------------------------------
# add_stream construction-time validation (satellite 1)
# ---------------------------------------------------------------------------


def test_add_stream_validation():
    g = TaskGraph("v")
    g.add_task(Task("A"))
    g.add_task(Task("B"))
    with pytest.raises(ValueError, match="self-loop"):
        g.add_stream(Stream(name="aa", src="A", dst="A"))
    with pytest.raises(ValueError, match="non-positive width"):
        g.add_stream(Stream(name="w", src="A", dst="B", width=0))
    with pytest.raises(ValueError, match="non-positive depth"):
        g.add_stream(Stream(name="d", src="A", dst="B", depth=0))
    # unknown endpoints are rejected even with the escape hatch
    with pytest.raises(ValueError, match="unknown task"):
        g.add_stream(Stream(name="x", src="A", dst="Z"), validate=False)
    g.add_stream(Stream(name="ok0", src="A", dst="B", depth=0),
                 validate=False)                   # escape hatch for tests
    g.add_stream(Stream(name="ok", src="A", dst="B"))
    assert g.num_streams == 2


# ---------------------------------------------------------------------------
# simulate(check=...) pre-flight (tentpole wiring)
# ---------------------------------------------------------------------------


def test_simulate_check_raise_and_warn():
    g = _chain(depth=0)                            # statically doomed
    with pytest.raises(StaticAnalysisError) as ei:
        simulate(g, firings=5, check="raise")
    assert "A005-zero-capacity" in str(ei.value)
    assert not ei.value.report.ok and ei.value.report.deadlock
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        res = simulate(g, firings=5, check="warn")
    assert res.deadlocked
    assert any("static analysis" in str(w.message) for w in rec)
    with pytest.raises(ValueError, match="check must be"):
        simulate(g, firings=5, check="yes")


def test_simulate_check_clean_graph_unchanged():
    g = _chain()
    plain = simulate(g, firings=10)
    checked = simulate(g, firings=10, check="raise")
    assert (plain.cycles, plain.fired) == (checked.cycles, checked.fired)


def test_simulate_batch_check():
    with pytest.raises(StaticAnalysisError):
        simulate_batch([_chain(), _chain(depth=0)], firings=5, check="raise")
    ok = simulate_batch([_chain(), _chain()], firings=5, check="raise")
    assert len(ok) == 2 and not any(r.deadlocked for r in ok)


# ---------------------------------------------------------------------------
# autobridge(check=True): static-infeasibility verdicts in the cache
# ---------------------------------------------------------------------------


def _broken_for_floorplan():
    g = _chain()
    for t in g.tasks.values():
        t.area = {"LUT": 100.0}
    del g.tasks["C"]                               # dangling stream
    return g


def test_autobridge_check_raises_and_caches():
    g = _broken_for_floorplan()
    grid = grid_for("u250")
    cache = FloorplanCache()
    with pytest.raises(InfeasibleError, match="static analysis: A001"):
        autobridge(g, grid, check=True, cache=cache)
    after_first = analysis_counts()
    assert after_first["infeasible"] == 1
    # the verdict is cached: the second call replays it without re-analyzing
    with pytest.raises(InfeasibleError, match="static analysis: A001"):
        autobridge(g, grid, check=True, cache=cache)
    assert analysis_counts()["analyzed"] == after_first["analyzed"]
    key = initial_floorplan_key(g, grid)
    assert cache.cached_error(key).startswith("static analysis")
    # check=False (the default) keeps the legacy behavior: no pre-flight —
    # the dangling stream surfaces as a raw KeyError deep in the ILP build,
    # exactly the crash that check=True upgrades to a diagnostic
    reset_analysis_counts()
    with pytest.raises(KeyError):
        autobridge(g, grid)
    assert analysis_counts()["analyzed"] == 0


def test_floorplan_cache_record_infeasible_first_writer_wins():
    cache = FloorplanCache()
    cache.record_infeasible(("k",), "first")
    cache.record_infeasible(("k",), "second")
    assert cache.cached_error(("k",)) == "first"
    assert cache.cached_error(("other",)) is None


def test_pool_parent_side_static_short_circuit():
    """A doomed graph never reaches the worker pool: the parent analyzes
    once, caches the per-point verdicts, and the replay raises the exact
    message a sequential ``autobridge(check=True)`` produces."""
    g = _broken_for_floorplan()
    grid = grid_for("u250")
    cache = FloorplanCache()
    pts = [SearchPoint(seed=0, max_util=u) for u in (0.7, 0.8)]
    stats = warm_floorplan_cache(g, grid, pts, cache=cache, jobs=2,
                                 ab_kwargs={"check": True})
    assert stats.static_skipped == 2 and stats.dispatched == 0
    assert analysis_counts()["infeasible"] == 2
    for pt in pts:
        with pytest.raises(InfeasibleError, match="static analysis: A001"):
            autobridge(g, grid, check=True, cache=cache,
                       max_util=pt.max_util, seed=pt.seed)
    # without check the pool behaves as before (nothing short-circuits)
    stats2 = warm_floorplan_cache(_chain(), grid, pts,
                                  cache=FloorplanCache(), jobs=1,
                                  ab_kwargs={"check": True})
    assert stats2.static_skipped == 0


# ---------------------------------------------------------------------------
# the search engine's static pre-flight gate (frontier bit-identity)
# ---------------------------------------------------------------------------


def _doomed_design():
    g = TaskGraph("doomed")
    for n in ("A", "Bb", "Cc"):
        g.add_task(Task(n, area={"LUT": 100.0}))
    g.add_stream(Stream(name="ab", src="A", dst="Bb"))
    g.add_stream(Stream(name="bc", src="Bb", dst="Cc"))
    g.add_stream(Stream(name="ca", src="Cc", dst="A"))
    return g


def _frontier_key(res):
    return sorted((c.point.max_util, c.point.seed,
                   round(c.report.fmax_mhz, 6),
                   None if c.sim is None else c.sim.cycles)
                  for c in res.frontier)


def test_gate_skips_doomed_candidates_without_moving_frontier():
    grid = grid_for("u250")
    space = SearchSpace(utils=(0.7, 0.8), seeds=(0,))
    gated = explore_design_space(_doomed_design(), grid, space=space,
                                 sim_firings=30)
    counts = analysis_counts()
    assert counts["skipped"] == 2 and counts["doomed"] >= 2
    ungated = explore_design_space(_doomed_design(), grid, space=space,
                                   sim_firings=30, static_check=False)
    assert _frontier_key(gated) == _frontier_key(ungated) == []
    for c in gated.candidates:
        assert c.sim.engine == "static" and c.sim.deadlocked
        assert c.sim.fired == {n: 0 for n in c.plan.graph.tasks}
        assert c.error.startswith("static deadlock:")
    for c in ungated.candidates:
        assert c.sim.engine != "static" and c.sim.deadlocked


def test_gate_noop_on_live_design():
    """On a healthy design the gate skips nothing and the frontier is
    bit-identical to the ungated run."""
    _, board, graph = next(e for e in B.autobridge_suite()
                           if e[0] == "stencil_x2")
    grid = grid_for(board)
    space = SearchSpace(utils=(0.7, 0.8), seeds=(0,))
    gated = explore_design_space(graph, grid, space=space, sim_firings=30)
    assert analysis_counts()["skipped"] == 0
    assert gated.frontier
    ungated = explore_design_space(graph, grid, space=space, sim_firings=30,
                                   static_check=False)
    assert _frontier_key(gated) == _frontier_key(ungated)


# ---------------------------------------------------------------------------
# benchmark designs are lint-clean; the CLI gates on that
# ---------------------------------------------------------------------------


def test_all_benchmark_designs_are_error_free():
    for name, board, graph in B.autobridge_suite() + B.hbm_suite():
        rep = analyze(graph, grid=grid_for(board), firings=50)
        assert rep.ok, f"{name}@{board}: {[str(d) for d in rep.errors]}"
        assert not rep.deadlock


def test_cli_lints_designs(capsys):
    # a bare name resolves to every board it appears on (stencil_x2 is on
    # both u250 and u280), a qualified one to exactly that entry
    assert lint_main(["stencil_x2", "page_rank@u280"]) == 0
    out = capsys.readouterr().out
    assert "3 design(s) linted, 0 with errors" in out
    assert lint_main(["--json", "bucket_sort"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc[0]["ok"] is True and doc[0]["design"].startswith("bucket_sort")
    assert lint_main(["--list"]) == 0
    assert "stencil_x2@u250" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        lint_main(["no_such_design"])
