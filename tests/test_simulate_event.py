"""Event-driven simulator tests: bit-for-bit equivalence against the
per-cycle reference engine (randomized DAGs, reconvergent diamonds,
dependency cycles, detached tasks), batch-engine parity across the NumPy
and jax-jitted padded backends (three-way jit == numpy == event property
test, bit-identical including ``steps``), the almost-full headroom
regression, and a perf smoke proving the engine does O(firings) work
instead of O(cycles)."""
import random

import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (SimJob, TaskGraphBuilder, pipeline_headroom,
                        simulate, simulate_batch)
from repro.core.graph import Stream, Task, TaskGraph
from repro.core.simulate import _jax_ready
# the corpus fuzz family subsumes the old ad-hoc helper: layered DAGs
# with random fanin, zero-depth FIFOs, control streams, detached sinks,
# skip edges and (allow_cycle) occasional feedback cycles
from repro.corpus import random_graph as _random_graph

#: does backend="auto" promote to the jitted sweep in this environment?
_HAVE_JAX = _jax_ready()
jax_only = pytest.mark.skipif(not _HAVE_JAX, reason="jax not installed")


def _assert_engines_agree(g, **kw):
    ev = simulate(g, engine="event", **kw)
    cy = simulate(g, engine="cycle", **kw)
    assert (ev.cycles, ev.fired, ev.deadlocked) == \
        (cy.cycles, cy.fired, cy.deadlocked), (ev, cy)
    return ev


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 99_999))
def test_equivalence_random_dags(seed):
    rng = random.Random(seed)
    g = _random_graph(rng)
    lat = {s.name: rng.randint(0, 4) for s in g.streams}
    extra = {s.name: rng.choice([0, 0, 2, 2 * lat[s.name]])
             for s in g.streams}
    ii = {n: rng.randint(1, 4) for n in g.tasks}
    _assert_engines_agree(g, firings=25, latency=lat, extra_capacity=extra,
                          ii=ii)


def _diamond():
    b = TaskGraphBuilder("d")
    for s in ("ab", "bd", "ad"):
        b.stream(s, width=32, depth=2)
    b.invoke("A", area={}, outs=["ab", "ad"])
    b.invoke("B", area={}, ins=["ab"], outs=["bd"])
    b.invoke("D", area={}, ins=["bd", "ad"])
    return b.build()


@pytest.mark.parametrize("lat,extra,ii", [
    ({}, {}, {}),                                       # plain
    ({"ab": 4, "bd": 4}, {}, {}),                       # unbalanced, tight
    ({"ab": 4, "bd": 4, "ad": 8},
     {"ab": 8, "bd": 8, "ad": 16}, {}),                 # balanced + headroom
    ({"ab": 2}, {"ab": 4}, {"A": 3, "D": 2}),           # II mix
])
def test_equivalence_reconvergent_diamond(lat, extra, ii):
    _assert_engines_agree(_diamond(), firings=120, latency=lat,
                          extra_capacity=extra, ii=ii)


def test_equivalence_dependency_cycle_deadlock():
    """A tokenless feedback cycle deadlocks immediately in both engines."""
    g = TaskGraph("cyc")
    g.add_task(Task("a"))
    g.add_task(Task("b"))
    g.add_stream(Stream(name="ab", src="a", dst="b"))
    g.add_stream(Stream(name="ba", src="b", dst="a"))
    res = _assert_engines_agree(g, firings=10)
    assert res.deadlocked
    assert res.fired == {"a": 0, "b": 0}


def test_equivalence_detached_tasks():
    b = TaskGraphBuilder("det")
    b.stream("s0", width=8)
    b.stream("s1", width=8)
    b.invoke("Src", area={}, outs=["s0", "s1"])
    b.invoke("Sink", area={}, ins=["s0"])
    b.invoke("Mon", area={}, ins=["s1"], detach=True)
    g = b.build()
    res = _assert_engines_agree(g, firings=50, latency={"s1": 3},
                                extra_capacity={"s1": 6})
    assert res.fired["Src"] == 50 and res.fired["Sink"] == 50
    assert res.fired["Mon"] <= 50   # detached: excluded from termination


# ---------------------------------------------------------------------------
# batched engine
# ---------------------------------------------------------------------------

def test_batch_numpy_matches_event():
    g = _diamond()
    rng = random.Random(3)
    jobs = [SimJob(g)]
    for _ in range(7):
        lat = {s.name: rng.randint(0, 4) for s in g.streams}
        jobs.append(SimJob(g, latency=lat,
                           extra_capacity=pipeline_headroom(lat),
                           ii={n: rng.randint(1, 3) for n in g.tasks}))
    vec = simulate_batch(jobs, firings=60, backend="numpy")
    ref = simulate_batch(jobs, firings=60, backend="event")
    assert all(r.engine == "numpy-batch" for r in vec)
    assert all(r.engine == "event" for r in ref)
    for a, b in zip(vec, ref):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b.cycles, b.fired, b.deadlocked)


def test_batch_mixed_topologies_vectorize_via_padding():
    """Mixed topologies no longer degrade to a per-job Python loop: the
    padded backend covers heterogeneous graphs in one array-sweep, with
    results identical to per-job event simulation."""
    b = TaskGraphBuilder("t2")
    b.stream("s", width=8)
    b.invoke("A", area={}, outs=["s"])
    b.invoke("B", area={}, ins=["s"])
    other = b.build()
    jobs = [SimJob(_diamond()), SimJob(other)]
    results = simulate_batch(jobs, firings=30, backend="numpy")
    assert all(r.engine == "numpy-padded" for r in results)
    ref = simulate_batch(jobs, firings=30, backend="event")
    assert all(r.engine == "event" for r in ref)
    for a, b_ in zip(results, ref):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b_.cycles, b_.fired, b_.deadlocked)


def test_batch_accepts_bare_graphs():
    out = simulate_batch([_diamond(), _diamond()], firings=40)
    assert [r.cycles for r in out] == [out[0].cycles] * 2
    assert all(not r.deadlocked for r in out)


def _random_mixed_jobs(seed: int) -> list[SimJob]:
    """2-6 jobs over independently random topologies: different task and
    stream counts, dependency cycles, detached tasks, zero-capacity FIFOs,
    random latency/headroom/II knobs."""
    rng = random.Random(seed)
    jobs = []
    for _ in range(rng.randint(2, 6)):
        g = _random_graph(rng, allow_cycle=True)
        lat = {s.name: rng.randint(0, 4) for s in g.streams}
        extra = {s.name: rng.choice([0, 0, 2, 2 * lat[s.name]])
                 for s in g.streams}
        ii = {n: rng.randint(1, 4) for n in g.tasks}
        jobs.append(SimJob(g, latency=lat, extra_capacity=extra, ii=ii))
    return jobs


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 99_999))
def test_padded_backend_equivalence_mixed_topologies(seed):
    """The padded ragged-batch backend is bit-for-bit equivalent to per-job
    event simulation on heterogeneous batches — including graphs that
    deadlock (cycles, zero-capacity FIFOs) and detached tasks."""
    jobs = _random_mixed_jobs(seed)
    vec = simulate_batch(jobs, firings=25)
    ref = simulate_batch(jobs, firings=25, backend="event")
    assert all(r.engine in ("numpy-batch", "numpy-padded", "jax-padded")
               for r in vec)
    for a, b in zip(vec, ref):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b.cycles, b.fired, b.deadlocked)


def test_backend_numpy_accepts_mixed_topologies():
    """``backend="numpy"`` used to raise on mixed batches; the padded
    backend now takes any mix (it only needs NumPy itself)."""
    b = TaskGraphBuilder("t3")
    b.stream("s", width=8)
    b.invoke("A", area={}, outs=["s"])
    b.invoke("B", area={}, ins=["s"])
    jobs = [SimJob(_diamond()), SimJob(b.build())]
    out = simulate_batch(jobs, firings=20, backend="numpy")
    assert all(r.engine == "numpy-padded" for r in out)
    # a lone job is also accepted (one group, no padding)
    solo = simulate_batch([SimJob(_diamond())], firings=20, backend="numpy")
    assert solo[0].engine == "numpy-batch"


def test_fast_subset_designs_vectorize_with_exact_results():
    """Acceptance: a batch of the full fast-subset designs (heterogeneous
    real benchmark graphs) runs through the padded numpy backend with
    results exactly equal to per-job event simulation."""
    from repro.fpga import benchmarks as B
    graphs = [B.stencil(2), B.stencil(4), B.cnn(2), B.gaussian(12),
              B.bucket_sort(), B.page_rank()]
    jobs = [SimJob(g) for g in graphs]
    vec = simulate_batch(jobs, firings=50)
    want = "jax-padded" if _HAVE_JAX else "numpy-padded"
    assert all(r.engine == want for r in vec)
    ref = [simulate(g, firings=50) for g in graphs]
    for a, b in zip(vec, ref):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b.cycles, b.fired, b.deadlocked)


def test_engine_invocation_counters():
    """The padded sweep is ONE Python-level invocation regardless of batch
    size; per-job event fallback is one per job (what the CI benchmark
    gate asserts never happens on the fast subset)."""
    from repro.core import engine_counts, reset_engine_counts
    jobs = _random_mixed_jobs(7)
    simulate_batch(jobs, firings=10)
    expected = {"event": 0, "cycle": 0, "numpy": 0, "jax": 0, "fallback": 0}
    expected["jax" if _HAVE_JAX else "numpy"] = 1
    assert engine_counts() == expected
    reset_engine_counts()
    simulate_batch(jobs, firings=10, backend="event")
    counts = engine_counts()
    assert counts["numpy"] == counts["jax"] == 0
    assert counts["event"] == len(jobs)


# ---------------------------------------------------------------------------
# jax-jitted backend (bit-exact against the NumPy oracle)
# ---------------------------------------------------------------------------

@jax_only
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 99_999))
def test_jax_backend_three_way_equivalence(seed):
    """jit == numpy == event on randomized mixed batches.  The jitted
    sweep's SimResults are bit-identical to the NumPy oracle's — including
    the ``steps`` counter, i.e. the very same number of sweep iterations —
    and both match per-job event simulation on cycles/fired/deadlock."""
    jobs = _random_mixed_jobs(seed)
    jx = simulate_batch(jobs, firings=25, backend="jax")
    np_ = simulate_batch(jobs, firings=25, backend="numpy")
    ev = simulate_batch(jobs, firings=25, backend="event")
    assert all(r.engine == "jax-padded" for r in jx)
    for a, b in zip(jx, np_):
        assert (a.cycles, a.fired, a.deadlocked, a.steps) == \
            (b.cycles, b.fired, b.deadlocked, b.steps)
    for a, b in zip(jx, ev):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b.cycles, b.fired, b.deadlocked)


@jax_only
def test_auto_promotes_to_jax():
    """backend="auto" resolves to the jitted sweep when jax imports and the
    knobs are int32-safe — with zero fallback ticks."""
    from repro.core import engine_counts
    jobs = _random_mixed_jobs(11)
    out = simulate_batch(jobs, firings=10)
    assert all(r.engine == "jax-padded" for r in out)
    counts = engine_counts()
    assert counts["jax"] == 1 and counts["numpy"] == 0
    assert counts["fallback"] == 0


@jax_only
def test_jax_chunking_matches_unchunked():
    """max_bytes chunking splits the jax sweep exactly like the NumPy one:
    one engine invocation per chunk, results identical to the whole-batch
    run."""
    from repro.core import engine_counts, reset_engine_counts
    jobs = _random_mixed_jobs(5)
    whole = simulate_batch(jobs, firings=15, backend="jax")
    reset_engine_counts()
    chunked = simulate_batch(jobs, firings=15, backend="jax", max_bytes=1)
    assert engine_counts()["jax"] == len(jobs)      # one sweep per chunk
    for a, b in zip(whole, chunked):
        assert (a.cycles, a.fired, a.deadlocked) == \
            (b.cycles, b.fired, b.deadlocked)


@jax_only
def test_jax_backend_int32_guard_raises():
    """Forcing backend="jax" past the sweep's int32 range is an error, not
    a silent degrade."""
    jobs = [SimJob(_diamond()), SimJob(_diamond())]
    with pytest.raises(ValueError, match="int32"):
        simulate_batch(jobs, firings=10, max_cycles=1 << 31, backend="jax")


@jax_only
def test_auto_int32_overflow_degrades_to_numpy_with_fallback_tick():
    """auto with int32-unsafe knobs degrades to the NumPy backend — but
    audibly: a warning plus an engine_counts()["fallback"] tick (what the
    CI gate asserts is zero)."""
    from repro.core import engine_counts
    jobs = [SimJob(_diamond()), SimJob(_diamond())]
    with pytest.warns(UserWarning, match="int32"):
        out = simulate_batch(jobs, firings=10, max_cycles=1 << 31)
    assert all(r.engine == "numpy-batch" for r in out)
    counts = engine_counts()
    assert counts["fallback"] == 1 and counts["numpy"] == 1
    assert counts["jax"] == 0


@jax_only
def test_jax_compile_cache_reuses_shapes():
    """Recompilation is keyed by the bucketed padded shape only: re-running
    the same batch with different scalar knobs (firings/max_cycles are
    traced values) must hit the cache, not recompile."""
    from repro.kernels.sim_sweep import sweep_cache_stats
    jobs = _random_mixed_jobs(3)
    simulate_batch(jobs, firings=10, backend="jax")
    first = dict(sweep_cache_stats())
    simulate_batch(jobs, firings=12, backend="jax")   # same shapes, new knobs
    second = sweep_cache_stats()
    assert first["compiles"] >= 1
    assert second["compiles"] == first["compiles"]    # no recompilation
    assert second["hits"] > first["hits"]


def test_explorer_batched_throughput_eval():
    """explore_floorplans(sim_firings=...) attaches batched simulation
    results to every feasible candidate, and best_candidate drops
    deadlocked ones."""
    from repro.core import SlotGrid, best_candidate, explore_floorplans
    b = TaskGraphBuilder("chain")
    for i in range(3):
        b.stream(f"s{i}", width=64)
    for i in range(4):
        b.invoke(f"K{i}", area={"LUT": 100},
                 ins=[f"s{i-1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < 3 else [])
    g = b.build()
    grid = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 150},
                    max_util=1.0)
    # 0.3 is infeasible (a 100-LUT task cannot fit in 45), the rest are not
    cands = explore_floorplans(g, grid, utils=(0.3, 0.8, 1.0),
                               sim_firings=100)
    infeasible, feasible = cands[0], [c for c in cands if c.plan is not None]
    assert infeasible.plan is None and infeasible.sim is None
    assert infeasible.throughput_preserved is None
    assert feasible, "expected feasible candidates"
    for c in feasible:
        assert c.sim is not None and c.base_sim is not None
        assert not c.sim.deadlocked
        assert c.throughput_preserved is True
        # the shared baseline is simulated once for the whole sweep
        assert c.base_sim is feasible[0].base_sim
    assert best_candidate(cands).plan is not None


# ---------------------------------------------------------------------------
# almost-full headroom ownership (regression: no implicit 2*lat capacity)
# ---------------------------------------------------------------------------

def _chain2(depth):
    # raw construction: depth=0 is rejected by the builder's validation,
    # and deliberately broken FIFOs are exactly what these tests need
    g = TaskGraph("c2")
    g.add_task(Task("P"))
    g.add_task(Task("C"))
    g.add_stream(Stream(name="s", src="P", dst="C", width=8, depth=depth),
                 validate=False)
    return g


def test_tight_fifo_stalls_without_headroom():
    """A 2-deep FIFO with 4 cycles of pipeline latency cannot sustain full
    throughput: the producer stalls on almost-full.  The old simulator
    silently added 2*latency capacity and hid this."""
    g = _chain2(depth=2)
    stalled = simulate(g, firings=100, latency={"s": 4})
    healthy = simulate(g, firings=100, latency={"s": 4},
                       extra_capacity=pipeline_headroom({"s": 4}))
    assert not stalled.deadlocked and not healthy.deadlocked
    assert healthy.cycles <= 100 + 6            # fill skew only
    assert stalled.cycles > 1.8 * healthy.cycles  # real almost-full stall
    # both engines agree on the stalled schedule too
    _assert_engines_agree(g, firings=100, latency={"s": 4})


def test_zero_depth_fifo_deadlocks_under_correct_capacity():
    """depth=0 FIFO: the producer can never write.  With the old implicit
    +2*latency headroom this design simulated as healthy."""
    g = _chain2(depth=0)
    res = _assert_engines_agree(g, firings=5, latency={"s": 1})
    assert res.deadlocked
    ok = simulate(g, firings=5, latency={"s": 1}, extra_capacity={"s": 2})
    assert not ok.deadlocked


# ---------------------------------------------------------------------------
# perf smoke: event engine does O(firings) work, not O(cycles)
# ---------------------------------------------------------------------------

def test_event_engine_steps_scale_with_firings_not_cycles():
    """II=32 chain: the per-cycle engine scans every task for every one of
    ~3200 cycles; the event engine processes ~2 events per firing."""
    b = TaskGraphBuilder("hi_ii")
    b.stream("s", width=8, depth=4)
    b.invoke("A", area={}, outs=["s"])
    b.invoke("B", area={}, ins=["s"])
    g = b.build()
    ii = {"A": 32, "B": 32}
    ev = simulate(g, firings=100, ii=ii, engine="event")
    cy = simulate(g, firings=100, ii=ii, engine="cycle")
    assert (ev.cycles, ev.deadlocked) == (cy.cycles, cy.deadlocked)
    assert ev.cycles > 3000                  # high-II schedule is long...
    assert ev.steps * 10 <= ev.cycles        # ...but costs >=10x fewer steps
    assert cy.steps == cy.cycles             # reference scans every cycle
