"""The disk-backed floorplan store (PR-8 tentpole, ``repro.search.store``).

Covers: persist-and-reopen with zero re-solves, infeasibility verdicts
surviving the process, torn/corrupt/misfiled blob quarantine, the
content address being stable across processes (frozenset order and
string-hash randomization), bounded stores evicting oldest-first,
first-writer-wins with conflict *detection* (not silent drops), stale
temp-file cleanup, and — stateful-machine-tested — interleaved writers
with deterministic kill-mid-write fault injection reproducing an
in-memory reference model after reopen.
"""
import os
import pickle
import subprocess
import sys
import tempfile

import pytest

from _propcheck import RuleBasedStateMachine, machine_st, rule, run_state_machine

from repro.core import FloorplanCache, SlotGrid, TaskGraphBuilder, autobridge
from repro.core.ilp import InfeasibleError
from repro.search import (
    DiskFloorplanStore,
    SearchJournal,
    key_digest,
    store_counts,
)
from repro.search import faults
from repro.search.store import _read_blob, _write_blob


def _chain_graph(n=4, width=64, lut=100):
    b = TaskGraphBuilder("chain")
    for i in range(n - 1):
        b.stream(f"s{i}", width=width)
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": lut},
                 ins=[f"s{i - 1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


GRID = SlotGrid("g", rows=2, cols=2, base_capacity={"LUT": 400},
                max_util=1.0)


# ---------------------------------------------------------------------------
# blob format
# ---------------------------------------------------------------------------


def test_blob_roundtrip_and_torn_detection(tmp_path):
    p = tmp_path / "x.fp"
    _write_blob(p, b"payload bytes")
    assert _read_blob(p) == b"payload bytes"
    # torn tail: checksum must fail, not return a prefix
    raw = p.read_bytes()
    p.write_bytes(raw[:-3])
    assert _read_blob(p) is None
    # flipped bit inside the payload
    _write_blob(p, b"payload bytes")
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0x01
    p.write_bytes(bytes(raw))
    assert _read_blob(p) is None
    # wrong magic
    p.write_bytes(b"XXXX" + raw[4:])
    assert _read_blob(p) is None


# ---------------------------------------------------------------------------
# DiskFloorplanStore
# ---------------------------------------------------------------------------


def test_reopened_store_serves_solves_without_resolving(tmp_path):
    g = _chain_graph()
    first = DiskFloorplanStore(tmp_path)
    autobridge(g, GRID, cache=first)
    assert first.disk_entries() >= 1

    second = DiskFloorplanStore(tmp_path)
    plan = autobridge(g, GRID, cache=second)
    # every lookup fell through memory -> disk: no ILP solve ran
    assert second.misses == 0
    assert second.disk_hits >= 1
    ref = autobridge(g, GRID, cache=FloorplanCache())
    assert plan.floorplan.placement == ref.floorplan.placement
    assert plan.depth == ref.depth


def test_infeasible_verdict_survives_the_process(tmp_path):
    g = _chain_graph()
    first = DiskFloorplanStore(tmp_path)
    with pytest.raises(InfeasibleError):
        # util=0.02 caps every slot below one task
        autobridge(g, GRID, max_util=0.02, cache=first)

    second = DiskFloorplanStore(tmp_path)
    with pytest.raises(InfeasibleError):
        autobridge(g, GRID, max_util=0.02, cache=second)
    assert second.misses == 0          # the verdict came from disk


def test_torn_entry_quarantined_on_reopen(tmp_path):
    first = DiskFloorplanStore(tmp_path)
    autobridge(_chain_graph(), GRID, cache=first)
    (entry,) = list(first.entries_dir.glob("*.fp"))
    entry.write_bytes(entry.read_bytes()[:10])

    second = DiskFloorplanStore(tmp_path)     # verify_on_open scrubs
    assert second.quarantined == 1
    assert store_counts()["quarantined"] == 1
    assert second.disk_entries() == 0
    assert list(second.quarantine_dir.glob("*.corrupt"))
    # the miss re-solves and re-persists; the store heals
    autobridge(_chain_graph(), GRID, cache=second)
    assert second.disk_entries() == 1


def test_misfiled_entry_quarantined_not_served(tmp_path):
    first = DiskFloorplanStore(tmp_path)
    first.record_infeasible(("k", 1), "nope")
    (entry,) = list(first.entries_dir.glob("*.fp"))
    # internally-consistent blob filed under the wrong content address
    wrong = entry.with_name("0" * 64 + ".fp")
    entry.rename(wrong)
    second = DiskFloorplanStore(tmp_path)
    assert second.quarantined == 1
    assert second.cached_error(("k", 1)) is None


def test_stale_tmp_files_removed_on_open(tmp_path):
    store = DiskFloorplanStore(tmp_path)
    stale = store.entries_dir / ("a" * 64 + ".fp.123.tmp")
    stale.write_bytes(b"half a write")
    reopened = DiskFloorplanStore(tmp_path)
    assert not list(reopened.entries_dir.glob("*.tmp"))


def test_key_digest_canonicalizes_frozensets():
    a = key_digest((frozenset({frozenset({"x", "y"}), frozenset({"z"})}),))
    b = key_digest((frozenset({frozenset({"z"}), frozenset({"y", "x"})}),))
    assert a == b


def test_key_digest_stable_across_processes():
    key = ("sig", frozenset({frozenset({"x", "y"}), frozenset({"z"})}),
           0.8, 0, 22, 8, 6.0)
    here = key_digest(key)
    code = ("from repro.search.store import key_digest\n"
            "print(key_digest(('sig', frozenset({frozenset({'x', 'y'}), "
            "frozenset({'z'})}), 0.8, 0, 22, 8, 6.0)))\n")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("PYTHONHASHSEED", None)    # fresh random string hashing
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        assert out.stdout.strip() == here


def test_bounded_store_evicts_oldest(tmp_path):
    store = DiskFloorplanStore(tmp_path, max_entries=2)
    for i in range(4):
        store.record_infeasible(("k", i), f"v{i}")
        os.utime(store._entry_path(("k", i)), (i + 1, i + 1))
    assert store.disk_entries() == 2
    assert store_counts()["evictions"] == 2
    # the newest entries survived
    kept = {p.name for p in store.entries_dir.glob("*.fp")}
    assert kept == {key_digest(("k", 2)) + ".fp", key_digest(("k", 3)) + ".fp"}


def test_concurrent_writer_conflict_detected_first_writer_kept(tmp_path):
    a = DiskFloorplanStore(tmp_path)
    b = DiskFloorplanStore(tmp_path)
    a.record_infeasible(("k",), "verdict A")
    # the race window: b's lookup missed before a's os.replace committed,
    # so b proceeds to persist its own (disagreeing) value — the store
    # must detect the disagreement instead of dropping it silently
    assert b._put(("k",), ("err", "verdict B"))
    assert store_counts()["conflicts"] == 1
    fresh = DiskFloorplanStore(tmp_path)
    assert fresh.cached_error(("k",)) == "verdict A"   # first writer wins


def test_agreeing_concurrent_writers_are_not_conflicts(tmp_path):
    a = DiskFloorplanStore(tmp_path)
    b = DiskFloorplanStore(tmp_path)
    a.record_infeasible(("k",), "same verdict")
    assert b._put(("k",), ("err", "same verdict"))     # same race, same value
    assert store_counts()["conflicts"] == 0


# ---------------------------------------------------------------------------
# SearchJournal
# ---------------------------------------------------------------------------


def test_journal_save_load_roundtrip(tmp_path):
    j = SearchJournal(tmp_path, config={"a": 1})
    assert j.load_latest() is None
    j.save_round(0, {"x": 1, "hypervolume": 0.5})
    j.save_round(1, {"x": 2, "hypervolume": 0.7})
    state = j.load_latest()
    assert state["round"] == 1 and state["x"] == 2
    assert j.rounds_on_disk() == 2
    lines = j.journal_path.read_text().splitlines()
    assert len(lines) == 2 and '"round": 1' in lines[1]


def test_journal_torn_newest_falls_back_to_previous_round(tmp_path):
    j = SearchJournal(tmp_path, config={"a": 1})
    j.save_round(0, {"x": 1})
    j.save_round(1, {"x": 2})
    newest = j._state_path(1)
    newest.write_bytes(newest.read_bytes()[:7])
    state = SearchJournal(tmp_path, config={"a": 1}).load_latest()
    assert state["round"] == 0 and state["x"] == 1
    assert not newest.exists()         # quarantined, not retried forever
    assert newest.with_suffix(".pkl.corrupt").exists()


def test_journal_refuses_mismatched_config(tmp_path):
    SearchJournal(tmp_path, config={"rounds": 3})
    with pytest.raises(ValueError, match="config mismatch"):
        SearchJournal(tmp_path, config={"rounds": 4})
    # same config re-attaches fine
    SearchJournal(tmp_path, config={"rounds": 3})


def test_journal_garbage_state_blob_is_quarantined(tmp_path):
    j = SearchJournal(tmp_path, config={})
    path = j._state_path(0)
    _write_blob(path, pickle.dumps(["not", "a", "dict"]))
    assert j.load_latest() is None
    assert path.with_suffix(".pkl.corrupt").exists()


# ---------------------------------------------------------------------------
# stateful property: interleaved writers + kill-mid-write ≡ reference model
# ---------------------------------------------------------------------------


class DiskStoreMachine(RuleBasedStateMachine):
    """Two writer processes (modelled as two store instances over one
    root) interleave first-writer-wins entry writes while a seeded fault
    plan tears a deterministic subset of them mid-write (the kill-mid-
    write drill: an atomic-rename crash leaves nothing, the injected tear
    leaves a detectable corpse).  A writer may 'die' at any point and
    reopen with empty memory.  The reference model predicts durability
    per key straight from the fault plan — ``FaultPlan.decide`` is pure —
    and a fresh store opened at the end must agree with it exactly."""

    PLAN = faults.FaultPlan(seed=11, torn_write=0.5)

    def __init__(self):
        self._tmp = tempfile.TemporaryDirectory(prefix="repro-storeprop-")
        self.root = self._tmp.name
        self.writers = [DiskFloorplanStore(self.root),
                        DiskFloorplanStore(self.root)]
        self.model: dict[tuple, str] = {}       # key -> first-written value
        self.wrote: list[set[tuple]] = [set(), set()]

    def _durable(self, key) -> bool:
        return not self.PLAN.decide("torn_write", key_digest(key))

    @rule(w=machine_st.integers(0, 1), i=machine_st.integers(0, 11))
    def put(self, w, i):
        key, value = ("k", i), f"verdict for {i}"
        with faults.install(self.PLAN, env=False):
            self.writers[w].record_infeasible(key, value)
        self.model.setdefault(key, value)
        self.wrote[w].add(key)

    @rule(w=machine_st.integers(0, 1), i=machine_st.integers(0, 11))
    def lookup(self, w, i):
        key = ("k", i)
        got = self.writers[w].cached_error(key)
        if key in self.wrote[w] or (key in self.model and self._durable(key)):
            assert got == self.model[key]
        else:
            assert got is None

    @rule(w=machine_st.integers(0, 1))
    def kill_and_reopen(self, w):
        # a killed writer loses its memory tier; disk is all that remains
        self.writers[w] = DiskFloorplanStore(self.root)
        self.wrote[w] = set()

    def finalize(self):
        fresh = DiskFloorplanStore(self.root)
        for key, value in self.model.items():
            got = fresh.cached_error(key)
            if self._durable(key):
                assert got == value, (key, "durable write lost")
            else:
                assert got is None, (key, "torn write served")
        # determinism must make disagreement impossible
        assert store_counts()["conflicts"] == 0


def test_disk_store_interleaved_writers_property():
    run_state_machine(DiskStoreMachine, steps=14, max_examples=6)
