"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU).

Every kernel is swept over shapes (incl. non-aligned head dims / odd
lengths) and dtypes; tolerances scale with dtype.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.burst_gather import burst_gather
from repro.kernels.flash_attention import decode_attention, flash_attention
from repro.kernels.mamba2_scan import mamba2_scan
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rwkv6_scan import rwkv6_scan


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, Sq, Skv, Hq, Hkv, D)
    (1, 16, 16, 2, 2, 16),     # MHA
    (2, 48, 48, 4, 2, 24),     # GQA, odd D
    (1, 33, 33, 4, 1, 64),     # non-tile-aligned S, MQA
])
@pytest.mark.parametrize("variant", ["causal", "window", "softcap", "full"])
def test_flash_attention_sweep(dtype, shape, variant):
    B, Sq, Skv, Hq, Hkv, D = shape
    key = jax.random.PRNGKey(hash((shape, variant)) % 2**31)
    q = rand(key, (B, Sq, Hq, D), dtype)
    k = rand(jax.random.fold_in(key, 1), (B, Skv, Hkv, D), dtype)
    v = rand(jax.random.fold_in(key, 2), (B, Skv, Hkv, D), dtype)
    kwargs = {
        "causal": dict(causal=True),
        "window": dict(causal=True, window=max(4, Sq // 3)),
        "softcap": dict(causal=True, softcap=20.0),
        "full": dict(causal=False),
    }[variant]
    want = ref.attention_ref(q, k, v, **kwargs)
    got = flash_attention(q, k, v, interpret=True, **kwargs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_kv_len_and_offset():
    key = jax.random.PRNGKey(0)
    q = rand(key, (2, 8, 2, 16), jnp.float32)
    k = rand(jax.random.fold_in(key, 1), (2, 32, 2, 16), jnp.float32)
    v = rand(jax.random.fold_in(key, 2), (2, 32, 2, 16), jnp.float32)
    kv_len = jnp.array([20, 32])
    want = ref.attention_ref(q, k, v, causal=True, q_offset=12, kv_len=kv_len)
    got = flash_attention(q, k, v, causal=True, q_offset=12, kv_len=kv_len,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention():
    key = jax.random.PRNGKey(1)
    q = rand(key, (2, 1, 4, 32), jnp.bfloat16)
    k = rand(jax.random.fold_in(key, 1), (2, 64, 2, 32), jnp.bfloat16)
    v = rand(jax.random.fold_in(key, 2), (2, 64, 2, 32), jnp.bfloat16)
    kv_len = jnp.array([40, 64])
    want = ref.attention_ref(q, k, v, causal=False, kv_len=kv_len)
    got = decode_attention(q, k, v, kv_len=kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=2e-2,
                               atol=2e-2)


# ---------------------------------------------------------------------------
# mamba2 / rwkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 16, 2, 8, 16), (2, 40, 3, 16, 20),
                                   (1, 65, 2, 64, 64)])
@pytest.mark.parametrize("with_state", [False, True])
def test_mamba2_scan_sweep(dtype, shape, with_state):
    B, S, H, P, N = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = rand(key, (B, S, H, P), dtype)
    dt = jax.nn.softplus(rand(jax.random.fold_in(key, 1), (B, S, H),
                              jnp.float32))
    A = -jnp.exp(rand(jax.random.fold_in(key, 2), (H,), jnp.float32))
    Bm = rand(jax.random.fold_in(key, 3), (B, S, N), dtype)
    Cm = rand(jax.random.fold_in(key, 4), (B, S, N), dtype)
    state = rand(jax.random.fold_in(key, 5), (B, H, P, N), jnp.float32) \
        if with_state else None
    yr, hr = ref.mamba2_scan_ref(x, dt, A, Bm, Cm, state)
    yk, hk = mamba2_scan(x, dt, A, Bm, Cm, state, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 16, 2, 8), (2, 40, 3, 16),
                                   (1, 33, 2, 64)])
@pytest.mark.parametrize("with_state", [False, True])
def test_rwkv6_scan_sweep(dtype, shape, with_state):
    B, S, H, D = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    r = rand(key, (B, S, H, D), dtype)
    k = rand(jax.random.fold_in(key, 1), (B, S, H, D), dtype)
    v = rand(jax.random.fold_in(key, 2), (B, S, H, D), dtype)
    w = jnp.exp(-jnp.exp(rand(jax.random.fold_in(key, 3), (B, S, H, D),
                              jnp.float32))).astype(dtype)
    u = 0.3 * rand(jax.random.fold_in(key, 4), (H, D), jnp.float32)
    state = rand(jax.random.fold_in(key, 5), (B, H, D, D), jnp.float32) \
        if with_state else None
    yr, sr = ref.rwkv6_scan_ref(r, k, v, w, u, state)
    yk, sk = rwkv6_scan(r, k, v, w, u, state, chunk=16, interpret=True)
    # chunked rescan vs the sequential reference: fp32 accumulation over the
    # longest (S=33, D=64) sweep legitimately drifts a few 1e-5, so the fp32
    # tolerance is looser than the generic 2e-5 used elsewhere.
    ytol = tol(dtype) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32), **ytol)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


# ---------------------------------------------------------------------------
# burst gather / moe gmm
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 60),
       st.sampled_from([0.0, 0.5, 1.0]))
def test_burst_gather_property(seed, n, seq_frac):
    """Any index pattern — fully sequential, mixed, or random — must match
    a plain gather (the burst detector is a pure optimization)."""
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(64, 12)), jnp.float32)
    idx = np.empty(n, np.int32)
    i = 0
    while i < n:
        if rng.random() < seq_frac:
            run = min(int(rng.integers(2, 12)), n - i)
            start = int(rng.integers(0, 64 - run))
            idx[i:i + run] = np.arange(start, start + run)
            i += run
        else:
            idx[i] = rng.integers(0, 64)
            i += 1
    idx = jnp.asarray(idx)
    want = ref.burst_gather_ref(table, idx)
    got = burst_gather(table, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(50, 24, 36, 5), (16, 8, 8, 2),
                                   (130, 64, 32, 8)])
def test_moe_gmm_sweep(dtype, shape):
    T, K, N, E = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = rand(key, (T, K), dtype)
    w = rand(jax.random.fold_in(key, 1), (E, K, N), dtype) * 0.1
    gid = jnp.sort(jax.random.randint(jax.random.fold_in(key, 2), (T,), 0, E))
    want = ref.moe_gmm_ref(x, w, gid)
    got = moe_gmm(x, w, gid, tb=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
