"""Distributed-layer tests on 8 host devices: pipeline loss/grad parity,
TAPA planning, refined mesh construction, collective extraction.

NOTE: runs in a subprocess with XLA_FLAGS so the main pytest process keeps
its single-device view (per the dry-run spec: only the dry-run sees many
devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro import configs
from repro.distributed.sharding import plan_cell
from repro.distributed.taskgraph import SHAPES, arch_taskgraph
from repro.launch.hlo_analysis import collective_summary


def test_arch_taskgraph_families():
    cfg = configs.get("zamba2-7b")
    g = arch_taskgraph(cfg, SHAPES["train_4k"], micro_tokens=4096)
    # zamba2 has the x0 skip stream into every group (reconvergent)
    x0 = [s for s in g.streams if s.name.startswith("x0_")]
    assert len(x0) == cfg.n_layers // len(cfg.layer_pattern)

    cfg = configs.get("whisper-tiny")
    g = arch_taskgraph(cfg, SHAPES["train_4k"], micro_tokens=4096)
    assert "frontend" in g.tasks


@pytest.mark.parametrize("arch", ["granite-8b", "zamba2-7b", "arctic-480b"])
def test_plan_cell_produces_stages(arch):
    cfg = configs.get(arch)
    plan = plan_cell(cfg, "train_4k", (2, 16, 16), mode="tapa")
    n_groups = cfg.n_layers // len(cfg.layer_pattern)
    assert plan.n_stages >= 1
    assert plan.n_stages * plan.groups_per_stage == n_groups
    assert len(plan.boundary_depth) == plan.n_stages - 1
    assert all(d >= 1 for d in plan.boundary_depth)
    # multi-pod plans must use pod-crossing boundaries somewhere if stages
    # span pods
    rows = {s[0] for s in plan.stage_slots}
    if len(rows) > 1:
        assert max(plan.boundary_depth) >= 2   # DCN boundary double-buffered


def test_collective_summary_parsing():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ag = bf16[8,256]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={1}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,4},{1,5}}
"""
    s = collective_summary(hlo, pod_size=4)
    assert s["count"] == 3
    assert s["ops"]["all-reduce"] == 1
    # ar: groups within pods (ids 0-3) -> ici; cp crosses pods (0->4) -> dcn
    assert s["dcn_bytes"] >= 64 * 4
    assert s["ici_bytes"] > 0


PIPELINE_PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro import configs
    from repro.model import lm
    from repro.distributed import pipeline as pp
    from repro.distributed.sharding import TpuPlan

    cfg = configs.get_reduced("granite-8b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_micro, mb, seq = 4, 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (n_micro, mb, seq+1),
                                0, cfg.vocab)
    def ref_loss(params):
        tot = 0.0
        for m in range(n_micro):
            tot = tot + lm.loss_fn(params, cfg, {"tokens": tokens[m]})
        return tot / n_micro
    ref = float(jax.jit(ref_loss)(params))
    plan = TpuPlan(mode="tapa", n_stages=2, groups_per_stage=1,
                   stage_slots=[(0, 0), (0, 1)], boundary_depth=[2], tp=2,
                   crossing_cost=0.0)
    rmesh = make_mesh((2, 2, 2), ("stage", "data", "tp"))
    pparams = pp.to_pipeline_params(params, 2)
    loss_fn = pp.build_train_loss(cfg, plan, rmesh, n_micro=n_micro,
                                  remat=False)
    with rmesh:
        specs = pp.param_specs(cfg, pparams, tp_axis="tp", tp_size=2,
                               stage_axis="stage")
        shard = jax.tree.map(lambda s: NamedSharding(rmesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
        pparams_s = jax.device_put(pparams, shard)
        out = float(jax.jit(loss_fn)(pparams_s, {"tokens": tokens}))
        g = jax.jit(jax.grad(loss_fn))(pparams_s, {"tokens": tokens})
    gref = pp.to_pipeline_params(jax.grad(ref_loss)(params), 2)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), g, gref)))
    assert abs(out - ref) < 1e-3, (out, ref)
    assert err < 5e-3, err
    print("PARITY_OK", out, err)
""")


def test_pipeline_parity_8dev():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", PIPELINE_PARITY], env=env,
                       cwd="/root/repo", capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PARITY_OK" in r.stdout
