"""Substrate tests: optimizers, data pipeline, checkpointing, compression,
elastic replanning, FT restart."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.data import ShardedLoader, SyntheticTokens
from repro.distributed.collectives import (compress_grads, decompress_grads,
                                           init_error_buf)
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule,
                         zero1_specs)


def _quad_problem():
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (8, 8))
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}

    def loss(p):
        return jnp.sum((p["w"] + p["b"][None, :] - target) ** 2)
    return params, loss, target


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend(opt):
    params, loss, _ = _quad_problem()
    init, update = ((adamw_init, adamw_update) if opt == "adamw"
                    else (adafactor_init, adafactor_update))
    state = init(params)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = update(params, g, state, lr=5e-2)
    assert float(loss(params)) < 0.05 * l0


def test_clip_and_schedule():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)
    lrs = [float(cosine_schedule(s, peak=1.0, warmup=10, total=100))
           for s in (0, 10, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(1.0) \
        and lrs[2] == pytest.approx(0.1, rel=1e-2)


def test_grad_compression_error_feedback():
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (64, 64))}
    err = init_error_buf(g)
    acc_true = jnp.zeros((64, 64))
    acc_q = jnp.zeros((64, 64))
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        acc_true = acc_true + gi["w"]
        q, s, err = compress_grads(gi, err)
        acc_q = acc_q + decompress_grads(q, s)["w"]
    # error feedback keeps the ACCUMULATED estimate unbiased & tight
    rel = float(jnp.abs(acc_q - acc_true).max() /
                jnp.abs(acc_true).max())
    assert rel < 0.05


def test_zero1_specs_divisibility():
    from jax.sharding import PartitionSpec as P
    specs = {"w": P(None, "model"), "g": P(None, None)}
    structs = {"w": jax.ShapeDtypeStruct((36, 64), jnp.float32),
               "g": jax.ShapeDtypeStruct((32, 7), jnp.float32)}
    z = zero1_specs(specs, structs, data_axes=("data",), data_size=16)
    assert tuple(z["w"]) == (None, "model")        # 36 not divisible: skip
    assert tuple(z["g"])[0] in ("data", ("data",))  # 32 divisible


def test_data_pipeline_learnable_and_sharded():
    src = SyntheticTokens(vocab=512, seed=0)
    b0 = src.batch(0, shard=0, batch=4, seq=32)
    b1 = src.batch(0, shard=1, batch=4, seq=32)
    assert b0.shape == (4, 33) and b0.dtype == np.int32
    assert not np.array_equal(b0, b1)             # shards differ
    assert np.array_equal(b0, src.batch(0, 0, 4, 32))   # deterministic
    half = 33 // 2
    assert np.array_equal(b0[:, half:2 * half], b0[:, :half])  # structure
    loader = ShardedLoader(src, shard=0, batch=4, seq=32)
    a, b = next(loader), next(loader)
    assert a.shape == (4, 33)
    loader.close()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "opt": {"m": jnp.ones((3, 4)), "step": jnp.asarray(7)}}
    save_checkpoint(str(tmp_path), 5, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    assert latest_step(str(tmp_path)) == 9
    back = restore_checkpoint(str(tmp_path), 9, tree)
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])
    assert int(back["opt"]["step"]) == 7


def test_train_restart_after_failure(tmp_path):
    """FT driver: crash at step 30, restart resumes from the checkpoint."""
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "granite-8b", "--reduced", "--steps", "60", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every",
            "20", "--log-every", "100"]
    r = subprocess.run(base + ["--fail-at", "30"], env=env, cwd="/root/repo",
                       capture_output=True, text=True)
    assert r.returncode == 42, r.stderr[-2000:]
    assert latest_step(str(tmp_path)) == 20
    r = subprocess.run(base, env=env, cwd="/root/repo",
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "restoring from step 20" in r.stdout
    assert latest_step(str(tmp_path)) == 60


def test_elastic_replan_on_failure():
    """Losing a slot re-floorplans onto the survivors."""
    from repro import configs
    from repro.distributed.elastic import ClusterState, replan
    cfg = configs.get("granite-8b")
    healthy = replan(cfg, "train_4k",
                     ClusterState(pods=2, data=16, model=16))
    degraded = replan(cfg, "train_4k",
                      ClusterState(pods=2, data=16, model=16,
                                   failed_slots=frozenset({(1, 3)})))
    assert healthy.n_stages >= 1
    assert (1, 3) not in degraded.stage_slots
    assert degraded.n_stages >= 1


def test_straggler_derate():
    from repro import configs
    from repro.distributed.elastic import ClusterState, replan
    cfg = configs.get("granite-8b")
    slow = replan(cfg, "train_4k",
                  ClusterState(pods=1, data=16, model=16,
                               derate={(0, 0): 0.4}))
    # the derated slot must not carry a full compute stage
    if (0, 0) in slow.stage_slots:
        # acceptable only if stages shrank around it
        assert slow.n_stages >= 1
    assert slow.n_stages >= 1
