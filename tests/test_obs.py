"""The unified observability layer (``repro.obs``).

Covers: the metrics registry (legacy dict idioms, snapshot/delta/merge
semantics — merge associativity/commutativity/zero-identity is
property-tested with a stateful machine), backcompat of all nine legacy
``*_counts()`` surfaces against the registry, structured tracing
(nesting, worker-token propagation, Chrome export ordering), the
trace_event schema validator and ``bench_block`` against synthetic
documents, the ``check_obs`` regression gate, and the acceptance
property: a ``jobs=4`` converged run's trace contains every dispatched
worker ILP solve exactly once, parented under its dispatching round.
"""

import json
import math
import os
import subprocess
import sys

import pytest
from _propcheck import (RuleBasedStateMachine, machine_st, rule,
                        run_state_machine)

from repro.core import (
    Interval,
    SearchSpace,
    SimJob,
    TaskGraphBuilder,
    engine_counts,
    floorplan_counts,
    merge_floorplan_counts,
    search_until_converged,
    simulate_batch,
)
from repro.core.ilp import merge_solve_counts, solve_counts
from repro.analysis import analysis_counts
from repro.fpga import u280_grid
from repro.obs import bench_obs_block, metrics, trace
from repro.search import fault_counts, pool_counts, store_counts
from repro.search.pool import pool_task_stats
from repro.search.store import store_lookup_stats

_HERE = os.path.dirname(os.path.abspath(__file__))
_BENCHMARKS = os.path.join(os.path.dirname(_HERE), "benchmarks")
sys.path.insert(0, _BENCHMARKS)

from check_regression import check_obs  # noqa: E402


def _chain_graph(n=4, width=64, lut=100):
    b = TaskGraphBuilder("obschain")
    for i in range(n - 1):
        b.stream(f"s{i}", width=width)
    for i in range(n):
        b.invoke(f"K{i}", area={"LUT": lut},
                 ins=[f"s{i - 1}"] if i > 0 else [],
                 outs=[f"s{i}"] if i < n - 1 else [])
    return b.build()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_group_legacy_dict_idioms():
    reg = metrics.Registry()
    g = reg.group("legacy", {"hits": 0, "misses": 0})
    g["hits"] += 2
    g.update({"misses": 5})
    assert dict(g) == {"hits": 2, "misses": 5}
    # clear() zeroes in place (legacy reset semantics), keeping the keys
    saved = dict(g)
    g.clear()
    assert dict(g) == {"hits": 0, "misses": 0}
    g.update(saved)  # the save/restore idiom measure_backend_speedup uses
    assert dict(g) == saved


def test_group_reset_hook_fires():
    fired = []
    reg = metrics.Registry()
    g = reg.group("hook", {"n": 0}, on_reset=lambda: fired.append(1))
    g["n"] = 3
    g.reset()
    assert dict(g) == {"n": 0} and fired == [1]
    g.clear()
    assert fired == [1, 1]


def test_delta_excludes_gauges_and_named_entries():
    reg = metrics.Registry()
    g = reg.group("work", {"n": 0})
    f = reg.group("faults", {"boom": 0})
    gauge = reg.gauge("queue_depth")
    before = reg.snapshot()
    g["n"] += 2
    f["boom"] += 1
    gauge.set(7)
    d = reg.delta(before, exclude=("faults",))
    assert d == {"work": {"kind": "group", "values": {"n": 2}}}


def test_merge_registers_unknown_entries_on_the_fly():
    src, dst = metrics.Registry(), metrics.Registry()
    src.group("g", {"a": 0})["a"] = 3
    src.counter("c").inc(2, kind="x")
    src.histogram("h").observe(1.5)
    delta = src.delta({})
    dst.merge(delta)
    assert dict(dst.get("g")) == {"a": 3}
    assert dst.get("c").value(kind="x") == 2
    assert dst.get("h").aggregate()["count"] == 1


def test_histogram_aggregate_merges_exactly():
    a, b = metrics.Histogram("t"), metrics.Histogram("t")
    a.observe(1.0, tier="disk")
    a.observe(3.0, tier="disk")
    b.observe(2.0, tier="disk")
    a.merge(b.snapshot())
    agg = a.aggregate(tier="disk")
    assert agg == {"count": 3, "sum": 6.0, "min": 1.0, "max": 3.0,
                   "mean": 2.0}


def test_restore_resets_entries_registered_after_snapshot():
    reg = metrics.Registry()
    g = reg.group("early", {"n": 0})
    g["n"] = 1
    snap = reg.snapshot()
    late = reg.group("late", {"m": 0})
    late["m"] = 9
    g["n"] = 5
    reg.restore(snap)
    assert dict(g) == {"n": 1}
    assert dict(late) == {"m": 0}


class MergeAlgebraMachine(RuleBasedStateMachine):
    """Registry merge is associative + commutative with zero-identity.

    Rules accumulate a random batch of worker-style deltas (group
    increments, histogram observations, empty deltas); ``finalize``
    checks that folding them in program order, in reverse order, and
    with interleaved zero deltas all reach the same registry state.
    """

    def __init__(self):
        self.deltas = []

    @rule(field=machine_st.sampled_from(["solved", "hits"]),
          amount=machine_st.integers(0, 7))
    def group_delta(self, field, amount):
        self.deltas.append(
            {"g": {"kind": "group", "values": {field: amount}}})

    @rule(count=machine_st.integers(1, 4),
          # dyadic values keep float sums exact, so reordered folds
          # compare equal without a tolerance
          value=machine_st.sampled_from([0.0, 0.25, 0.5, 1.0, 1.5]))
    def hist_delta(self, count, value):
        self.deltas.append(
            {"h": {"kind": "histogram",
                   "values": {"": {"count": count, "sum": value * count,
                                   "min": value, "max": value}}}})

    @rule(amount=machine_st.integers(1, 5))
    def counter_delta(self, amount):
        self.deltas.append(
            {"c": {"kind": "counter", "values": {"kind=x": amount}}})

    @rule()
    def zero_delta(self):
        self.deltas.append({})

    @staticmethod
    def _fold(deltas):
        reg = metrics.Registry()
        for d in deltas:
            reg.merge(d)
        return reg.snapshot()

    def finalize(self):
        fwd = self._fold(self.deltas)
        rev = self._fold(list(reversed(self.deltas)))
        assert fwd == rev, "merge order changed the folded state"
        # zero-delta identity: interleaving empties changes nothing
        padded = []
        for d in self.deltas:
            padded += [{}, d]
        assert self._fold(padded) == fwd


def test_registry_merge_algebra_property():
    run_state_machine(MergeAlgebraMachine, steps=12, max_examples=8)


# ---------------------------------------------------------------------------
# legacy surface backcompat
# ---------------------------------------------------------------------------


def test_all_legacy_surfaces_are_registry_views():
    """Every legacy ``*_counts()`` dict must be the exact values held by
    the registry under its dotted name — the shims are views, not copies
    that can drift."""
    from repro.kernels.sim_sweep import sweep_cache_stats

    simulate_batch([SimJob(_chain_graph())], firings=5, backend="event")
    surfaces = {
        "sim.engine": engine_counts(),
        "ilp": solve_counts(),
        "floorplan": floorplan_counts(),
        "analysis": analysis_counts(),
        "pool": pool_counts(),
        "store": store_counts(),
        "faults": fault_counts(),
        "sim.jit_cache": sweep_cache_stats(),
    }
    assert len(surfaces) == 8
    # floorplan_counts() joins in the ilp group's bipartitions as a
    # derived field; everything else maps one-to-one
    derived = surfaces["floorplan"].pop("ilp_bipartitions")
    assert derived == metrics.REGISTRY.get("ilp")["bipartitions"]
    for name, legacy in surfaces.items():
        entry = metrics.REGISTRY.get(name)
        assert entry is not None, f"{name} not registered"
        assert dict(legacy) == entry.snapshot(), name
    # the ninth surface: the merge shims mutate the same registry state
    merge_floorplan_counts({"solved": 2, "cache_hits": 1,
                            "merge_conflicts": 0})
    merge_solve_counts(4)
    assert floorplan_counts()["solved"] == \
        metrics.REGISTRY.get("floorplan")["solved"]
    assert solve_counts()["bipartitions"] == \
        metrics.REGISTRY.get("ilp")["bipartitions"]


def test_engine_counts_tick_through_registry():
    simulate_batch([SimJob(_chain_graph())], firings=5, backend="event")
    assert engine_counts()["event"] == 1
    assert metrics.REGISTRY.get("sim.engine")["event"] == 1


def test_latency_histograms_surface_as_stats():
    assert set(pool_task_stats()) == {"ok", "infeasible"}
    assert set(store_lookup_stats()) == {"hit", "miss"}
    for agg in (*pool_task_stats().values(), *store_lookup_stats().values()):
        assert set(agg) == {"count", "sum", "min", "max", "mean"}


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_span_nesting_and_args():
    trace.enable(clear=True)
    with trace.span("outer", label="x", dropped=None):
        with trace.span("inner"):
            pass
    evs = trace.events()
    assert [e["name"] for e in evs] == ["outer", "inner"]
    assert evs[1]["parent"] == evs[0]["id"]
    assert evs[0]["args"] == {"label": "x"}  # None args dropped
    # inner interval nested inside outer (shared monotonic timebase)
    assert evs[0]["t_ns"] <= evs[1]["t_ns"]
    assert (evs[1]["t_ns"] + evs[1]["dur_ns"]
            <= evs[0]["t_ns"] + evs[0]["dur_ns"])


def test_disabled_tracing_is_noop():
    trace.disable()
    trace.clear()
    with trace.span("ghost") as rec:
        assert rec is None
    assert trace.events() == []


def test_worker_token_parents_spans_across_drain_absorb():
    """Simulate the pool protocol in-process: the parent opens a round,
    ships its token, the 'worker' begins with it, records a span, drains,
    and the parent absorbs — the worker span must parent under the round."""
    trace.enable(clear=True)
    with trace.span("search.round", round=0) as round_rec:
        token = trace.current_token()
        assert token == round_rec["id"]
        parent_events = trace.drain()  # stash parent buffer (round is open)
        trace.begin_worker(token, enable_tracing=True)
        with trace.span("pool.worker_solve"):
            pass
        shipped = trace.drain()
        trace.absorb(parent_events)
        trace.absorb(shipped)
    evs = trace.events()
    worker = next(e for e in evs if e["name"] == "pool.worker_solve")
    assert worker["parent"] == round_rec["id"]


def test_begin_worker_clears_inherited_buffer():
    trace.enable(clear=True)
    with trace.span("stale"):
        pass
    trace.begin_worker("tok-1", enable_tracing=True)
    assert trace.events() == []
    with trace.span("fresh"):
        pass
    assert trace.events()[0]["parent"] == "tok-1"


def test_to_chrome_emits_sorted_pairs_and_metadata():
    trace.enable(clear=True)
    with trace.span("a.outer"):
        with trace.span("a.inner"):
            pass
    doc = trace.to_chrome()
    phs = [e["ph"] for e in doc["traceEvents"]]
    assert phs == ["M", "B", "B", "E", "E"]
    meta = doc["traceEvents"][0]
    assert meta["name"] == "process_name"
    assert meta["args"]["name"] == "repro"
    b_outer = doc["traceEvents"][1]
    assert b_outer["cat"] == "a"
    assert "span_id" in b_outer["args"]
    assert trace.validate_chrome(doc) == []


def test_to_chrome_skips_unclosed_spans():
    trace.enable(clear=True)
    rec = trace.begin("never.closed")
    with trace.span("fine"):
        pass
    doc = trace.to_chrome()
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert names == {"fine"}
    trace.end(rec)


# ---------------------------------------------------------------------------
# validator + bench block on synthetic documents
# ---------------------------------------------------------------------------


def _ev(ph, name, ts, pid=1, tid=1, **kw):
    return {"ph": ph, "name": name, "ts": ts, "pid": pid, "tid": tid, **kw}


def test_validate_chrome_accepts_well_formed_doc():
    doc = {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "repro"}},
        _ev("B", "outer", 0.0), _ev("B", "inner", 1.0),
        _ev("E", "inner", 2.0), _ev("E", "outer", 3.0),
    ]}
    assert trace.validate_chrome(doc) == []


def test_validate_chrome_flags_missing_pid_tid():
    doc = {"traceEvents": [{"ph": "B", "name": "x", "ts": 0.0, "pid": 1}]}
    errs = trace.validate_chrome(doc)
    assert any("missing pid/tid" in e for e in errs)


def test_validate_chrome_flags_nonmonotonic_ts():
    doc = {"traceEvents": [
        _ev("B", "a", 5.0), _ev("E", "a", 2.0),
    ]}
    errs = trace.validate_chrome(doc)
    assert any("not monotonic" in e for e in errs)


def test_validate_chrome_flags_unmatched_pairs():
    assert any("E without B" in e for e in trace.validate_chrome(
        {"traceEvents": [_ev("E", "x", 1.0)]}))
    assert any("unclosed B" in e for e in trace.validate_chrome(
        {"traceEvents": [_ev("B", "x", 1.0)]}))
    assert any("mismatched B/E" in e for e in trace.validate_chrome(
        {"traceEvents": [_ev("B", "x", 1.0), _ev("E", "y", 2.0)]}))


def test_validate_chrome_flags_empty_and_spanless_docs():
    assert trace.validate_chrome({}) == ["traceEvents missing or empty"]
    only_meta = {"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "repro"}}]}
    assert trace.validate_chrome(only_meta) == ["no complete spans in trace"]


def _span_rec(id, name, parent=None, t0=0, dur=1_000_000, pid=1, tid=1):
    return {"id": id, "parent": parent, "name": name, "pid": pid,
            "tid": tid, "t_ns": t0, "dur_ns": dur, "end_seq": 1, "args": {}}


def test_bench_block_counts_unclosed_and_orphans():
    spans = [
        _span_rec("1-1", "bench.suite", dur=10_000_000_000),
        _span_rec("1-2", "bench.prepare", parent="1-1",
                  dur=9_500_000_000),
        dict(_span_rec("1-3", "hung", parent="1-1"), dur_ns=None),
        _span_rec("2-1", "pool.worker_solve", parent="gone-99",
                  pid=2),
    ]
    block = trace.bench_block(10.0, spans)
    assert block["spans"] == 3          # closed only
    assert block["unclosed"] == 1
    assert block["orphans"] == 1
    assert block["pids"] == 2
    # coverage from depth-1 children of roots (bench.prepare), not roots
    assert block["stage_coverage"] == pytest.approx(0.95)
    assert block["by_name"]["bench.prepare"]["count"] == 1


def test_bench_block_falls_back_to_roots_in_flat_trace():
    spans = [_span_rec("1-1", "only.root", dur=2_000_000_000)]
    block = trace.bench_block(4.0, spans)
    assert block["stage_coverage"] == pytest.approx(0.5)


def test_bench_block_coverage_capped_at_one():
    spans = [
        _span_rec("1-1", "root", dur=2_000_000_000),
        _span_rec("1-2", "stage", parent="1-1", dur=2_000_000_000),
    ]
    assert trace.bench_block(0.5, spans)["stage_coverage"] == 1.0


def test_summarize_renders_top_table():
    trace.enable(clear=True)
    with trace.span("big.stage"):
        pass
    text = trace.summarize(trace.to_chrome())
    assert "big.stage" in text and "total_ms" in text
    assert trace.summarize({"traceEvents": []}) == "no complete spans"


# ---------------------------------------------------------------------------
# the check_obs regression gate
# ---------------------------------------------------------------------------


def _obs_doc(**over):
    obs = {"enabled": True, "spans": 12, "unclosed": 0, "orphans": 0,
           "pids": 1, "stage_coverage": 0.97, "covered_wall_s": 9.7,
           "wall_s": 10.0, "by_name": {}}
    obs.update(over)
    return {"suite": "fmax_suite", "sim": {"obs": obs}}


def test_check_obs_passes_healthy_block(tmp_path):
    assert check_obs(_obs_doc(), label="t", json_dir=str(tmp_path)) == []


def test_check_obs_ignores_uninstrumented_runs(tmp_path):
    assert check_obs({"suite": "fmax_suite", "sim": {}}, label="t",
                     json_dir=str(tmp_path)) == []
    assert check_obs({"suite": "fmax_suite"}, label="t",
                     json_dir=str(tmp_path)) == []


def test_check_obs_flags_zero_span_runs(tmp_path):
    errs = check_obs(_obs_doc(spans=0), label="t", json_dir=str(tmp_path))
    assert any("zero spans" in e for e in errs)


def test_check_obs_flags_unclosed_orphans_and_low_coverage(tmp_path):
    errs = check_obs(_obs_doc(unclosed=2, orphans=1, stage_coverage=0.5),
                     label="t", json_dir=str(tmp_path))
    assert any("unclosed" in e for e in errs)
    assert any("orphaned" in e for e in errs)
    assert any("50%" in e for e in errs)


def test_check_obs_validates_referenced_trace_file(tmp_path):
    good = {"traceEvents": [_ev("B", "a", 0.0), _ev("E", "a", 1.0)]}
    (tmp_path / "ok.trace.json").write_text(json.dumps(good))
    assert check_obs(_obs_doc(trace_file="ok.trace.json"), label="t",
                     json_dir=str(tmp_path)) == []
    bad = {"traceEvents": [_ev("E", "a", 1.0)]}
    (tmp_path / "bad.trace.json").write_text(json.dumps(bad))
    errs = check_obs(_obs_doc(trace_file="bad.trace.json"), label="t",
                     json_dir=str(tmp_path))
    assert any("E without B" in e for e in errs)
    errs = check_obs(_obs_doc(trace_file="missing.trace.json"), label="t",
                     json_dir=str(tmp_path))
    assert any("unreadable" in e for e in errs)


def test_corpus_suite_obs_block_is_top_level(tmp_path):
    doc = {"suite": "corpus", "obs": _obs_doc()["sim"]["obs"] | {"spans": 0}}
    errs = check_obs(doc, label="corpus", json_dir=str(tmp_path))
    assert any("zero spans" in e for e in errs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_obs_cli_summarize_and_validate(tmp_path):
    trace.enable(clear=True)
    with trace.span("cli.demo"):
        pass
    path = tmp_path / "t.trace.json"
    trace.write_chrome(str(path))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(_HERE), "src")
    out = subprocess.run([sys.executable, "-m", "repro.obs", "summarize",
                          str(path)], capture_output=True, text=True,
                         env=env)
    assert out.returncode == 0 and "cli.demo" in out.stdout
    out = subprocess.run([sys.executable, "-m", "repro.obs", "validate",
                          str(path)], capture_output=True, text=True,
                         env=env)
    assert out.returncode == 0 and "ok: 1 spans" in out.stdout
    bad = tmp_path / "bad.trace.json"
    bad.write_text(json.dumps({"traceEvents": [_ev("E", "x", 1.0)]}))
    out = subprocess.run([sys.executable, "-m", "repro.obs", "validate",
                          str(bad)], capture_output=True, text=True, env=env)
    assert out.returncode == 1 and "E without B" in out.stderr


# ---------------------------------------------------------------------------
# acceptance property: jobs=4 converged run's worker spans
# ---------------------------------------------------------------------------


def test_parallel_converged_trace_has_every_worker_solve_once():
    """A ``jobs=4`` converged run's trace must contain **every** dispatched
    worker ILP solve exactly once (``pool.worker_solve`` span count ==
    pool ``dispatched``), and each must reach a ``search.round`` span
    through its parent chain — the cross-process token really landed."""
    trace.enable(clear=True)
    graph = _chain_graph()
    res = search_until_converged(
        graph, u280_grid(), jobs=4,
        space=SearchSpace(utils=Interval(0.7, 1.0)),
        rounds=2, points_per_round=6, sim_firings=40, tol=0.0)
    assert res.pool is not None
    dispatched = pool_counts()["dispatched"]
    assert dispatched > 0
    evs = trace.events()
    by_id = {e["id"]: e for e in evs}
    solves = [e for e in evs if e["name"] == "pool.worker_solve"]
    assert len(solves) == dispatched
    assert len({e["id"] for e in solves}) == dispatched  # exactly once
    rounds = {e["id"] for e in evs if e["name"] == "search.round"}
    assert rounds
    for e in solves:
        chain = set()
        p = e["parent"]
        while p is not None and p in by_id and p not in chain:
            if p in rounds:
                break
            chain.add(p)
            p = by_id[p]["parent"]
        assert p in rounds, f"worker solve {e['id']} not under a round"
        assert e["dur_ns"] is not None  # shipped spans arrive closed
    # and the whole thing exports to a valid Chrome document
    doc = trace.to_chrome()
    assert trace.validate_chrome(doc) == []
    block = bench_obs_block(1.0)
    assert block["unclosed"] == 0 and block["orphans"] == 0


def test_worker_registry_delta_merges_back():
    """The pool's generic registry-delta merge must surface worker-side
    floorplan solves in the parent's counters (the old bespoke
    merge_floorplan_counts path, now generic)."""
    graph = _chain_graph()
    search_until_converged(
        graph, u280_grid(), jobs=2,
        space=SearchSpace(utils=Interval(0.7, 1.0)),
        rounds=1, points_per_round=4, sim_firings=30, tol=0.0)
    assert floorplan_counts()["solved"] > 0
    stats = pool_task_stats()
    assert stats["ok"]["count"] == pool_counts()["merged"]
    assert stats["ok"]["sum"] > 0.0
    assert math.isfinite(stats["ok"]["mean"])
