"""Exactness and feasibility tests for the bipartition ILP engine."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.ilp import (BipartitionProblem, Edge, brute_force_bipartition,
                            check_feasible, solve_bipartition,
                            InfeasibleError)


def _random_problem(rng, n, n_edges, n_groups=1, cap_slack=1.5, with_k=False):
    areas = [{"LUT": float(rng.integers(1, 20))} for _ in range(n)]
    group = [int(rng.integers(0, n_groups)) for _ in range(n)]
    per_group = [sum(areas[i]["LUT"] for i in range(n) if group[i] == g)
                 for g in range(n_groups)]
    cap0 = [{"LUT": max(1.0, per_group[g] / 2 * cap_slack)} for g in range(n_groups)]
    cap1 = [{"LUT": max(1.0, per_group[g] / 2 * cap_slack)} for g in range(n_groups)]
    edges = []
    for _ in range(n_edges):
        u, v = rng.integers(0, n, size=2)
        if u == v:
            continue
        k = float(rng.integers(-2, 3)) if with_k else 0.0
        edges.append(Edge(u=int(u), v=int(v), w=float(rng.integers(1, 64)), k=k))
    return BipartitionProblem(areas=areas, group=group, cap0=cap0,
                              cap1=cap1, edges=edges)


@pytest.mark.parametrize("seed", range(12))
def test_bnb_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n=int(rng.integers(3, 11)),
                        n_edges=int(rng.integers(2, 16)),
                        n_groups=int(rng.integers(1, 3)),
                        with_k=(seed % 2 == 0))
    ref_assign, ref_cost = brute_force_bipartition(p)
    if ref_assign is None:
        with pytest.raises(InfeasibleError):
            solve_bipartition(p)
        return
    assign, cost, stats = solve_bipartition(p)
    assert stats["exact"]
    assert check_feasible(p, assign)
    assert cost == pytest.approx(ref_cost)


@pytest.mark.parametrize("seed", range(6))
def test_bnb_respects_pins(seed):
    rng = np.random.default_rng(100 + seed)
    p = _random_problem(rng, n=8, n_edges=10)
    p.pinned = {0: 1, 3: 0}
    ref_assign, ref_cost = brute_force_bipartition(p)
    if ref_assign is None:
        return
    assign, cost, _ = solve_bipartition(p)
    assert assign[0] == 1 and assign[3] == 0
    assert cost == pytest.approx(ref_cost)


def test_heuristic_on_large_instance_feasible():
    rng = np.random.default_rng(7)
    p = _random_problem(rng, n=300, n_edges=600, n_groups=4)
    assign, cost, stats = solve_bipartition(p, exact_threshold=0)
    assert check_feasible(p, assign)
    assert cost >= 0


def test_tight_capacity_forces_balance():
    # 4 equal tasks in a chain, capacity for exactly 2 per side:
    # optimal respects capacity even though cutting once is cheapest.
    p = BipartitionProblem(
        areas=[{"LUT": 10.0}] * 4, group=[0] * 4,
        cap0=[{"LUT": 20.0}], cap1=[{"LUT": 20.0}],
        edges=[Edge(0, 1, 5.0), Edge(1, 2, 5.0), Edge(2, 3, 5.0)])
    assign, cost, _ = solve_bipartition(p)
    assert sum(assign) == 2
    assert cost == pytest.approx(5.0)  # split a single chain edge


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 9), st.integers(0, 14), st.integers(0, 10_000))
def test_property_exactness(n, n_edges, seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n=n, n_edges=n_edges, cap_slack=2.0)
    ref_assign, ref_cost = brute_force_bipartition(p)
    assert ref_assign is not None  # slack 2.0 always feasible
    assign, cost, stats = solve_bipartition(p)
    assert check_feasible(p, assign)
    assert cost == pytest.approx(ref_cost)
