"""Paper Tables 4-7 cycle columns: dataflow-simulated execution cycles,
baseline vs TAPA-pipelined+balanced — throughput must be preserved
(delta = fill/drain skew only, mirroring the paper's +10 cycles /1e5).

Each design runs through the joint design-space searcher over a small util
grid with simulation deferred; ONE ``simulate_batch`` call then scores all
five designs' baselines + candidates together (mixed topologies vectorize
through the padded ragged-batch backend), and the reported plan is each
design's best Pareto-frontier candidate.

CLI:
    python benchmarks/throughput.py [--json PATH] [--firings N]
                                    [--backend auto|numpy|jax|event]
                                    [--store DIR] [--trace PATH]

``--store DIR`` routes every floorplan solve through a shared
content-addressed ``DiskFloorplanStore`` — a second run against the same
DIR is solve-free (all disk hits) and the JSON gains a ``sim.store``
block with the hit/write counters.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.analysis import reset_analysis_counts
from repro.core import (SearchSpace, prepare_design_space,
                        timed_pool_simulations)
from repro.fpga import benchmarks as B, u250_grid, u280_grid
from repro.obs import bench_obs_block, trace as obs_trace
from repro.search import DiskFloorplanStore, reset_store_counts, store_counts
from repro.search.store import store_lookup_stats

DEFAULT_FIRINGS = 300


def run(firings: int = DEFAULT_FIRINGS, json_path: str | None = None,
        backend: str = "auto", store: str | None = None,
        trace_path: str | None = None):
    reset_analysis_counts()
    reset_store_counts()
    obs_trace.enable(clear=True)
    t0 = time.monotonic()
    cache = DiskFloorplanStore(store) if store else None
    designs = [
        ("cnn_13x4", B.cnn(4), u250_grid()),
        ("gaussian_12", B.gaussian(12), u250_grid()),
        ("bucket_sort", B.bucket_sort(), u280_grid()),
        ("page_rank", B.page_rank(), u280_grid()),
        ("stencil_x4", B.stencil(4), u250_grid()),
    ]
    space = SearchSpace(utils=(0.70, 0.75, 0.80))
    with obs_trace.span("bench.suite", suite="throughput"):
        with obs_trace.span("bench.prepare"):
            preps = [(name, prepare_design_space(graph, grid, space=space,
                                                 floorplan_cache=cache))
                     for name, graph, grid in designs]

        # the suite's whole simulation phase: one padded cross-design batch
        _, sim_meta = timed_pool_simulations([prep for _, prep in preps],
                                             firings=firings, backend=backend)

        with obs_trace.span("bench.finish"):
            results = [(name, prep.finish(sim_calls=1))
                       for name, prep in preps]

    rows = []
    for name, res in results:
        cand = res.best
        assert not cand.sim.deadlocked, name
        assert cand.throughput_preserved, name
        row = {
            "name": name,
            "cycles_base": cand.base_sim.cycles,
            "cycles_tapa": cand.sim.cycles,
            "delta": cand.sim.cycles - cand.base_sim.cycles,
            "overhead_bits": cand.plan.area_overhead,
            "util": cand.point.max_util,
            "frontier": len(res.frontier),
            "backend_used": cand.sim.engine,
        }
        rows.append(row)
        print(f"throughput,{name},0,cycles_base={row['cycles_base']} "
              f"cycles_tapa={row['cycles_tapa']} "
              f"delta={row['delta']} "
              f"overhead_bits={row['overhead_bits']:.0f}")
    print(f"throughput,SIM,0,jobs={sim_meta['jobs']} "
          f"invocations={sim_meta['invocations']} "
          f"backends={'+'.join(sim_meta['backends'])} "
          f"wall={sim_meta['wall_s']:.3f}s")
    # always emit the store block — zeroed when no --store DIR was given,
    # so downstream tooling never has to special-case its absence
    store_block = dict(store_counts())
    store_block["enabled"] = cache is not None
    store_block["entries"] = cache.disk_entries() if cache is not None else 0
    store_block["lookup_s"] = store_lookup_stats()
    obs_block = bench_obs_block(time.monotonic() - t0, trace_path)
    sim_meta = dict(sim_meta, store=store_block, obs=obs_block)
    if store_block["enabled"]:
        st = store_block
        print(f"throughput,STORE,0,entries={st['entries']} "
              f"writes={st['writes']} disk_hits={st['disk_hits']} "
              f"quarantined={st['quarantined']}")
    print(f"throughput,OBS,0,spans={obs_block['spans']} "
          f"coverage={obs_block['stage_coverage']:.2f}"
          + (f" trace={obs_block['trace_file']}" if trace_path else ""))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "throughput", "firings": firings,
                       "backend": backend,
                       "rows": rows, "sim": sim_meta}, f, indent=2)
        print(f"throughput,JSON,0,wrote {json_path}")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write rows as JSON (BENCH_throughput.json)")
    ap.add_argument("--firings", type=int, default=DEFAULT_FIRINGS)
    ap.add_argument("--backend", choices=("auto", "numpy", "jax", "event"),
                    default="auto",
                    help="simulate_batch backend for the batched scoring")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persist floorplan solves to a DiskFloorplanStore "
                         "at DIR (re-runs become solve-free)")
    ap.add_argument("--trace", dest="trace_path", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON profile "
                         "of the run to PATH")
    args = ap.parse_args()
    if args.firings <= 0:
        ap.error("--firings must be positive (the cycle columns ARE the "
                 "benchmark; use fmax_suite.py --no-sim for a sim-free run)")
    run(firings=args.firings, json_path=args.json_path,
        backend=args.backend, store=args.store,
        trace_path=args.trace_path)


if __name__ == "__main__":
    main()
