"""Paper Tables 4-7 cycle columns: dataflow-simulated execution cycles,
baseline vs TAPA-pipelined+balanced — throughput must be preserved
(delta = fill/drain skew only, mirroring the paper's +10 cycles /1e5).

Each design's (baseline, optimized) pair runs as one ``simulate_batch``
call: the two variants share the topology, so the simulator vectorizes
them across variants instead of looping cycles twice in Python."""
from __future__ import annotations

from repro.core import autobridge
from repro.fpga import benchmarks as B, u250_grid, u280_grid


def main():
    designs = [
        ("cnn_13x4", B.cnn(4), u250_grid()),
        ("gaussian_12", B.gaussian(12), u250_grid()),
        ("bucket_sort", B.bucket_sort(), u280_grid()),
        ("page_rank", B.page_rank(), u280_grid()),
        ("stencil_x4", B.stencil(4), u250_grid()),
    ]
    for name, graph, grid in designs:
        plan = autobridge(graph, grid, max_util=0.75)
        base, opt = plan.verify_throughput(firings=300)
        assert not opt.deadlocked, name
        print(f"throughput,{name},0,cycles_base={base.cycles} "
              f"cycles_tapa={opt.cycles} "
              f"delta={opt.cycles - base.cycles} "
              f"overhead_bits={plan.area_overhead:.0f}")


if __name__ == "__main__":
    main()
