"""§Roofline: three-term roofline per (arch x shape x mesh x mode) from the
dry-run artifacts (artifacts/dryrun_unroll preferred, _scan as fallback
with a loop-undercount warning).

  compute    = HLO_FLOPs_per_chip / peak        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / bw          (819 GB/s HBM)
  collective = ici_bytes/chip / 50 GB/s  +  dcn_bytes/chip / 12.5 GB/s

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) tokens-processed model
flops; usefulness = MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.distributed.taskgraph import SHAPES

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCN = 12.5e9


def model_flops(arch: str, shape: str, train: bool) -> float:
    cfg = configs.get(arch)
    cell = SHAPES[shape]
    n = cfg.active_param_count()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    mult = 3.0 if train else 1.0          # fwd + bwd(2x); serve fwd only
    return 2.0 * n * tokens * mult


def load_records():
    recs = {}
    for d in ("artifacts/dryrun_scan", "artifacts/dryrun_unroll"):
        for fn in glob.glob(os.path.join(d, "*.json")):
            with open(fn) as f:
                r = json.load(f)
            key = (r["arch"], r["shape"], r["mesh"], r["mode"])
            if key not in recs or r.get("unroll"):
                recs[key] = r
    return recs


def main():
    recs = load_records()
    if not recs:
        print("roofline,NO_ARTIFACTS,0,run repro.launch.dryrun first")
        return
    rows = []
    for (arch, shape, mesh, mode), r in sorted(recs.items()):
        chips = r["chips"]
        t_comp = r["flops"] / chips / PEAK if r.get("unroll") else \
            model_flops(arch, shape, shape.startswith("train")) \
            * 1.5 / chips / PEAK
        t_mem = r["bytes_accessed"] / chips / HBM
        c = r["collectives"]
        t_coll = (c["ici_bytes"] / chips / ICI
                  + c["dcn_bytes"] / chips / DCN)
        dom = max((t_comp, "compute"), (t_mem, "memory"),
                  (t_coll, "collective"))[1]
        mf = model_flops(arch, shape, shape.startswith("train"))
        useful = mf / r["flops"] if r.get("unroll") and r["flops"] else \
            float("nan")
        frac = t_comp / max(t_comp, t_mem, t_coll)
        rows.append(dict(arch=arch, shape=shape, mesh=mesh, mode=mode,
                         t_comp=t_comp, t_mem=t_mem, t_coll=t_coll,
                         dom=dom, useful=useful, frac=frac,
                         unrolled=bool(r.get("unroll"))))
        print(f"roofline,{arch}|{shape}|{mesh}|{mode},0,"
              f"comp={t_comp*1e3:.2f}ms mem={t_mem*1e3:.2f}ms "
              f"coll={t_coll*1e3:.2f}ms dom={dom} "
              f"useful={useful:.2f} roofline_frac={frac:.2f} "
              f"{'unrolled' if r.get('unroll') else 'scan(est)'}")
    return rows


if __name__ == "__main__":
    main()
