"""Paper §7.6 / Table 11: floorplanner wall time vs design size (CNN
family; per-iteration ILP times + latency-balancing time)."""
from __future__ import annotations

import time

from repro.core import floorplan, assign_pipelining, balance_graph
from repro.fpga import benchmarks as B, u250_grid


def main():
    for n in (2, 4, 6, 8, 10, 12, 14, 16):
        graph = B.cnn(n)
        grid = u250_grid()
        t0 = time.monotonic()
        fp = floorplan(graph, grid, max_util=0.75)
        t_fp = time.monotonic() - t0
        pa = assign_pipelining(graph, fp)
        t0 = time.monotonic()
        balance_graph(graph, pa.lat)
        t_bal = time.monotonic() - t0
        iters = " ".join(f"div{i+1}={s['wall_s']:.2f}s"
                         for i, s in enumerate(fp.iteration_stats))
        print(f"scalability,cnn_13x{n},{t_fp*1e6:.0f},"
              f"V={graph.num_tasks} E={graph.num_streams} {iters} "
              f"rebalance={t_bal:.3f}s")


if __name__ == "__main__":
    main()
