"""Paper §7.3: the 43-design frequency study (headline table).

For every design: baseline = packed placement, no pipelining (the default
tool flow); TAPA = autobridge co-optimization (floorplan + pipeline +
balance), sweeping max-util upward if the default 0.70 is infeasible
(paper §6.3's knob).  Frequencies come from the calibrated physical-design
surrogate; throughput (cycle) preservation is checked by dataflow
simulation on a subset (see throughput.py for the full table).

Paper targets: baseline avg 147 MHz (failures counted as 0), optimized avg
297 MHz; 16/43 baseline failures, all recovered (avg 274 MHz).
"""
from __future__ import annotations

import time

from repro.core import (InfeasibleError, analyze_timing, autobridge,
                        packed_placement)
from repro.fpga import benchmarks as B, u250_grid, u280_grid

UTIL_SWEEP = (0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.0)


def grid_for(board: str):
    return u250_grid() if board == "u250" else u280_grid()


def run_tapa(graph, grid, seed: int = 0):
    """autobridge with the §6.3 util sweep; returns (plan, util)."""
    last = None
    for u in UTIL_SWEEP:
        try:
            return autobridge(graph, grid, max_util=u, seed=seed), u
        except InfeasibleError as e:
            last = e
    raise last


def evaluate(name: str, board: str, graph, sim_firings: int | None = None):
    grid = grid_for(board)
    base_pl = packed_placement(graph, grid)
    base = analyze_timing(graph, grid, base_pl)
    t0 = time.monotonic()
    try:
        plan, util = run_tapa(graph, grid)
        opt = analyze_timing(graph, grid, plan.floorplan.placement, plan.depth)
        wall = time.monotonic() - t0
        overhead = plan.area_overhead
    except InfeasibleError as e:
        plan, util, wall, overhead = None, None, time.monotonic() - t0, 0.0
        opt = analyze_timing(graph, grid, base_pl)  # placeholder, marked fail
        opt.routed, opt.fmax_mhz, opt.fail_reason = False, 0.0, str(e)
    row = {
        "name": name, "board": board,
        "tasks": graph.num_tasks, "streams": graph.num_streams,
        "base_mhz": base.fmax_mhz if base.routed else 0.0,
        "base_fail": None if base.routed else base.fail_reason,
        "opt_mhz": opt.fmax_mhz if opt.routed else 0.0,
        "opt_fail": None if opt.routed else opt.fail_reason,
        "util": util, "wall_s": wall,
        "buffer_overhead_bits": overhead,
    }
    if sim_firings and plan is not None:
        # throughput preservation by dataflow simulation (paper Tables 4-7):
        # base and optimized variants run as one batched, vectorized call.
        sim_base, sim_opt = plan.verify_throughput(firings=sim_firings)
        row["cycles_base"] = sim_base.cycles
        row["cycles_opt"] = sim_opt.cycles
        row["cycles_delta"] = sim_opt.cycles - sim_base.cycles
        row["sim_deadlock"] = sim_opt.deadlocked
    return row


def main(verbose: bool = True, sim_firings: int | None = None) -> list[dict]:
    rows = []
    for name, board, graph in B.autobridge_suite():
        r = evaluate(name, board, graph, sim_firings=sim_firings)
        rows.append(r)
        if verbose:
            base = f"{r['base_mhz']:.0f}" if not r["base_fail"] else "FAIL"
            opt = f"{r['opt_mhz']:.0f}" if not r["opt_fail"] else "FAIL"
            cyc = (f" cycles_delta={r['cycles_delta']}"
                   if "cycles_delta" in r else "")
            print(f"fmax_suite,{r['name']}@{r['board']},{r['wall_s']*1e6:.0f},"
                  f"base={base}MHz opt={opt}MHz util={r['util']}{cyc}")
    n = len(rows)
    base_avg = sum(r["base_mhz"] for r in rows) / n
    opt_avg = sum(r["opt_mhz"] for r in rows) / n
    fails = [r for r in rows if r["base_fail"]]
    recovered = [r for r in fails if not r["opt_fail"]]
    rec_avg = (sum(r["opt_mhz"] for r in recovered) / len(recovered)
               if recovered else 0.0)
    routable = [r for r in rows if not r["base_fail"]]
    print(f"fmax_suite,SUMMARY,0,designs={n} base_avg={base_avg:.0f}MHz "
          f"(paper 147) opt_avg={opt_avg:.0f}MHz (paper 297) "
          f"baseline_fails={len(fails)} (paper 16) "
          f"recovered={len(recovered)} recovered_avg={rec_avg:.0f}MHz "
          f"(paper 274) routable_base_avg="
          f"{sum(r['base_mhz'] for r in routable)/max(len(routable),1):.0f}MHz"
          f" (paper 234)")
    return rows


if __name__ == "__main__":
    main()
