"""Paper §7.3: the 43-design frequency study (headline table).

For every design: baseline = packed placement, no pipelining (the default
tool flow); TAPA = the §6.3 joint design-space search over the max-util
sweep (``explore_design_space`` — all knob points evaluated, Pareto-pruned,
best frontier candidate kept), replacing the old first-feasible retry loop.
Frequencies come from the calibrated physical-design surrogate; throughput
(cycle) preservation is checked by dataflow simulation on *every* run —
each design's baseline + all candidates share one vectorized
``simulate_batch`` call.

Paper targets: baseline avg 147 MHz (failures counted as 0), optimized avg
297 MHz; 16/43 baseline failures, all recovered (avg 274 MHz).

CLI:
    python benchmarks/fmax_suite.py [--subset fast|full] [--json PATH]
                                    [--firings N] [--no-sim]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (InfeasibleError, SearchSpace, analyze_timing,
                        explore_design_space, packed_placement)
from repro.fpga import benchmarks as B, u250_grid, u280_grid

UTIL_SWEEP = (0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.0)

#: small, quick designs exercised by the CI bench-regression gate; the full
#: 43-design table runs nightly.
FAST_SUBSET = ("stencil_x2", "stencil_x4", "cnn_13x2", "gaussian_12",
               "bucket_sort", "page_rank")

#: throughput-preservation firings used by the default path (satisfies the
#: ROADMAP item: cycles are checked on every run, not a spot-check subset)
DEFAULT_FIRINGS = 200


def grid_for(board: str):
    return u250_grid() if board == "u250" else u280_grid()


def run_tapa(graph, grid, seed: int = 0, *, sim_firings: int | None = None):
    """§6.3 knob search as a joint batched sweep: every util point is
    evaluated ("implement all candidates in parallel"), throughput-scored in
    one ``simulate_batch`` call, and the best Pareto-frontier candidate is
    returned along with the full ``SearchResult``.

    Raises ``InfeasibleError`` when no point yields a routable plan."""
    space = SearchSpace(seeds=(seed,), utils=UTIL_SWEEP)
    res = explore_design_space(graph, grid, space=space,
                               sim_firings=sim_firings)
    return res.best, res


def evaluate(name: str, board: str, graph,
             sim_firings: int | None = DEFAULT_FIRINGS):
    grid = grid_for(board)
    base_pl = packed_placement(graph, grid)
    base = analyze_timing(graph, grid, base_pl)
    t0 = time.monotonic()
    cand = None
    try:
        cand, search = run_tapa(graph, grid, sim_firings=sim_firings)
        plan, util, opt = cand.plan, cand.point.max_util, cand.report
        wall = time.monotonic() - t0
        overhead = plan.area_overhead
        frontier = len(search.frontier)
    except InfeasibleError as e:
        util, wall, overhead, frontier = None, time.monotonic() - t0, 0.0, 0
        opt = analyze_timing(graph, grid, base_pl)  # placeholder, marked fail
        opt.routed, opt.fmax_mhz, opt.fail_reason = False, 0.0, str(e)
    row = {
        "name": name, "board": board,
        "tasks": graph.num_tasks, "streams": graph.num_streams,
        "base_mhz": base.fmax_mhz if base.routed else 0.0,
        "base_fail": None if base.routed else base.fail_reason,
        "opt_mhz": opt.fmax_mhz if opt.routed else 0.0,
        "opt_fail": None if opt.routed else opt.fail_reason,
        "util": util, "wall_s": wall,
        "buffer_overhead_bits": overhead,
        "frontier": frontier,
    }
    if sim_firings and cand is not None and cand.sim is not None:
        # throughput preservation by dataflow simulation (paper Tables 4-7):
        # scored for every candidate inside the search's batched call.
        row["cycles_base"] = cand.base_sim.cycles
        row["cycles_opt"] = cand.sim.cycles
        row["cycles_delta"] = cand.sim.cycles - cand.base_sim.cycles
        row["sim_deadlock"] = cand.sim.deadlocked
        row["throughput_preserved"] = cand.throughput_preserved
    return row


def summarize(rows: list[dict]) -> dict:
    n = len(rows)
    fails = [r for r in rows if r["base_fail"]]
    recovered = [r for r in fails if not r["opt_fail"]]
    routable = [r for r in rows if not r["base_fail"]]
    return {
        "designs": n,
        "base_avg_mhz": sum(r["base_mhz"] for r in rows) / n,
        "opt_avg_mhz": sum(r["opt_mhz"] for r in rows) / n,
        "baseline_fails": len(fails),
        "recovered": len(recovered),
        "recovered_avg_mhz": (sum(r["opt_mhz"] for r in recovered)
                              / len(recovered) if recovered else 0.0),
        "routable_base_avg_mhz": (sum(r["base_mhz"] for r in routable)
                                  / max(len(routable), 1)),
        "sim_deadlocks": sum(1 for r in rows if r.get("sim_deadlock")),
        "throughput_violations": sum(
            1 for r in rows if r.get("throughput_preserved") is False),
        "cycles_delta_total": sum(r.get("cycles_delta", 0) for r in rows),
    }


def main(verbose: bool = True, sim_firings: int | None = DEFAULT_FIRINGS,
         subset: tuple[str, ...] | None = None,
         json_path: str | None = None) -> list[dict]:
    rows = []
    for name, board, graph in B.autobridge_suite():
        if subset is not None and name not in subset:
            continue
        r = evaluate(name, board, graph, sim_firings=sim_firings)
        rows.append(r)
        if verbose:
            base = f"{r['base_mhz']:.0f}" if not r["base_fail"] else "FAIL"
            opt = f"{r['opt_mhz']:.0f}" if not r["opt_fail"] else "FAIL"
            cyc = (f" cycles_delta={r['cycles_delta']}"
                   if "cycles_delta" in r else "")
            print(f"fmax_suite,{r['name']}@{r['board']},{r['wall_s']*1e6:.0f},"
                  f"base={base}MHz opt={opt}MHz util={r['util']}{cyc}")
    s = summarize(rows)
    print(f"fmax_suite,SUMMARY,0,designs={s['designs']} "
          f"base_avg={s['base_avg_mhz']:.0f}MHz (paper 147) "
          f"opt_avg={s['opt_avg_mhz']:.0f}MHz (paper 297) "
          f"baseline_fails={s['baseline_fails']} (paper 16) "
          f"recovered={s['recovered']} "
          f"recovered_avg={s['recovered_avg_mhz']:.0f}MHz (paper 274) "
          f"routable_base_avg={s['routable_base_avg_mhz']:.0f}MHz (paper 234) "
          f"deadlocks={s['sim_deadlocks']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "fmax_suite", "sim_firings": sim_firings,
                       "subset": sorted(subset) if subset else None,
                       "rows": rows, "summary": s}, f, indent=2)
        print(f"fmax_suite,JSON,0,wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--subset", choices=("fast", "full"), default="full",
                    help="fast = CI bench-regression subset; full = all 43")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write rows + summary as JSON (BENCH_fmax.json)")
    ap.add_argument("--firings", type=int, default=DEFAULT_FIRINGS,
                    help="throughput-sim firings per task (0 disables)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip throughput simulation entirely")
    args = ap.parse_args()
    main(sim_firings=None if args.no_sim else (args.firings or None),
         subset=FAST_SUBSET if args.subset == "fast" else None,
         json_path=args.json_path)
