"""Paper §7.3: the 43-design frequency study (headline table).

For every design: baseline = packed placement, no pipelining (the default
tool flow); TAPA = the §6.3 joint design-space search over the max-util
sweep (all knob points evaluated, Pareto-pruned, best frontier candidate
kept), replacing the old first-feasible retry loop.  Frequencies come from
the calibrated physical-design surrogate; throughput (cycle) preservation
is checked by dataflow simulation on *every* run.

Cross-design batching: the search phase defers simulation
(``prepare_design_space``), and then ONE ``simulate_batch`` call scores
every design's baseline + all candidates for the whole suite — the padded
ragged-batch backend vectorizes across the heterogeneous topologies, so
the suite's simulation phase is a single array-sweep instead of one
Python-level engine run per design.  The JSON summary records the engine
invocation counters, backends used and simulation wall-time so CI can
verify the fast subset never degrades to per-job event simulation.

Paper targets: baseline avg 147 MHz (failures counted as 0), optimized avg
297 MHz; 16/43 baseline failures, all recovered (avg 274 MHz).

CLI:
    python benchmarks/fmax_suite.py [--subset fast|full] [--json PATH]
                                    [--firings N] [--no-sim]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (InfeasibleError, SearchSpace, analyze_timing,
                        packed_placement, prepare_design_space,
                        timed_pool_simulations)
from repro.fpga import benchmarks as B, grid_for

UTIL_SWEEP = (0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.0)

#: small, quick designs exercised by the CI bench-regression gate; the full
#: 43-design table runs nightly.
FAST_SUBSET = ("stencil_x2", "stencil_x4", "cnn_13x2", "gaussian_12",
               "bucket_sort", "page_rank")

#: throughput-preservation firings used by the default path (satisfies the
#: ROADMAP item: cycles are checked on every run, not a spot-check subset)
DEFAULT_FIRINGS = 200


def prepare(name: str, board: str, graph) -> dict:
    """Baseline timing + deferred candidate search for one design (no
    simulation yet — that happens once for the whole suite)."""
    grid = grid_for(board)
    base_pl = packed_placement(graph, grid)
    base = analyze_timing(graph, grid, base_pl)
    t0 = time.monotonic()
    prep = prepare_design_space(graph, grid,
                                space=SearchSpace(seeds=(0,),
                                                  utils=UTIL_SWEEP))
    wall = time.monotonic() - t0
    return {"name": name, "board": board, "graph": graph, "grid": grid,
            "base_pl": base_pl, "base": base, "prep": prep, "wall_s": wall}


def score_all(entries: list[dict], sim_firings: int | None) -> dict | None:
    """The suite's entire simulation phase: one ``simulate_batch`` call
    over every design's baseline + feasible candidates (mixed topologies
    vectorize through the padded backend).  Returns the recorded metadata
    (engine counters, backends, wall time) or None when sim is disabled."""
    if not sim_firings:
        return None
    _, meta = timed_pool_simulations([e["prep"] for e in entries],
                                     firings=sim_firings)
    return meta


def finish(entry: dict, sim_firings: int | None) -> dict:
    """Frontier + row assembly for one prepared (and batch-scored) design."""
    graph, base = entry["graph"], entry["base"]
    res = entry["prep"].finish(sim_calls=1 if sim_firings else 0)
    cand = None
    try:
        cand = res.best
        util, opt = cand.point.max_util, cand.report
        overhead = cand.plan.area_overhead
        frontier = len(res.frontier)
    except InfeasibleError as e:
        util, overhead, frontier = None, 0.0, 0
        opt = analyze_timing(graph, entry["grid"], entry["base_pl"])
        opt.routed, opt.fmax_mhz, opt.fail_reason = False, 0.0, str(e)
    row = {
        "name": entry["name"], "board": entry["board"],
        "tasks": graph.num_tasks, "streams": graph.num_streams,
        "base_mhz": base.fmax_mhz if base.routed else 0.0,
        "base_fail": None if base.routed else base.fail_reason,
        "opt_mhz": opt.fmax_mhz if opt.routed else 0.0,
        "opt_fail": None if opt.routed else opt.fail_reason,
        "util": util, "wall_s": entry["wall_s"],
        "buffer_overhead_bits": overhead,
        "frontier": frontier,
    }
    if sim_firings and cand is not None and cand.sim is not None:
        # throughput preservation by dataflow simulation (paper Tables 4-7):
        # scored for every candidate inside the suite-wide batched call.
        row["cycles_base"] = cand.base_sim.cycles
        row["cycles_opt"] = cand.sim.cycles
        row["cycles_delta"] = cand.sim.cycles - cand.base_sim.cycles
        row["sim_deadlock"] = cand.sim.deadlocked
        row["throughput_preserved"] = cand.throughput_preserved
        row["backend_used"] = cand.sim.engine
    return row


def summarize(rows: list[dict]) -> dict:
    n = len(rows)
    fails = [r for r in rows if r["base_fail"]]
    recovered = [r for r in fails if not r["opt_fail"]]
    routable = [r for r in rows if not r["base_fail"]]
    return {
        "designs": n,
        "base_avg_mhz": sum(r["base_mhz"] for r in rows) / n,
        "opt_avg_mhz": sum(r["opt_mhz"] for r in rows) / n,
        "baseline_fails": len(fails),
        "recovered": len(recovered),
        "recovered_avg_mhz": (sum(r["opt_mhz"] for r in recovered)
                              / len(recovered) if recovered else 0.0),
        "routable_base_avg_mhz": (sum(r["base_mhz"] for r in routable)
                                  / max(len(routable), 1)),
        "sim_deadlocks": sum(1 for r in rows if r.get("sim_deadlock")),
        "throughput_violations": sum(
            1 for r in rows if r.get("throughput_preserved") is False),
        "cycles_delta_total": sum(r.get("cycles_delta", 0) for r in rows),
    }


def main(verbose: bool = True, sim_firings: int | None = DEFAULT_FIRINGS,
         subset: tuple[str, ...] | None = None,
         json_path: str | None = None) -> list[dict]:
    entries = [prepare(name, board, graph)
               for name, board, graph in B.autobridge_suite()
               if subset is None or name in subset]
    sim_meta = score_all(entries, sim_firings)
    rows = []
    for entry in entries:
        r = finish(entry, sim_firings)
        rows.append(r)
        if verbose:
            base = f"{r['base_mhz']:.0f}" if not r["base_fail"] else "FAIL"
            opt = f"{r['opt_mhz']:.0f}" if not r["opt_fail"] else "FAIL"
            cyc = (f" cycles_delta={r['cycles_delta']}"
                   if "cycles_delta" in r else "")
            print(f"fmax_suite,{r['name']}@{r['board']},{r['wall_s']*1e6:.0f},"
                  f"base={base}MHz opt={opt}MHz util={r['util']}{cyc}")
    s = summarize(rows)
    print(f"fmax_suite,SUMMARY,0,designs={s['designs']} "
          f"base_avg={s['base_avg_mhz']:.0f}MHz (paper 147) "
          f"opt_avg={s['opt_avg_mhz']:.0f}MHz (paper 297) "
          f"baseline_fails={s['baseline_fails']} (paper 16) "
          f"recovered={s['recovered']} "
          f"recovered_avg={s['recovered_avg_mhz']:.0f}MHz (paper 274) "
          f"routable_base_avg={s['routable_base_avg_mhz']:.0f}MHz (paper 234) "
          f"deadlocks={s['sim_deadlocks']}")
    if sim_meta:
        print(f"fmax_suite,SIM,0,jobs={sim_meta['jobs']} "
              f"invocations={sim_meta['invocations']} "
              f"backends={'+'.join(sim_meta['backends'])} "
              f"wall={sim_meta['wall_s']:.3f}s")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "fmax_suite", "sim_firings": sim_firings,
                       "subset": sorted(subset) if subset else None,
                       "rows": rows, "summary": s, "sim": sim_meta},
                      f, indent=2)
        print(f"fmax_suite,JSON,0,wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--subset", choices=("fast", "full"), default="full",
                    help="fast = CI bench-regression subset; full = all 43")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write rows + summary as JSON (BENCH_fmax.json)")
    ap.add_argument("--firings", type=int, default=DEFAULT_FIRINGS,
                    help="throughput-sim firings per task (0 disables)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip throughput simulation entirely")
    args = ap.parse_args()
    main(sim_firings=None if args.no_sim else (args.firings or None),
         subset=FAST_SUBSET if args.subset == "fast" else None,
         json_path=args.json_path)
