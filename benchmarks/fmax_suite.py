"""Paper §7.3: the 43-design frequency study (headline table).

For every design: baseline = packed placement, no pipelining (the default
tool flow); TAPA = the §6.3 joint design-space search over the max-util
sweep (all knob points evaluated, Pareto-pruned, best frontier candidate
kept), replacing the old first-feasible retry loop.  Frequencies come from
the calibrated physical-design surrogate; throughput (cycle) preservation
is checked by dataflow simulation on *every* run.

Cross-design batching: the search phase defers simulation
(``prepare_design_space``), and then ONE ``simulate_batch`` call scores
every design's baseline + all candidates for the whole suite — the padded
ragged-batch backend vectorizes across the heterogeneous topologies, so
the suite's simulation phase is a single array-sweep instead of one
Python-level engine run per design.  The JSON summary records the engine
invocation counters, backends used and simulation wall-time so CI can
verify the fast subset never degrades to per-job event simulation.

Paper targets: baseline avg 147 MHz (failures counted as 0), optimized avg
297 MHz; 16/43 baseline failures, all recovered (avg 274 MHz).

Converged mode (``--converge``): every design instead runs
``search_until_converged`` over a *continuous* util range anchored on the
discrete UTIL_SWEEP grid — refine rounds re-anchor on the incumbent Pareto
frontier, all rounds share one ``FloorplanCache`` and the round-1 baseline
simulation, and the JSON ``sim`` block records ``floorplan`` solve/cache-hit
counters plus ``points_evaluated`` so the CI gate can *prove* the
memoization fired (cache hits > 0, solves < points).  Because the anchors
are exactly the default path's sweep, a converged run's frontier can never
score below the non-converged baseline JSON it is gated against.

``--jobs N`` fans each round's cold ILP solves over the
``repro.search.pool`` worker pool: results are bit-identical to ``--jobs
1`` (the CI gate compares a ``--jobs 2`` run's rows against the fresh
sequential converged JSON and requires exact frontier identity), the
search wall time drops with cores, and the ``sim.pool`` block records the
worker dispatch/merge counters plus the parent-side merged floorplan
counts.  ``--proposer surrogate`` switches the round proposals to the
response-surface model (``repro.search.surrogate``).

``--backend`` pins the ``simulate_batch`` backend for the suite's
simulation phase (default ``auto``: the jax-jitted sweep when jax is
importable, the NumPy sweep otherwise).  A ``--backend jax`` run records
the jitted sweep's compile-cache counters (``sim.jit_cache``) and a
*measured* NumPy-vs-jax ``sim.speedup`` block — the CI jax leg gates that
run row-exact against a fresh NumPy JSON (``check_jax_backend``).

Crash-safety (``docs/robustness-guide.md``): ``--store DIR`` keeps the
converged run's floorplan solves in a content-addressed
``DiskFloorplanStore`` shared across designs and *runs* (the JSON
``sim.store`` block records writes/hits/quarantined-entry counts), and
``--checkpoint DIR`` journals each design's search per round so a killed
suite resumes from the last completed round with bit-identical rows —
the chaos CI job (``benchmarks/chaos_suite.py``) SIGKILLs a run mid-suite
under seeded fault injection and gates the resumed rows against a clean
run.  The ``sim.faults`` block records injected-vs-observed fault counts
(all zero on a clean run).

CLI:
    python benchmarks/fmax_suite.py [--subset fast|full] [--json PATH]
                                    [--firings N] [--no-sim] [--converge]
                                    [--jobs N] [--proposer uniform|surrogate]
                                    [--backend auto|numpy|jax|event]
                                    [--store DIR] [--checkpoint DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.analysis import analysis_counts, reset_analysis_counts
from repro.core import (FloorplanCache, InfeasibleError, Interval,
                        SearchPoint, SearchSpace, analyze_timing,
                        engine_counts, floorplan_counts, packed_placement,
                        prepare_design_space, reset_engine_counts,
                        reset_floorplan_counts, search_until_converged,
                        timed_pool_simulations)
from repro.fpga import benchmarks as B, grid_for
from repro.obs import bench_obs_block, trace as obs_trace
from repro.search import (DiskFloorplanStore, fault_counts, pool_counts,
                          reset_fault_counts, reset_pool_counts,
                          reset_store_counts, store_counts)
from repro.search.faults import active_plan
from repro.search.pool import pool_task_stats
from repro.search.store import store_lookup_stats

UTIL_SWEEP = (0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.0)

#: small, quick designs exercised by the CI bench-regression gate; the full
#: 43-design table runs nightly.
FAST_SUBSET = ("stencil_x2", "stencil_x4", "cnn_13x2", "gaussian_12",
               "bucket_sort", "page_rank")

#: throughput-preservation firings used by the default path (satisfies the
#: ROADMAP item: cycles are checked on every run, not a spot-check subset)
DEFAULT_FIRINGS = 200

#: converged-mode budget: refine rounds per design and configurations per
#: round (round 1 = the UTIL_SWEEP anchors + random draws from the
#: continuous range; later rounds = frontier anchors + refined draws)
CONVERGE_ROUNDS = 3
CONVERGE_POINTS = 12


def prepare(name: str, board: str, graph) -> dict:
    """Baseline timing + deferred candidate search for one design (no
    simulation yet — that happens once for the whole suite)."""
    grid = grid_for(board)
    base_pl = packed_placement(graph, grid)
    base = analyze_timing(graph, grid, base_pl)
    t0 = time.monotonic()
    prep = prepare_design_space(graph, grid,
                                space=SearchSpace(seeds=(0,),
                                                  utils=UTIL_SWEEP))
    wall = time.monotonic() - t0
    return {"name": name, "board": board, "graph": graph, "grid": grid,
            "base_pl": base_pl, "base": base, "prep": prep, "wall_s": wall}


def score_all(entries: list[dict], sim_firings: int | None,
              backend: str = "auto") -> dict | None:
    """The suite's entire simulation phase: one ``simulate_batch`` call
    over every design's baseline + feasible candidates (mixed topologies
    vectorize through the padded backend).  Returns the recorded metadata
    (engine counters, backends, wall time; plus the jit compile-cache and
    the measured NumPy-vs-jax speedup for ``backend="jax"`` runs) or None
    when sim is disabled."""
    if not sim_firings:
        return None
    _, meta = timed_pool_simulations([e["prep"] for e in entries],
                                     firings=sim_firings, backend=backend,
                                     measure_speedup=(backend == "jax"))
    return meta


def assemble_row(name: str, board: str, graph, grid, base_pl, base, res, *,
                 wall: float, sim_firings: int | None) -> dict:
    """Best-candidate resolution (with the unroutable fallback) plus the
    row schema shared by the default and converged paths.  One definition
    on purpose: ``check_regression`` compares both paths' rows against the
    same committed baseline, so the schemas must never drift.

    ``res`` is anything with ``.best`` (raising ``InfeasibleError`` when no
    candidate routes) and ``.frontier`` — a ``SearchResult`` or a
    ``ConvergedSearch``."""
    cand = None
    try:
        cand = res.best
        util, opt = cand.point.max_util, cand.report
        overhead = cand.plan.area_overhead
    except InfeasibleError as e:
        util, overhead = None, 0.0
        opt = analyze_timing(graph, grid, base_pl)
        opt.routed, opt.fmax_mhz, opt.fail_reason = False, 0.0, str(e)
    row = {
        "name": name, "board": board,
        "tasks": graph.num_tasks, "streams": graph.num_streams,
        "base_mhz": base.fmax_mhz if base.routed else 0.0,
        "base_fail": None if base.routed else base.fail_reason,
        "opt_mhz": opt.fmax_mhz if opt.routed else 0.0,
        "opt_fail": None if opt.routed else opt.fail_reason,
        "util": util, "wall_s": wall,
        "buffer_overhead_bits": overhead,
        "frontier": len(res.frontier),
    }
    if sim_firings and cand is not None and cand.sim is not None:
        # throughput preservation by dataflow simulation (paper Tables 4-7):
        # scored for every candidate inside the batched call(s).
        row["cycles_base"] = cand.base_sim.cycles
        row["cycles_opt"] = cand.sim.cycles
        row["cycles_delta"] = cand.sim.cycles - cand.base_sim.cycles
        row["sim_deadlock"] = cand.sim.deadlocked
        row["throughput_preserved"] = cand.throughput_preserved
        row["backend_used"] = cand.sim.engine
    return row


def finish(entry: dict, sim_firings: int | None) -> dict:
    """Frontier + row assembly for one prepared (and batch-scored) design."""
    res = entry["prep"].finish(sim_calls=1 if sim_firings else 0)
    return assemble_row(entry["name"], entry["board"], entry["graph"],
                        entry["grid"], entry["base_pl"], entry["base"], res,
                        wall=entry["wall_s"], sim_firings=sim_firings)


def run_converged(name: str, board: str, graph, *, sim_firings: int | None,
                  cache: FloorplanCache, jobs: int = 1,
                  proposer: str = "uniform",
                  backend: str = "auto",
                  checkpoint: str | None = None) -> dict:
    """One design through ``search_until_converged``: continuous util range
    anchored on the discrete UTIL_SWEEP grid, shared floorplan cache.
    ``jobs`` fans the cold ILP solves over the worker pool (bit-identical
    rows, less wall time); ``proposer`` selects the round-proposal model;
    ``checkpoint`` journals the search per round for kill-resume."""
    grid = grid_for(board)
    base_pl = packed_placement(graph, grid)
    base = analyze_timing(graph, grid, base_pl)
    anchors = [SearchPoint(seed=0, max_util=u) for u in UTIL_SWEEP]
    t0 = time.monotonic()
    res = search_until_converged(
        graph, grid,
        space=SearchSpace(utils=Interval(UTIL_SWEEP[0], UTIL_SWEEP[-1])),
        rounds=CONVERGE_ROUNDS, points_per_round=CONVERGE_POINTS,
        sim_firings=sim_firings, initial_points=anchors, cache=cache,
        jobs=jobs, proposer=proposer, sim_backend=backend,
        checkpoint=checkpoint)
    row = assemble_row(name, board, graph, grid, base_pl, base, res,
                       wall=time.monotonic() - t0, sim_firings=sim_firings)
    row.update({
        "rounds_run": res.rounds_run,
        "converged": res.converged,
        "points_evaluated": res.points_evaluated,
        "hypervolume": res.hypervolumes[-1] if res.hypervolumes else 0.0,
        "proposer": res.proposer,
        "resumed_rounds": res.resumed_rounds,
    })
    return row


def summarize(rows: list[dict]) -> dict:
    n = len(rows)
    fails = [r for r in rows if r["base_fail"]]
    recovered = [r for r in fails if not r["opt_fail"]]
    routable = [r for r in rows if not r["base_fail"]]
    return {
        "designs": n,
        "base_avg_mhz": sum(r["base_mhz"] for r in rows) / n,
        "opt_avg_mhz": sum(r["opt_mhz"] for r in rows) / n,
        "baseline_fails": len(fails),
        "recovered": len(recovered),
        "recovered_avg_mhz": (sum(r["opt_mhz"] for r in recovered)
                              / len(recovered) if recovered else 0.0),
        "routable_base_avg_mhz": (sum(r["base_mhz"] for r in routable)
                                  / max(len(routable), 1)),
        "sim_deadlocks": sum(1 for r in rows if r.get("sim_deadlock")),
        "throughput_violations": sum(
            1 for r in rows if r.get("throughput_preserved") is False),
        "cycles_delta_total": sum(r.get("cycles_delta", 0) for r in rows),
    }


def main(verbose: bool = True, sim_firings: int | None = DEFAULT_FIRINGS,
         subset: tuple[str, ...] | None = None,
         json_path: str | None = None,
         backend: str = "auto",
         trace_path: str | None = None) -> list[dict]:
    reset_analysis_counts()
    obs_trace.enable(clear=True)
    t0 = time.monotonic()
    with obs_trace.span("bench.suite", suite="fmax"):
        with obs_trace.span("bench.prepare"):
            entries = [prepare(name, board, graph)
                       for name, board, graph in B.autobridge_suite()
                       if subset is None or name in subset]
        sim_meta = score_all(entries, sim_firings, backend)
        with obs_trace.span("bench.finish"):
            rows = [finish(entry, sim_firings) for entry in entries]
    if verbose:
        for r in rows:
            base = f"{r['base_mhz']:.0f}" if not r["base_fail"] else "FAIL"
            opt = f"{r['opt_mhz']:.0f}" if not r["opt_fail"] else "FAIL"
            cyc = (f" cycles_delta={r['cycles_delta']}"
                   if "cycles_delta" in r else "")
            print(f"fmax_suite,{r['name']}@{r['board']},{r['wall_s']*1e6:.0f},"
                  f"base={base}MHz opt={opt}MHz util={r['util']}{cyc}")
    obs_block = bench_obs_block(time.monotonic() - t0, trace_path)
    if sim_meta is not None:
        sim_meta["obs"] = obs_block
    print(f"fmax_suite,OBS,0,spans={obs_block['spans']} "
          f"coverage={obs_block['stage_coverage']:.2f}"
          + (f" trace={obs_block['trace_file']}" if trace_path else ""))
    s = summarize(rows)
    print(f"fmax_suite,SUMMARY,0,designs={s['designs']} "
          f"base_avg={s['base_avg_mhz']:.0f}MHz (paper 147) "
          f"opt_avg={s['opt_avg_mhz']:.0f}MHz (paper 297) "
          f"baseline_fails={s['baseline_fails']} (paper 16) "
          f"recovered={s['recovered']} "
          f"recovered_avg={s['recovered_avg_mhz']:.0f}MHz (paper 274) "
          f"routable_base_avg={s['routable_base_avg_mhz']:.0f}MHz (paper 234) "
          f"deadlocks={s['sim_deadlocks']}")
    if sim_meta:
        print(f"fmax_suite,SIM,0,jobs={sim_meta['jobs']} "
              f"invocations={sim_meta['invocations']} "
              f"backends={'+'.join(sim_meta['backends'])} "
              f"wall={sim_meta['wall_s']:.3f}s")
        if sim_meta.get("speedup"):
            sp = sim_meta["speedup"]
            print(f"fmax_suite,SPEEDUP,0,numpy={sp['numpy_wall_s']:.3f}s "
                  f"jax={sp['jax_wall_s']:.3f}s x{sp['speedup']:.1f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "fmax_suite", "sim_firings": sim_firings,
                       "subset": sorted(subset) if subset else None,
                       "backend": backend,
                       "rows": rows, "summary": s, "sim": sim_meta},
                      f, indent=2)
        print(f"fmax_suite,JSON,0,wrote {json_path}")
    return rows


def main_converged(verbose: bool = True,
                   sim_firings: int | None = DEFAULT_FIRINGS,
                   subset: tuple[str, ...] | None = None,
                   json_path: str | None = None,
                   jobs: int = 1,
                   proposer: str = "uniform",
                   backend: str = "auto",
                   store: str | None = None,
                   checkpoint: str | None = None,
                   trace_path: str | None = None) -> list[dict]:
    """The ``--converge`` path: per-design ``search_until_converged`` with a
    suite-wide ``FloorplanCache``; the JSON ``sim`` block carries the
    floorplan solve/cache-hit counters the CI gate checks, plus the
    ``pool`` worker dispatch/merge counters when ``jobs > 1`` (the
    parallel-run gate requires them and exact row identity vs the
    sequential run).  ``store`` swaps the suite cache for a
    ``DiskFloorplanStore`` (adds the ``sim.store`` block); ``checkpoint``
    journals each design's search under ``DIR/<name>@<board>`` so a killed
    suite run resumes — completed designs replay from their final
    checkpoint, the interrupted one continues from its last round."""
    reset_engine_counts()
    reset_floorplan_counts()
    reset_pool_counts()
    reset_analysis_counts()
    reset_store_counts()
    reset_fault_counts()
    obs_trace.enable(clear=True)
    cache = DiskFloorplanStore(store) if store else FloorplanCache()
    t0 = time.monotonic()
    rows = []
    with obs_trace.span("bench.suite", suite="fmax", mode="converged"):
        for name, board, graph in B.autobridge_suite():
            if subset is not None and name not in subset:
                continue
            ckpt = (os.path.join(checkpoint, f"{name}@{board}")
                    if checkpoint else None)
            with obs_trace.span("bench.design", design=f"{name}@{board}"):
                r = run_converged(name, board, graph,
                                  sim_firings=sim_firings,
                                  cache=cache, jobs=jobs, proposer=proposer,
                                  backend=backend, checkpoint=ckpt)
            rows.append(r)
            if verbose:
                base = (f"{r['base_mhz']:.0f}" if not r["base_fail"]
                        else "FAIL")
                opt = f"{r['opt_mhz']:.0f}" if not r["opt_fail"] else "FAIL"
                print(f"fmax_suite,{r['name']}@{r['board']},"
                      f"{r['wall_s']*1e6:.0f},"
                      f"base={base}MHz opt={opt}MHz util={r['util']} "
                      f"rounds={r['rounds_run']} converged={r['converged']} "
                      f"points={r['points_evaluated']}")
    obs_block = bench_obs_block(time.monotonic() - t0, trace_path)
    fp = floorplan_counts()
    pool = {"jobs": jobs, **pool_counts(), "task_s": pool_task_stats()}
    ana = analysis_counts()
    plan = active_plan()
    # always emitted — zeroed (enabled=False) when no --store was given —
    # so the store gate can never pass by silently not running
    store_block = dict(store_counts())
    store_block["enabled"] = isinstance(cache, DiskFloorplanStore)
    store_block["entries"] = (cache.disk_entries()
                              if store_block["enabled"] else 0)
    store_block["lookup_s"] = store_lookup_stats()
    faults_block = {
        "plan": plan.as_dict() if plan is not None else None,
        "injected": fault_counts(),
        "observed": {k: pool[k] for k in ("retried", "timed_out",
                                          "quarantined", "pool_rebuilds")}
        | {"store_quarantined": store_counts()["quarantined"],
           "merge_conflicts": fp["merge_conflicts"]},
    }
    from repro.kernels.sim_sweep import sweep_cache_stats
    sim_meta = {"firings": sim_firings, "mode": "converged",
                "counts": engine_counts(), "floorplan": fp,
                "cache": cache.stats(), "pool": pool,
                "analysis": ana, "jit_cache": sweep_cache_stats(),
                "store": store_block, "faults": faults_block,
                "proposer": proposer, "backend": backend,
                "points_evaluated": sum(r["points_evaluated"] for r in rows),
                "wall_s": time.monotonic() - t0,
                "obs": obs_block}
    s = summarize(rows)
    print(f"fmax_suite,SUMMARY,0,designs={s['designs']} "
          f"opt_avg={s['opt_avg_mhz']:.0f}MHz (converged) "
          f"deadlocks={s['sim_deadlocks']}")
    print(f"fmax_suite,FLOORPLAN,0,solved={fp['solved']} "
          f"cache_hits={fp['cache_hits']} "
          f"ilp_bipartitions={fp['ilp_bipartitions']} "
          f"points={sim_meta['points_evaluated']}")
    print(f"fmax_suite,POOL,0,jobs={jobs} "
          f"dispatched={pool['dispatched']} merged={pool['merged']} "
          f"worker_solves={pool['worker_solves']} "
          f"search_wall={sim_meta['wall_s']:.2f}s")
    print(f"fmax_suite,ANALYSIS,0,analyzed={ana['analyzed']} "
          f"doomed={ana['doomed']} skipped={ana['skipped']} "
          f"infeasible={ana['infeasible']}")
    print(f"fmax_suite,OBS,0,spans={obs_block['spans']} "
          f"coverage={obs_block['stage_coverage']:.2f}"
          + (f" trace={obs_block['trace_file']}" if trace_path else ""))
    if store_block["enabled"]:
        print(f"fmax_suite,STORE,0,entries={store_block['entries']} "
              f"writes={store_block['writes']} "
              f"disk_hits={store_block['disk_hits']} "
              f"quarantined={store_block['quarantined']}")
    if plan is not None:
        obs = faults_block["observed"]
        print(f"fmax_suite,FAULTS,0,injected={faults_block['injected']} "
              f"retried={obs['retried']} timed_out={obs['timed_out']} "
              f"quarantined={obs['quarantined']} "
              f"pool_rebuilds={obs['pool_rebuilds']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "fmax_suite", "converge": True,
                       "sim_firings": sim_firings,
                       "subset": sorted(subset) if subset else None,
                       "backend": backend,
                       "rows": rows, "summary": s, "sim": sim_meta},
                      f, indent=2)
        print(f"fmax_suite,JSON,0,wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--subset", choices=("fast", "full"), default="full",
                    help="fast = CI bench-regression subset; full = all 43")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write rows + summary as JSON (BENCH_fmax.json)")
    ap.add_argument("--firings", type=int, default=DEFAULT_FIRINGS,
                    help="throughput-sim firings per task (0 disables)")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip throughput simulation entirely")
    ap.add_argument("--converge", action="store_true",
                    help="run search_until_converged per design (continuous "
                         "util range, memoized floorplans, cache stats in "
                         "the JSON sim block)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for the converged search's cold "
                         "ILP floorplan solves (1 = sequential; results "
                         "are bit-identical either way)")
    ap.add_argument("--proposer", choices=("uniform", "surrogate"),
                    default="uniform",
                    help="converged-search round-proposal strategy")
    ap.add_argument("--backend", choices=("auto", "numpy", "jax", "event"),
                    default="auto",
                    help="simulate_batch backend for the simulation phase "
                         "(jax additionally records sim.jit_cache and a "
                         "measured sim.speedup block)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="converged mode: persist floorplan solves to a "
                         "content-addressed DiskFloorplanStore at DIR "
                         "(shared across designs and runs; sim.store block)")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="converged mode: journal each design's search per "
                         "round under DIR so a killed run resumes with "
                         "bit-identical rows")
    ap.add_argument("--trace", dest="trace_path", default=None,
                    metavar="PATH",
                    help="write the run's span trace as Chrome/Perfetto "
                         "trace_event JSON at PATH (open in ui.perfetto.dev"
                         "; summarize with python -m repro.obs)")
    args = ap.parse_args()
    sim = None if args.no_sim else (args.firings or None)
    subset = FAST_SUBSET if args.subset == "fast" else None
    if args.converge:
        main_converged(sim_firings=sim, subset=subset,
                       json_path=args.json_path, jobs=args.jobs,
                       proposer=args.proposer, backend=args.backend,
                       store=args.store, checkpoint=args.checkpoint,
                       trace_path=args.trace_path)
    else:
        main(sim_firings=sim, subset=subset, json_path=args.json_path,
             backend=args.backend, trace_path=args.trace_path)
