"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV lines."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (control, fmax_suite, hbm_opts, kernels_bench,
                            roofline, scalability, throughput)
    failures = 0
    for mod in (fmax_suite, hbm_opts, control, scalability, throughput,
                kernels_bench, roofline):
        print(f"# === {mod.__name__} ===", flush=True)
        try:
            mod.main()
        except Exception:
            traceback.print_exc()
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
