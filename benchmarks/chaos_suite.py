"""CI chaos drill: prove the search stack survives injected faults.

Drives three child runs of the converged fmax suite (fast subset,
``--jobs 2``, disk store + per-design checkpoints):

1. **clean**   — no faults: the identity baseline JSON;
2. **killed**  — seeded worker crashes / hangs / torn store writes, plus
   a SIGKILL of the whole process right after the first design's round-0
   checkpoint commits.  The child MUST die with ``-SIGKILL`` — a clean
   exit means the kill site never fired and the drill is vacuous;
3. **resumed** — the same fault seed without the kill, against the same
   store and checkpoint directories.  It must resume the journal and run
   to completion.

The resumed run's JSON — augmented with a ``chaos`` block recording the
kill and the fault plan — is what ``check_regression.py --tol`` gates
against the clean JSON (``check_chaos``): every per-design row must be
bit-identical to the clean run (faults may only tick counters, never
move the frontier), the pool counters must show retries and rebuilds
actually happened, and the reopened store must have quarantined the
entries torn in the killed run.

The fault plan is pinned (seed and rates below): ``FaultPlan.decide`` is
deterministic per (seed, site, token), so the same points crash/tear on
every CI run and the nonzero-counter gates cannot flake.

CLI:
    python benchmarks/chaos_suite.py --json BENCH_chaos.json \
        [--clean-json BENCH_chaos_clean.json] [--workdir DIR] \
        [--timeout 900] [--keep]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

#: the pinned chaos plan (see repro.search.faults.FaultPlan).  attempts=1
#: keeps every fault transient — retries succeed, nothing is quarantined
#: in the pool, so the frontier-identity gate stays exact.
FAULT_PLAN = {"seed": 7, "worker_crash": 0.25, "worker_hang": 0.06,
              "torn_write": 0.30, "hang_s": 60.0, "attempts": 1}

#: per-future timeout for the FAULT-INJECTED runs only, so injected
#: hangs (hang_s=60) resolve in seconds.  The clean run keeps the stock
#: timeout: a cold ILP solve can legitimately take longer than this, and
#: a spurious timeout on the baseline would be a self-inflicted fault.
POOL_TIMEOUT_S = 10.0


def _child_env(fault_plan: dict | None) -> dict:
    env = os.environ.copy()
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.pop("REPRO_FAULTS", None)
    env.pop("REPRO_POOL_TIMEOUT_S", None)
    if fault_plan is not None:
        env["REPRO_FAULTS"] = json.dumps(fault_plan)
        env["REPRO_POOL_TIMEOUT_S"] = repr(POOL_TIMEOUT_S)
    return env


def _run_suite(label: str, *, json_path: Path, store: Path, checkpoint: Path,
               fault_plan: dict | None, timeout: float) -> int:
    cmd = [sys.executable, str(ROOT / "benchmarks" / "fmax_suite.py"),
           "--subset", "fast", "--converge", "--jobs", "2",
           "--store", str(store), "--checkpoint", str(checkpoint),
           "--json", str(json_path)]
    print(f"chaos_suite,RUN,0,{label}: {' '.join(cmd[1:])}", flush=True)
    proc = subprocess.run(cmd, env=_child_env(fault_plan), cwd=ROOT,
                          timeout=timeout)
    print(f"chaos_suite,EXIT,0,{label} returncode={proc.returncode}",
          flush=True)
    return proc.returncode


def run(json_path: str, clean_json: str | None = None,
        workdir: str | None = None, timeout: float = 900.0,
        keep: bool = False) -> dict:
    out = Path(json_path)
    clean_out = Path(clean_json) if clean_json else (
        out.with_name(out.stem + "_clean" + out.suffix))
    wd = Path(workdir) if workdir else Path(
        tempfile.mkdtemp(prefix="repro-chaos-"))
    wd.mkdir(parents=True, exist_ok=True)
    try:
        rc = _run_suite("clean", json_path=clean_out,
                        store=wd / "clean_store",
                        checkpoint=wd / "clean_ckpt",
                        fault_plan=None, timeout=timeout)
        if rc != 0:
            raise SystemExit(f"chaos_suite: clean run failed (rc={rc})")

        kill_plan = dict(FAULT_PLAN, kill_after_round=0)
        rc_killed = _run_suite("killed", json_path=wd / "killed.json",
                               store=wd / "chaos_store",
                               checkpoint=wd / "chaos_ckpt",
                               fault_plan=kill_plan, timeout=timeout)
        if rc_killed != -signal.SIGKILL:
            raise SystemExit(
                f"chaos_suite: killed run exited rc={rc_killed}, expected "
                f"{-signal.SIGKILL} — the parent_kill site never fired "
                f"and the drill is vacuous")

        rc = _run_suite("resumed", json_path=out,
                        store=wd / "chaos_store",
                        checkpoint=wd / "chaos_ckpt",
                        fault_plan=FAULT_PLAN, timeout=timeout)
        if rc != 0:
            raise SystemExit(
                f"chaos_suite: resumed run failed (rc={rc}) — the search "
                f"did not survive resume under fault injection")
    finally:
        if not keep and workdir is None:
            shutil.rmtree(wd, ignore_errors=True)

    with open(out) as f:
        data = json.load(f)
    resumed = [r["name"] for r in data["rows"]
               if r.get("resumed_rounds", 0) > 0]
    data["chaos"] = {
        "killed_runs": 1,
        "kill_returncode": rc_killed,
        "resumed": bool(resumed),
        "resumed_designs": resumed,
        "fault_plan": FAULT_PLAN,
        "pool_timeout_s": POOL_TIMEOUT_S,
    }
    with open(out, "w") as f:
        json.dump(data, f, indent=2)
    obs = data["sim"]["faults"]["observed"]
    print(f"chaos_suite,KILL,0,returncode={rc_killed} "
          f"resumed={sorted(resumed)}")
    print(f"chaos_suite,OBSERVED,0,retried={obs['retried']} "
          f"timed_out={obs['timed_out']} "
          f"pool_rebuilds={obs['pool_rebuilds']} "
          f"store_quarantined={obs['store_quarantined']}")
    print(f"chaos_suite,JSON,0,wrote {out} (baseline {clean_out})")
    return data


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_path", required=True,
                    help="write the resumed run's JSON (+ chaos block) here")
    ap.add_argument("--clean-json", default=None,
                    help="write the clean baseline JSON here "
                         "(default: <json>_clean)")
    ap.add_argument("--workdir", default=None,
                    help="store/checkpoint scratch dir (default: temp dir, "
                         "removed afterwards)")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="per-child-run timeout in seconds")
    ap.add_argument("--keep", action="store_true",
                    help="keep the scratch dir for post-mortem")
    args = ap.parse_args()
    run(args.json_path, clean_json=args.clean_json, workdir=args.workdir,
        timeout=args.timeout, keep=args.keep)


if __name__ == "__main__":
    main()
