"""Paper §7.5 / Fig. 15 control experiments on the CNN family (U250):

  (a) full TAPA (floorplan constraints + pipelining)      — green curve
  (b) pipelining computed but floorplan NOT passed to P&R — blue curve
  (c) floorplanning without pipelining                     — Fig. 3's point
  (d) 4-slot grid (die boundaries only, no middle column)  — yellow curve
"""
from __future__ import annotations

from repro.core import (Boundary, SlotGrid, analyze_timing, autobridge,
                        packed_placement)
from repro.fpga import benchmarks as B, u250_grid


def four_slot_grid(max_util=0.7):
    g = u250_grid(max_util)
    return SlotGrid("U250-4slot", rows=4, cols=1,
                    base_capacity={k: v * 2 for k, v in
                                   g.base_capacity.items()},
                    slot_caps={(r, 0): {"ddr_channels": 4.0}
                               for r in range(4)},
                    row_boundaries=[Boundary(weight=1.0, pipeline_depth=2,
                                             delay_ns=2.4)] * 3,
                    max_util=max_util)


def main():
    for n in (2, 6, 10, 14):
        graph = B.cnn(n)
        grid = u250_grid()
        base = analyze_timing(graph, grid, packed_placement(graph, grid))
        plan = autobridge(graph, grid, max_util=0.75)
        full = analyze_timing(graph, grid, plan.floorplan.placement,
                              plan.depth)
        # (b) pipeline depths computed from the floorplan, but placement is
        # the packed one (constraints not passed downstream)
        pipe_only = analyze_timing(graph, grid,
                                   packed_placement(graph, grid), plan.depth)
        # (c) floorplanned placement without pipelining
        fp_only = analyze_timing(graph, grid, plan.floorplan.placement)
        try:
            plan4 = autobridge(graph, four_slot_grid(), max_util=0.75)
            g4 = analyze_timing(graph, four_slot_grid(),
                                plan4.floorplan.placement, plan4.depth)
            g4v = f"{g4.fmax_mhz:.0f}" if g4.routed else "FAIL"
        except Exception:
            g4v = "INFEAS"
        def fmt(r):
            return f"{r.fmax_mhz:.0f}" if r.routed else "FAIL"
        print(f"control,cnn_13x{n},0,"
              f"baseline={fmt(base)} pipe_only={fmt(pipe_only)} "
              f"fp_only={fmt(fp_only)} tapa={fmt(full)} four_slot={g4v}")


if __name__ == "__main__":
    main()
