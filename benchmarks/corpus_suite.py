"""Corpus frontier-quality benchmark: generated designs through the
differential harness plus per-family search-power buckets.

Three phases, one ``BENCH_corpus.json``:

1. **lint** — every clean-family design through ``repro.analysis``'s
   structure pass; the CI gate requires zero error diagnostics.
2. **differential** — the full oracle table (``repro.corpus.differential``)
   over the clean corpus *plus* a fuzz batch (broken graphs: zero-capacity
   FIFOs, data-cycle deadlocks) at the same seeds CI pins.
3. **buckets** — per family, the first ``--search-per-family`` designs get
   a small joint design-space search; the bucket rows record frontier size
   and exact hypervolume w.r.t. the fixed ``HV_REF`` reference, which
   ``check_corpus`` compares against the committed baseline.  The ``hbm``
   family searches over ``hbm_splits`` (channel-binding axis), so corpus
   designs with HBM channel demands exercise channel-binding floorplans.

Usage:
    python benchmarks/corpus_suite.py [--designs 200] [--fuzz 40]
        [--seed 0] [--search-per-family 2] [--jobs 2] [--json OUT.json]
        [--trace PATH]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.analysis import analysis_counts, analyze, reset_analysis_counts
from repro.core import engine_counts, reset_engine_counts
from repro.corpus import CLEAN_FAMILIES, run_differential, sample_corpus
from repro.fpga import u280_grid
from repro.obs import bench_obs_block, trace as obs_trace
from repro.search.engine import explore_design_space
from repro.search.pareto import hypervolume, objective_vector
from repro.search.space import SearchSpace

#: fixed hypervolume reference (fmax floor, area/cycles ceilings) — all
#: bucket hypervolumes are measured against the same box so runs compare;
#: the box is sized to the corpus designs' actual ranges (overhead well
#: under 20k bits, waves well under 2k cycles) so all three axes move it
HV_REF = (0.0, -20_000.0, -2_000.0)
#: the channel-binding sweep of the hbm family's buckets
HBM_SPLITS = (0.25, 0.5, 0.75)


def _bucket_space(family: str) -> SearchSpace:
    base = dict(seeds=(0,), utils=(0.6, 0.75), depth_scales=(1.0, 2.0))
    if family == "hbm":
        return SearchSpace(**base, hbm_splits=HBM_SPLITS)
    return SearchSpace(**base)


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--designs", type=int, default=200,
                    help="total clean-family designs (split evenly)")
    ap.add_argument("--fuzz", type=int, default=40,
                    help="extra fuzz-family designs for the differential")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--search-per-family", type=int, default=2,
                    help="designs per family given a full search bucket")
    ap.add_argument("--floorplans", type=int, default=25,
                    help="differential autobridge budget")
    ap.add_argument("--jobs", type=int, default=2,
                    help="worker processes for the parallel-identity check")
    ap.add_argument("--surrogate", action="store_true", default=True,
                    help="include the surrogate-vs-uniform check")
    ap.add_argument("--json", dest="json_path", default=None)
    ap.add_argument("--trace", dest="trace_path", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON profile "
                         "of the run to PATH")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    obs_trace.enable(clear=True)
    grid = u280_grid()
    per_family = max(1, args.designs // len(CLEAN_FAMILIES))
    corpus = {fam: sample_corpus(fam, per_family, seed=args.seed)
              for fam in CLEAN_FAMILIES}
    fuzz = sample_corpus("fuzz", args.fuzz, seed=args.seed)

    with obs_trace.span("bench.suite", suite="corpus"):
        # phase 1: lint gate — clean families must have zero structure errors
        lint_checked, lint_errors, codes = 0, 0, set()
        with obs_trace.span("corpus.lint",
                            designs=sum(len(ds) for ds in corpus.values())):
            for designs in corpus.values():
                for d in designs:
                    rep = analyze(d.graph, grid=grid, passes=("structure",))
                    lint_checked += 1
                    if not rep.ok:
                        lint_errors += 1
                        codes.update(rep.codes())

        # phases 2+3 under shared engine/analysis counters
        reset_engine_counts()
        reset_analysis_counts()
        all_designs = [d for ds in corpus.values() for d in ds] + fuzz
        with obs_trace.span("corpus.differential", designs=len(all_designs)):
            diff = run_differential(
                all_designs, grid=grid, floorplan_limit=args.floorplans,
                search_designs=args.search_per_family, search_jobs=args.jobs,
                check_surrogate=args.surrogate)

        buckets = []
        with obs_trace.span("corpus.buckets",
                            per_family=args.search_per_family):
            for fam in CLEAN_FAMILIES:
                space = _bucket_space(fam)
                for d in corpus[fam][:args.search_per_family]:
                    res = explore_design_space(d.graph, grid, space=space,
                                               sim_firings=d.firings)
                    vecs = [objective_vector(c) for c in res.frontier]
                    hv = hypervolume(vecs, HV_REF)
                    row = {
                        "family": fam,
                        "design": d.name,
                        "fingerprint": d.fingerprint,
                        "tasks": len(d.graph.tasks),
                        "streams": len(d.graph.streams),
                        "points": res.space_size,
                        "feasible": sum(1 for c in res.candidates
                                        if c.plan is not None),
                        "frontier": len(res.frontier),
                        "hypervolume": hv,
                        "hbm_axis": space.hbm_splits != (0.5,),
                    }
                    buckets.append(row)
                    print(f"corpus,{row['design']},0,hv={hv:.1f} "
                          f"frontier={row['frontier']} "
                          f"feasible={row['feasible']}"
                          f"{' hbm_axis' if row['hbm_axis'] else ''}",
                          flush=True)

    obs_block = bench_obs_block(time.perf_counter() - t0, args.trace_path)
    out = {
        "suite": "corpus",
        "seed": args.seed,
        "designs": lint_checked,
        "fuzz_designs": len(fuzz),
        "families": {fam: len(ds) for fam, ds in corpus.items()},
        "lint": {"checked": lint_checked, "errors": lint_errors,
                 "codes": sorted(codes)},
        "differential": diff.counters(),
        "buckets": buckets,
        "engine": engine_counts(),
        "analysis": analysis_counts(),
        "hbm_splits": list(HBM_SPLITS),
        "obs": obs_block,
        "wall_s": time.perf_counter() - t0,
    }
    print(f"corpus,summary,0,designs={lint_checked}+{len(fuzz)}fuzz "
          f"lint_errors={lint_errors} differential_ok={diff.ok} "
          f"fallbacks={out['engine'].get('fallback', 0)}", flush=True)
    print(f"corpus,OBS,0,spans={obs_block['spans']} "
          f"coverage={obs_block['stage_coverage']:.2f}"
          + (f" trace={obs_block['trace_file']}" if args.trace_path else ""),
          flush=True)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json_path}", flush=True)
    return out


if __name__ == "__main__":
    main()
